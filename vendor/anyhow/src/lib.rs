//! Offline facade for the `anyhow` error-handling API.
//!
//! The real `anyhow` crate is not in the offline vendor set, so this shim
//! provides the subset the codebase uses: [`Error`], [`Result`], the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!` /
//! `bail!` macros. Context frames are stored as a flat string chain;
//! `{}` displays the outermost frame and `{:#}` the full chain joined with
//! `": "`, matching anyhow's display conventions closely enough for logs
//! and test assertions.

use std::fmt;

/// Drop-in for `anyhow::Error`: an owned error with a context chain.
/// `chain[0]` is the outermost (most recently attached) frame.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a context frame (what `.context(...)` produces).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) frame.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for frame in &self.chain[1..] {
                writeln!(f, "    {frame}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = anyhow!("inner {}", 3);
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 3");
    }

    #[test]
    fn from_std_error_keeps_source_chain() {
        let r: Result<()> = Err(io_err().into());
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("boom");
        }
        let e = inner().context("mid").context("top").unwrap_err();
        assert_eq!(format!("{e:#}"), "top: mid: boom");
        assert_eq!(e.root_cause(), "boom");
    }

    #[test]
    fn question_mark_conversions() {
        fn f() -> Result<i64> {
            let n: i64 = "12x".parse().unwrap_or(0);
            let _ = std::fs::metadata("/definitely/not/here/ever")?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn ensure_macro() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert!(f(-1).is_err());
    }
}
