//! Offline facade for the `log` logging facade crate.
//!
//! Provides `Level`, `LevelFilter`, `Record`, `Metadata`, the `Log` trait,
//! the global logger registry, and the `error!`..`trace!` macros — the
//! subset `heron_sfl::util::logging` and the rest of the crate use.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

#[derive(Debug)]
pub struct SetLoggerError;

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("logger already set")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER
        .set(Box::leak(logger))
        .map_err(|_| SetLoggerError)
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record {
                metadata: Metadata { level },
                args,
            };
            if logger.enabled(&record.metadata) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Error, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Warn, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Info, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Debug, ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Trace, ::std::format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
