//! Paper Table III: client consumption for GPT2-Medium fine-tuning —
//! peak client memory and FLOPs per step on the GPT2-micro analog
//! (SplitLoRA / CSE-FSL / FSL-SAGE / HERON-SFL).

use heron_sfl::bench_harness::Table;
use heron_sfl::coordinator::accounting::CostBook;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::experiments::{curve_summary, lm_base, run, scaled_rounds};
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(3, 30);
    let variant = "gpt2micro_c2_a1";
    let v = session.variant(variant)?.clone();

    let mut t = Table::new(&[
        "Algorithm", "Peak FP (MB)", "FLOPs/step (M)", "ppl curve",
    ]);
    // SplitLoRA is SFLV2 on the LoRA transformer
    for (label, alg) in [
        ("SplitLoRA", Algorithm::SflV2),
        ("CSE-FSL", Algorithm::CseFsl),
        ("FSL-SAGE", Algorithm::FslSage),
        ("HERON-SFL", Algorithm::Heron),
    ] {
        let book = CostBook::new(&v, alg, 1);
        let mut cfg = lm_base(variant, rounds);
        cfg.algorithm = alg;
        let rec = run(&session, cfg, label)?;
        t.row(vec![
            label.into(),
            format!("{:.3}", book.peak_mem_bytes as f64 / 1e6),
            format!("{:.1}", book.flops_per_step as f64 / 1e6),
            curve_summary(&rec, false),
        ]);
    }
    t.print("TABLE III — client consumption, GPT2-micro on SynthE2E");

    let heron = CostBook::new(&v, Algorithm::Heron, 1);
    let cse = CostBook::new(&v, Algorithm::CseFsl, 1);
    let sfl = CostBook::new(&v, Algorithm::SflV2, 1);
    println!(
        "\nHERON peak mem vs CSE-FSL: -{:.0}% | vs SplitLoRA: {:+.0}%",
        (1.0 - heron.peak_mem_bytes as f64 / cse.peak_mem_bytes as f64)
            * 100.0,
        (heron.peak_mem_bytes as f64 / sfl.peak_mem_bytes as f64 - 1.0)
            * 100.0,
    );
    println!(
        "HERON FLOPs vs CSE-FSL: -{:.0}% (paper: ~44%)",
        (1.0 - heron.flops_per_step as f64 / cse.flops_per_step as f64)
            * 100.0
    );
    assert!(heron.peak_mem_bytes < cse.peak_mem_bytes);
    assert!(heron.flops_per_step < cse.flops_per_step);
    println!("\ntable3_gpt2_resources OK");
    Ok(())
}
