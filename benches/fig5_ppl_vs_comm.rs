//! Paper Fig 5: GPT2 validation perplexity vs cumulative communication
//! volume on the E2E task — nano (GPT2-Small analog, left) and micro
//! (GPT2-Medium analog, right), four algorithms.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::experiments::{curve_summary, lm_base, run, scaled_rounds};
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(4, 25);

    for (panel, variant) in [
        ("left: GPT2-nano (Small analog)", "gpt2nano_c1_a1"),
        ("right: GPT2-micro (Medium analog)", "gpt2micro_c2_a1"),
    ] {
        println!("\n=== Fig 5 ({panel}) — perplexity vs comm volume ===");
        println!("csv: algo,comm_mb,ppl");
        for (label, alg) in [
            ("SplitLoRA", Algorithm::SflV2),
            ("CSE-FSL", Algorithm::CseFsl),
            ("FSL-SAGE", Algorithm::FslSage),
            ("HERON-SFL", Algorithm::Heron),
        ] {
            let mut cfg = lm_base(variant, rounds);
            cfg.algorithm = alg;
            let rec = run(&session, cfg, label)?;
            for r in &rec.rounds {
                if r.eval_metric.is_finite() {
                    println!(
                        "{label},{:.3},{:.3}",
                        r.comm_bytes_cum as f64 / 1e6,
                        r.eval_metric
                    );
                }
            }
            println!("# {label:<10} ppl {}", curve_summary(&rec, false));
        }
    }
    println!("\nfig5_ppl_vs_comm OK");
    Ok(())
}
