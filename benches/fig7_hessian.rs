//! Paper Fig 7 / Appendix B: Hessian eigenvalue density of the client-side
//! local loss, estimated by stochastic Lanczos quadrature over the `hvp`
//! HLO entry — the empirical evidence for the low-effective-rank
//! Assumption 5 that gives HERON-SFL its dimension-independent rate.

use anyhow::{Context, Result};
use heron_sfl::analysis::lanczos::{self, Hvp};
use heron_sfl::data::synth_vision;
use heron_sfl::experiments::full_mode;
use heron_sfl::runtime::tensor::TensorValue;
use heron_sfl::runtime::{Call, Session};

struct EntryHvp<'a> {
    session: &'a Session,
    variant: &'a str,
    theta: Vec<f32>,
    x: Vec<f32>,
    y: Vec<i32>,
}

impl Hvp for EntryHvp<'_> {
    fn dim(&self) -> usize {
        self.theta.len()
    }
    fn apply(&mut self, v: &[f32]) -> Result<Vec<f32>> {
        let outs = Call::new(self.session, self.variant, "hvp")
            .arg("theta_l", self.theta.clone())
            .arg("x", self.x.clone())
            .arg("y", TensorValue::I32(self.y.clone()))
            .arg("v", v.to_vec())
            .run()?;
        outs.get("hv").context("hv")?.clone().into_f32()
    }
}

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let variant = "cnn_c1";
    let v = session.variant(variant)?;
    let (steps, probes) = if full_mode() { (48, 8) } else { (16, 2) };

    let (xs, ys) = synth_vision::batch(42, 0, v.batch);
    let mut h = EntryHvp {
        session: &session,
        variant,
        theta: v.blob("init_theta_l")?,
        x: xs,
        y: ys,
    };

    let hist = lanczos::spectral_density(&mut h, steps, probes, 31)?;
    hist.print(
        "Fig 7 — Hessian eigenvalue density, MiniResNet client local loss",
    );
    let near0 = hist.mass_near_zero((hist.hi - hist.lo) * 0.05);
    println!(
        "\nspectral mass within 5% of range around zero: {:.1}% \
         (paper: 'heavily concentrated at zero')",
        near0 * 100.0
    );
    let kappa = lanczos::effective_rank(&mut h, steps, probes)?;
    println!(
        "effective rank tr(H)/||H||_2 ~ {kappa:.1} of dim {} \
         (Assumption 5's kappa << d)",
        Hvp::dim(&h)
    );
    assert!(
        near0 > 0.5,
        "spectrum not concentrated near zero (mass {near0:.2})"
    );
    assert!(
        kappa < Hvp::dim(&h) as f64 * 0.2,
        "effective rank not small: {kappa}"
    );
    println!("\nfig7_hessian OK");
    Ok(())
}
