//! Paper Table II: client consumption for ResNet training — cumulative
//! communication until the accuracy threshold, peak client memory, and
//! client FLOPs per step, for all five algorithms.
//!
//! The paper's threshold is 80% on CIFAR-10; the substitute threshold here
//! scales to SynthCIFAR (env ACC_THRESHOLD, default 0.8 under REPRO_FULL,
//! 0.45 in smoke mode so the table populates within the short budget).

use heron_sfl::bench_harness::Table;
use heron_sfl::coordinator::accounting::fmt_bytes;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::experiments::{full_mode, run, scaled_rounds, vision_base};
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(8, 120);
    let threshold: f64 = std::env::var("ACC_THRESHOLD")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full_mode() { 0.8 } else { 0.45 });

    let mut t = Table::new(&[
        "Algorithm",
        &format!("Comm to {:.0}% (MB)", threshold * 100.0),
        "Peak FP (MB)",
        "FLOPs/step (G)",
        "Best acc",
    ]);

    let mut rows: Vec<(Algorithm, Option<u64>, u64, u64, f64)> = Vec::new();
    for alg in Algorithm::all() {
        let mut cfg = vision_base(rounds);
        cfg.algorithm = alg;
        let mut driver =
            heron_sfl::coordinator::round::Driver::new(&session, cfg.clone())?;
        let book_mem = driver.book.peak_mem_bytes;
        let book_flops = driver.book.flops_per_step;
        let rec = driver.run(alg.name())?;
        let comm = rec.comm_to_threshold(threshold, true);
        let best = rec.best_metric(true).unwrap_or(0.0);
        rows.push((alg, comm, book_mem, book_flops, best));
        let _ = run; // (helper consumed above through Driver directly)
    }

    for (alg, comm, mem, flops, best) in &rows {
        t.row(vec![
            alg.name().into(),
            comm.map(|c| format!("{:.2}", c as f64 / 1e6))
                .unwrap_or_else(|| "not reached".into()),
            format!("{:.2}", *mem as f64 / 1e6),
            format!("{:.2}", *flops as f64 / 1e9),
            format!("{best:.3}"),
        ]);
    }
    t.print("TABLE II — client consumption, MiniResNet on SynthCIFAR");

    // paper-shape checks: HERON minimizes memory and flops
    let heron = rows
        .iter()
        .find(|(a, ..)| *a == Algorithm::Heron)
        .unwrap();
    let cse = rows
        .iter()
        .find(|(a, ..)| *a == Algorithm::CseFsl)
        .unwrap();
    let sflv1 = rows
        .iter()
        .find(|(a, ..)| *a == Algorithm::SflV1)
        .unwrap();
    println!(
        "\nHERON memory reduction vs CSE-FSL: {:.0}% (paper: ~64% vs SFLV1/V2)",
        (1.0 - heron.2 as f64 / cse.2 as f64) * 100.0
    );
    println!(
        "HERON FLOPs reduction vs CSE-FSL: {:.0}% (paper: ~33%)",
        (1.0 - heron.3 as f64 / cse.3 as f64) * 100.0
    );
    assert!(heron.2 < cse.2 && heron.2 < sflv1.2);
    assert!(heron.3 < cse.3);
    println!(
        "comm note: {}",
        fmt_bytes(heron.1.unwrap_or(0))
    );
    println!("\ntable2_resnet_resources OK");
    Ok(())
}
