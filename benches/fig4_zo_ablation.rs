//! Paper Fig 4: ablation of the ZO hyperparameters on MiniResNet —
//! (left) perturbation step size mu, (right) probes per step n_pert —
//! for both client split points ("Client Size 1" = cnn_c1,
//! "Client Size 2" = cnn_c2).
//!
//! Expected shape: accuracy stable over a wide mu band, n_pert=1-2 already
//! sufficient, and cnn_c1 >= cnn_c2 (larger client share trains slower with
//! ZO).

use heron_sfl::experiments::{full_mode, run, scaled_rounds, vision_base};
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(5, 40);
    let variants = ["cnn_c1", "cnn_c2"];

    println!("=== Fig 4 (left) — perturbation step size mu ===");
    println!("csv: variant,mu,best_acc");
    let mus: &[f32] = if full_mode() {
        &[1e-4, 1e-3, 1e-2, 5e-2, 1e-1]
    } else {
        &[1e-3, 1e-2]
    };
    for variant in variants {
        for &mu in mus {
            let mut cfg = vision_base(rounds);
            cfg.variant = variant.into();
            cfg.n_clients = 10;
            cfg.dataset_size = 4096;
            cfg.mu = mu;
            cfg.eval_every = rounds;
            let rec = run(&session, cfg, &format!("{variant}-mu{mu}"))?;
            println!(
                "{variant},{mu},{:.4}",
                rec.best_metric(true).unwrap_or(0.0)
            );
        }
    }

    println!("\n=== Fig 4 (right) — perturbation count n_pert ===");
    println!("csv: variant,n_pert,best_acc");
    let nps: &[usize] = if full_mode() { &[1, 2, 4, 8] } else { &[1, 2] };
    for variant in variants {
        for &np in nps {
            let mut cfg = vision_base(rounds);
            cfg.variant = variant.into();
            cfg.n_clients = 10;
            cfg.n_pert = np;
            cfg.eval_every = rounds;
            let rec = run(&session, cfg, &format!("{variant}-np{np}"))?;
            println!(
                "{variant},{np},{:.4}",
                rec.best_metric(true).unwrap_or(0.0)
            );
        }
    }

    println!("\nfig4_zo_ablation OK");
    Ok(())
}
