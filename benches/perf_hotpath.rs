//! §Perf hot-path microbenchmarks: the numbers EXPERIMENTS.md §Perf records.
//!
//! Measures each layer's contribution to a training step:
//!   L3: marshaling, aggregation, perturbation streaming, data generation
//!   runtime entries: zo_step / fo_step / server_step / client_fwd
//!   end-to-end: one full HERON round, sequential vs parallel workers
//!
//! Set `BENCH_OUT=path.json` to write the measurements (plus the parallel
//! speedup) as a JSON report — CI uploads this as the perf-smoke artifact.

use anyhow::Result;
use heron_sfl::bench_harness::Bench;
use heron_sfl::coordinator::aggregator::fedavg_into;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::data::synth_vision;
use heron_sfl::golden;
use heron_sfl::runtime::Session;
use heron_sfl::zo::stream::PerturbStream;
use heron_sfl::zo::ZoSgd;

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let mut b = Bench::new();

    Bench::header("L3 primitives");
    // perturbation stream regeneration (the Remark-4 O(1)-memory path)
    let mut buf = vec![0.0f32; 1 << 16];
    b.run("perturb_stream_fill_64k", || {
        PerturbStream::new(7).fill(&mut buf);
        std::hint::black_box(&buf);
    });
    let m = b.results().last().unwrap();
    println!(
        "  -> {:.2} M elems/s",
        (1 << 16) as f64 / m.mean_secs() / 1e6
    );

    // ZO-SGD quadratic steps: materialized vs streamed
    let quad = |x: &[f32]| x.iter().map(|v| v * v).sum::<f32>() * 0.5;
    let opt = ZoSgd::new(quad, 1e-3, 0.01);
    let mut theta = vec![0.5f32; 1 << 16];
    b.run("zo_step_materialized_64k", || {
        opt.step_materialized(&mut theta, 3);
    });
    b.run("zo_step_streamed_64k", || {
        opt.alloc_free_step(&mut theta, 3);
    });

    // FedAvg aggregation over 10 clients x 64k params
    let clients: Vec<Vec<f32>> = (0..10)
        .map(|i| vec![i as f32 * 0.1; 1 << 16])
        .collect();
    let refs: Vec<&[f32]> = clients.iter().map(|c| c.as_slice()).collect();
    let weights = vec![1.0f64; 10];
    let mut out = vec![0.0f32; 1 << 16];
    b.run("fedavg_10x64k", || {
        fedavg_into(&refs, &weights, &mut out);
        std::hint::black_box(&out);
    });

    // synthetic data generation (per 32-image batch)
    let mut xs = vec![0.0f32; 32 * synth_vision::PIXELS];
    let mut ys = vec![0i32; 32];
    b.run("synth_vision_batch32", || {
        synth_vision::batch_into(42, 0, 32, &mut xs, &mut ys);
        std::hint::black_box(&xs);
    });

    Bench::header("runtime entries (cnn_c1, batch 32)");
    let variant = "cnn_c1";
    session.warmup(
        variant,
        &["zo_step", "fo_step", "server_step", "client_fwd", "eval_full"],
    )?;
    let v = session.variant(variant)?.clone();
    for entry in ["client_fwd", "zo_step", "fo_step", "server_step", "eval_full"]
    {
        let espec = v.entry(entry)?.clone();
        let inputs: Vec<_> = espec
            .inputs
            .iter()
            .enumerate()
            .map(|(idx, spec)| {
                golden_input_for_bench(&session, variant, spec, idx, &v.task)
            })
            .collect::<Result<Vec<_>>>()?;
        b.run(&format!("invoke_{entry}"), || {
            session.invoke(variant, entry, &inputs).expect("invoke");
        });
    }

    Bench::header("end-to-end round (HERON, 5 clients, h=2)");
    let cfg = RunConfig {
        rounds: 1,
        ..heron_sfl::experiments::vision_base(1)
    };
    let mut driver = Driver::new(&session, cfg)?;
    driver.warmup()?;
    b.run("heron_full_round", || {
        driver.run_round().expect("round");
    });

    // ---- parallel round engine: sequential vs worker-pool wall clock ----
    Bench::header("parallel round engine (HERON, 8 clients, h=4)");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut worker_counts = vec![1usize, 2, cores.max(2)];
    worker_counts.dedup(); // <=2 cores would repeat the workers=2 run
    let mut round_means: Vec<(usize, f64)> = Vec::new();
    for workers in worker_counts {
        let cfg = RunConfig {
            rounds: 1,
            n_clients: 8,
            local_steps: 4,
            workers,
            ..heron_sfl::experiments::vision_base(1)
        };
        let mut driver = Driver::new(&session, cfg)?;
        driver.warmup()?;
        let m = b
            .run(&format!("heron_round_8c_workers{workers}"), || {
                driver.run_round().expect("round");
            })
            .clone();
        round_means.push((workers, m.mean_ns));
    }
    let seq = round_means[0].1;
    let (best_w, best) = round_means
        .iter()
        .cloned()
        .fold((1, f64::INFINITY), |a, b| if b.1 < a.1 { b } else { a });
    let speedup = seq / best.max(1.0);
    println!(
        "  -> parallel speedup: {speedup:.2}x at {best_w} workers \
         (sequential {} vs {})",
        heron_sfl::bench_harness::fmt_ns(seq),
        heron_sfl::bench_harness::fmt_ns(best),
    );

    let st = session.stats();
    println!(
        "\nruntime totals: {} invocations | exec {:.2}s | marshal {:.2}s ({:.1}% of exec)",
        st.invocations,
        st.exec_seconds,
        st.marshal_seconds,
        100.0 * st.marshal_seconds / st.exec_seconds.max(1e-9)
    );

    if let Ok(path) = std::env::var("BENCH_OUT") {
        write_report(&path, b.results(), speedup, best_w)?;
        println!("wrote JSON report to {path}");
    }
    println!("\nperf_hotpath OK");
    Ok(())
}

/// JSON report for the CI perf-smoke artifact.
fn write_report(
    path: &str,
    results: &[heron_sfl::bench_harness::Measurement],
    speedup: f64,
    speedup_workers: usize,
) -> Result<()> {
    use heron_sfl::util::json::Value;
    let benchmarks: Vec<Value> = results
        .iter()
        .map(|m| {
            Value::obj(vec![
                ("name", Value::str(&m.name)),
                ("iters", Value::Num(m.iters as f64)),
                ("mean_ns", Value::Num(m.mean_ns)),
                ("p50_ns", Value::Num(m.p50_ns)),
                ("p95_ns", Value::Num(m.p95_ns)),
                ("std_ns", Value::Num(m.std_ns)),
            ])
        })
        .collect();
    let report = Value::obj(vec![
        ("schema", Value::str("heron-sfl-bench-v1")),
        ("benchmarks", Value::Arr(benchmarks)),
        ("parallel_speedup", Value::Num(speedup)),
        ("parallel_speedup_workers", Value::Num(speedup_workers as f64)),
    ]);
    std::fs::write(path, report.to_string_pretty())?;
    Ok(())
}

fn golden_input_for_bench(
    session: &Session,
    variant: &str,
    spec: &heron_sfl::runtime::manifest::TensorSpec,
    idx: usize,
    task: &str,
) -> Result<heron_sfl::runtime::tensor::TensorValue> {
    // reuse the golden-input construction (deterministic, well-conditioned)
    golden::bench_input(session, variant, spec, idx, task)
}
