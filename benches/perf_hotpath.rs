//! §Perf hot-path microbenchmarks: the numbers EXPERIMENTS.md §Perf records.
//!
//! Measures each layer's contribution to a training step:
//!   L3: marshaling, aggregation, perturbation streaming, data generation
//!   runtime entries: zo_step / fo_step / server_step / client_fwd
//!   end-to-end: one full HERON round, sequential vs parallel workers
//!
//! Set `BENCH_OUT=path.json` to write the measurements (plus the parallel
//! speedup and the feature-plan cache counters) as a JSON report — CI
//! uploads this as the perf-smoke artifact.
//!
//! Set `BENCH_BASELINE=path.json` to compare against a committed baseline
//! report (`BENCH_BASELINE.json` at the repo root): the run fails if the
//! `heron_full_round` mean regresses by more than 25% (machine-normalized
//! by the `perturb_stream_fill_64k` canary, which this crate's hot-path
//! work never touches), and prints the sequential-vs-parallel speedup
//! delta. When `GITHUB_STEP_SUMMARY` is set, the comparison is appended
//! there as markdown.

use anyhow::{bail, Context, Result};
use heron_sfl::bench_harness::{fmt_ns, Bench, Measurement};
use heron_sfl::coordinator::aggregator::fedavg_into;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::coordinator::server_queue::{ServerQueue, SmashedBatch};
use heron_sfl::data::synth_vision;
use heron_sfl::golden;
use heron_sfl::runtime::{RuntimeStats, Session};
use heron_sfl::util::json::{self, Value};
use heron_sfl::zo::stream::PerturbStream;
use heron_sfl::zo::ZoSgd;

/// Machine-speed canary: untouched by the invoke-path/caching work, so
/// baseline-vs-current ratios of (round / canary) cancel host speed.
const CANARY: &str = "perturb_stream_fill_64k";
const ROUND: &str = "heron_full_round";
/// Fail the baseline gate when the normalized round mean regresses >25%.
const REGRESSION_LIMIT: f64 = 1.25;
/// 64k disabled `span!` sites must stay within this multiple of the 64k
/// stream-fill canary — a machine-independent ceiling on the "telemetry
/// off" cost (one relaxed atomic load per site).
const TELEMETRY: &str = "telemetry_disabled_64k";
const TELEMETRY_LIMIT: f64 = 8.0;

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    // metrics registry on for the whole run (the report dumps it below);
    // spans stay OFF — `telemetry_disabled_64k` measures exactly that path
    heron_sfl::telemetry::enable_metrics();
    let session = Session::open_default()?;
    let mut b = Bench::new();

    Bench::header("L3 primitives");
    // perturbation stream regeneration (the Remark-4 O(1)-memory path)
    let mut buf = vec![0.0f32; 1 << 16];
    b.run(CANARY, || {
        PerturbStream::new(7).fill(&mut buf);
        std::hint::black_box(&buf);
    });
    let m = b.results().last().unwrap();
    println!(
        "  -> {:.2} M elems/s",
        (1 << 16) as f64 / m.mean_secs() / 1e6
    );
    let canary_ns = m.mean_ns;

    // the flight recorder with no trace writer installed: 64k span sites
    // per iteration, each one relaxed AtomicBool load + branch
    b.run(TELEMETRY, || {
        let mut acc = 0u64;
        for i in 0..(1u64 << 16) {
            let _s = heron_sfl::span!("bench_site", i = i);
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
    });
    let tel_ns = b.results().last().unwrap().mean_ns;
    println!(
        "  -> disabled telemetry: {} per 64k span sites \
         ({:.2}x canary, limit {TELEMETRY_LIMIT}x)",
        fmt_ns(tel_ns),
        tel_ns / canary_ns.max(1.0),
    );
    if tel_ns > canary_ns.max(1.0) * TELEMETRY_LIMIT {
        bail!(
            "{TELEMETRY} mean {} exceeds {TELEMETRY_LIMIT}x the {CANARY} \
             canary ({}) — the disabled span path grew a clock read or lock",
            fmt_ns(tel_ns),
            fmt_ns(canary_ns),
        );
    }

    // ZO-SGD quadratic steps: materialized (optimizer-held scratch) vs
    // streamed (O(chunk) regeneration)
    let quad = |x: &[f32]| x.iter().map(|v| v * v).sum::<f32>() * 0.5;
    let mut opt = ZoSgd::new(quad, 1e-3, 0.01);
    let mut theta = vec![0.5f32; 1 << 16];
    b.run("zo_step_materialized_64k", || {
        opt.step_materialized(&mut theta, 3);
    });
    b.run("zo_step_streamed_64k", || {
        opt.alloc_free_step(&mut theta, 3);
    });

    // FedAvg aggregation over 10 clients x 64k params
    let clients: Vec<Vec<f32>> = (0..10)
        .map(|i| vec![i as f32 * 0.1; 1 << 16])
        .collect();
    let refs: Vec<&[f32]> = clients.iter().map(|c| c.as_slice()).collect();
    let weights = vec![1.0f64; 10];
    let mut out = vec![0.0f32; 1 << 16];
    b.run("fedavg_10x64k", || {
        fedavg_into(&refs, &weights, &mut out);
        std::hint::black_box(&out);
    });

    // synthetic data generation (per 32-image batch)
    let mut xs = vec![0.0f32; 32 * synth_vision::PIXELS];
    let mut ys = vec![0i32; 32];
    b.run("synth_vision_batch32", || {
        synth_vision::batch_into(42, 0, 32, &mut xs, &mut ys);
        std::hint::black_box(&xs);
    });

    // wire codec: encode+decode one 64k-param ModelSync frame (the
    // dominant message of a networked round) — serialization must stay
    // negligible next to the model math it ships
    let theta: Vec<f32> = PerturbStream::new(11).take_vec(1 << 16);
    let sync = heron_sfl::net::Msg::ModelSync {
        lane: 0,
        round: 1,
        client: 0,
        theta,
    };
    b.run("wire_roundtrip_modelsync_64k", || {
        let frame = heron_sfl::net::wire::encode_frame(&sync);
        let (msg, _) =
            heron_sfl::net::wire::decode_frame(&frame).expect("decode");
        std::hint::black_box(&msg);
    });

    // payload codec: int8-quantize one 64k-element smashed activation —
    // the per-upload encode cost `--codec int8` adds on the client hot
    // path (one max/min pass + one round/clamp pass over the tensor)
    let smashed64: Vec<f32> = PerturbStream::new(19).take_vec(1 << 16);
    b.run("codec_encode_64k", || {
        let enc = heron_sfl::net::codec::encode(
            heron_sfl::net::codec::Codec::Int8,
            &smashed64,
        );
        std::hint::black_box(&enc);
    });

    // seeds-mode server replay: reconstruct θ' over 64k params from a
    // recorded (seed, per-probe gscales) pair — the per-step server cost
    // `--zo_wire seeds` trades for the eliminated θ upload
    let theta64: Vec<f32> = PerturbStream::new(13).take_vec(1 << 16);
    let gscales = [0.125f32, -0.0625];
    let mut replay_out = Vec::new();
    b.run("zo_replay_64k", || {
        heron_sfl::zo::stream::replay_update(
            &theta64,
            0x5EED,
            &gscales,
            &mut replay_out,
        );
        std::hint::black_box(&replay_out);
    });

    // seed_agg client-side aggregate replay: FedAvg 4 participants'
    // 4-step trajectories over 64k params straight from a SeedSync
    // roster — the per-round client cost `--zo_wire seed_agg` trades
    // for the eliminated dense θ broadcast
    let (agg_p, agg_h, agg_np) = (4usize, 4usize, 2usize);
    let agg_seeds: Vec<i32> =
        (0..agg_p * agg_h).map(|i| 0x5EED + i as i32).collect();
    let agg_gscales: Vec<f32> = (0..agg_p * agg_h * agg_np)
        .map(|i| ((i % 7) as f32 - 3.0) * 0.015625)
        .collect();
    let agg_records: Vec<(&[i32], &[f32])> = (0..agg_p)
        .map(|i| {
            (
                &agg_seeds[i * agg_h..(i + 1) * agg_h],
                &agg_gscales[i * agg_h * agg_np..(i + 1) * agg_h * agg_np],
            )
        })
        .collect();
    let agg_weights = vec![1.0f64; agg_p];
    b.run("seed_agg_replay_64k", || {
        let out = heron_sfl::zo::aggregate_trajectories(
            &theta64,
            &agg_records,
            &agg_weights,
            agg_np,
        )
        .expect("aggregate");
        std::hint::black_box(&out);
    });

    // stream-drain queue mechanics: 16 × 4096-f32 smashed batches (64k
    // elements) through the bounded MPSC — push + arrival-order FIFO pop,
    // the per-round queue work `--drain stream` adds to the hot path
    let payload: Vec<f32> = PerturbStream::new(17).take_vec(4096);
    b.run("stream_drain_64k", || {
        let q = ServerQueue::new(32);
        for step in 1..=16usize {
            q.push(SmashedBatch {
                client: 0,
                round: 0,
                step,
                smashed: payload.clone(),
                targets: vec![0; 32],
            });
        }
        let mut elems = 0usize;
        while let Some(batch) = q.pop() {
            elems += batch.smashed.len();
        }
        std::hint::black_box(elems);
    });

    Bench::header("runtime entries (cnn_c1, batch 32)");
    let variant = "cnn_c1";
    session.warmup(
        variant,
        &["zo_step", "fo_step", "server_step", "client_fwd", "eval_full"],
    )?;
    let v = session.variant(variant)?.clone();
    for entry in ["client_fwd", "zo_step", "fo_step", "server_step", "eval_full"]
    {
        let espec = v.entry(entry)?.clone();
        let inputs: Vec<_> = espec
            .inputs
            .iter()
            .enumerate()
            .map(|(idx, spec)| {
                golden_input_for_bench(&session, variant, spec, idx, &v.task)
            })
            .collect::<Result<Vec<_>>>()?;
        b.run(&format!("invoke_{entry}"), || {
            session.invoke(variant, entry, &inputs).expect("invoke");
        });
    }

    Bench::header("end-to-end round (HERON, 5 clients, h=2)");
    let cfg = RunConfig {
        rounds: 1,
        ..heron_sfl::experiments::vision_base(1)
    };
    let mut driver = Driver::new(&session, cfg)?;
    driver.warmup()?;
    b.run(ROUND, || {
        driver.run_round().expect("round");
    });
    // counters snapshotted around ONE further round (the bench loop above
    // already warmed the cache): the steady-state per-round hit rate, not
    // an aggregate over warmup + every timed iteration
    let cache_before = session.stats();
    driver.run_round()?;
    let cache_after = session.stats();
    let round_hits =
        cache_after.feature_cache_hits - cache_before.feature_cache_hits;
    let round_misses =
        cache_after.feature_cache_misses - cache_before.feature_cache_misses;
    println!(
        "  -> feature cache, one steady-state HERON round: {round_hits} \
         hits / {round_misses} misses ({:.1}% hit rate)",
        100.0 * round_hits as f64 / (round_hits + round_misses).max(1) as f64
    );
    // the drain-policy comparison for that round, from the event-sim's
    // arrival-driven server schedule (recorded into bench_report.json)
    let (mk_barrier, mk_stream) = driver
        .timings
        .last()
        .map(|t| (t.server_makespan_barrier, t.server_makespan_stream))
        .unwrap_or((0.0, 0.0));
    println!(
        "  -> simulated server makespan: barrier {mk_barrier:.3}s vs \
         stream {mk_stream:.3}s ({:.1}% lower pipelined)",
        100.0 * (1.0 - mk_stream / mk_barrier.max(1e-12))
    );

    // ---- parallel round engine: sequential vs worker-pool wall clock ----
    Bench::header("parallel round engine (HERON, 8 clients, h=4)");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut worker_counts = vec![1usize, 2, cores.max(2)];
    worker_counts.dedup(); // <=2 cores would repeat the workers=2 run
    let mut round_means: Vec<(usize, f64)> = Vec::new();
    for workers in worker_counts {
        let cfg = RunConfig {
            rounds: 1,
            n_clients: 8,
            local_steps: 4,
            workers,
            ..heron_sfl::experiments::vision_base(1)
        };
        let mut driver = Driver::new(&session, cfg)?;
        driver.warmup()?;
        let m = b
            .run(&format!("heron_round_8c_workers{workers}"), || {
                driver.run_round().expect("round");
            })
            .clone();
        round_means.push((workers, m.mean_ns));
    }
    let seq = round_means[0].1;
    let (best_w, best) = round_means
        .iter()
        .cloned()
        .fold((1, f64::INFINITY), |a, b| if b.1 < a.1 { b } else { a });
    let speedup = seq / best.max(1.0);
    println!(
        "  -> parallel speedup: {speedup:.2}x at {best_w} workers \
         (sequential {} vs {})",
        fmt_ns(seq),
        fmt_ns(best),
    );

    let st = session.stats();
    println!(
        "\nruntime totals: {} invocations | exec {:.2}s | marshal {:.2}s ({:.1}% of exec)",
        st.invocations,
        st.exec_seconds,
        st.marshal_seconds,
        100.0 * st.marshal_seconds / st.exec_seconds.max(1e-9)
    );
    println!(
        "feature cache totals: {} hits / {} misses ({:.1}% hit rate), {} avoided",
        st.feature_cache_hits,
        st.feature_cache_misses,
        100.0 * st.feature_cache_hit_rate(),
        heron_sfl::coordinator::accounting::fmt_bytes(st.alloc_avoided_bytes),
    );

    // analytic downlink of one HERON round sync for the bench preset:
    // the dense θ_l broadcast vs the dimension-free SeedSync roster —
    // the byte claim behind the seed_agg_replay_64k timing above
    let vb = heron_sfl::experiments::vision_base(1);
    let agg_book = heron_sfl::coordinator::accounting::CostBook::new(
        session.variant(&vb.variant)?,
        vb.algorithm,
        vb.n_pert as u64,
    )
    .with_zo_wire(
        heron_sfl::coordinator::config::ZoWireMode::SeedAgg,
        vb.local_steps as u64,
        vb.participants_per_round() as u64,
    );
    let downlink_dense = agg_book.downlink_per_round_sync(0);
    let downlink_lean = agg_book.downlink_per_round_sync(1);
    println!(
        "  -> per-round sync downlink: dense {} vs seed_agg roster {} \
         ({:.1}x leaner)",
        heron_sfl::coordinator::accounting::fmt_bytes(downlink_dense),
        heron_sfl::coordinator::accounting::fmt_bytes(downlink_lean),
        downlink_dense as f64 / downlink_lean.max(1) as f64,
    );

    if let Ok(path) = std::env::var("BENCH_OUT") {
        write_report(
            &path,
            b.results(),
            speedup,
            best_w,
            &st,
            round_hits,
            round_misses,
            mk_barrier,
            mk_stream,
            downlink_dense,
            downlink_lean,
        )?;
        // dump the live metrics registry (counters/histograms the bench
        // itself populated — queue waits, client step counters, runtime
        // totals) into the same report under a `registry.` prefix
        st.publish_registry();
        let snap = heron_sfl::telemetry::registry::snapshot();
        if !snap.is_empty() {
            let owned: Vec<(String, Value)> = snap
                .into_iter()
                .map(|(k, v)| (format!("registry.{k}"), Value::Num(v)))
                .collect();
            let extras: Vec<(&str, Value)> =
                owned.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
            heron_sfl::bench_harness::merge_report(&path, &[], &extras)?;
        }
        println!("wrote JSON report to {path}");
    }

    if let Ok(baseline) = std::env::var("BENCH_BASELINE") {
        compare_with_baseline(&baseline, b.results(), speedup)?;
    }

    println!("\nperf_hotpath OK");
    Ok(())
}

/// JSON report for the CI perf-smoke artifact.
#[allow(clippy::too_many_arguments)]
fn write_report(
    path: &str,
    results: &[Measurement],
    speedup: f64,
    speedup_workers: usize,
    st: &RuntimeStats,
    round_hits: u64,
    round_misses: u64,
    mk_barrier: f64,
    mk_stream: f64,
    downlink_dense: u64,
    downlink_lean: u64,
) -> Result<()> {
    let benchmarks: Vec<Value> = results
        .iter()
        .map(|m| {
            Value::obj(vec![
                ("name", Value::str(&m.name)),
                ("iters", Value::Num(m.iters as f64)),
                ("mean_ns", Value::Num(m.mean_ns)),
                ("p50_ns", Value::Num(m.p50_ns)),
                ("p95_ns", Value::Num(m.p95_ns)),
                ("std_ns", Value::Num(m.std_ns)),
            ])
        })
        .collect();
    let round_total = (round_hits + round_misses).max(1);
    let report = Value::obj(vec![
        ("schema", Value::str("heron-sfl-bench-v1")),
        ("benchmarks", Value::Arr(benchmarks)),
        ("parallel_speedup", Value::Num(speedup)),
        ("parallel_speedup_workers", Value::Num(speedup_workers as f64)),
        ("feature_cache_hits", Value::Num(st.feature_cache_hits as f64)),
        (
            "feature_cache_misses",
            Value::Num(st.feature_cache_misses as f64),
        ),
        (
            "feature_cache_hit_rate",
            Value::Num(st.feature_cache_hit_rate()),
        ),
        // one steady-state round's hits/(hits+misses), measured in
        // isolation after the timed loop
        (
            "heron_round_cache_hit_rate",
            Value::Num(round_hits as f64 / round_total as f64),
        ),
        (
            "alloc_avoided_bytes",
            Value::Num(st.alloc_avoided_bytes as f64),
        ),
        // event-sim drain-policy comparison for one steady-state round:
        // virtual server completion under the barrier schedule vs
        // arrival-order mid-round consumption (`--drain stream`)
        ("server_makespan_barrier_seconds", Value::Num(mk_barrier)),
        ("server_makespan_stream_seconds", Value::Num(mk_stream)),
        // analytic per-round sync downlink for the bench preset: the
        // dense θ_l broadcast vs the wire v7 seed_agg SeedSync roster
        (
            "downlink_dense_sync_bytes_per_round",
            Value::Num(downlink_dense as f64),
        ),
        (
            "downlink_seed_agg_sync_bytes_per_round",
            Value::Num(downlink_lean as f64),
        ),
    ]);
    std::fs::write(path, report.to_string_pretty())?;
    Ok(())
}

fn bench_mean(report: &Value, name: &str) -> Result<f64> {
    let arr = report
        .get("benchmarks")
        .and_then(Value::as_arr)
        .context("baseline: missing benchmarks array")?;
    for entry in arr {
        if entry.get("name").and_then(Value::as_str) == Some(name) {
            return entry
                .get("mean_ns")
                .and_then(Value::as_f64)
                .with_context(|| format!("baseline: {name} lacks mean_ns"));
        }
    }
    bail!("baseline: no benchmark named {name}")
}

/// Compare this run's `heron_full_round` against the committed baseline,
/// normalizing by the stream-fill canary so the gate is meaningful across
/// hosts of different speeds. Fails on a >25% normalized regression.
fn compare_with_baseline(
    path: &str,
    results: &[Measurement],
    speedup: f64,
) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading baseline {path}"))?;
    let base = json::parse(&text)
        .with_context(|| format!("parsing baseline {path}"))?;
    let base_round = bench_mean(&base, ROUND)?;
    let base_canary = bench_mean(&base, CANARY)?.max(1.0);
    let base_speedup = base
        .get("parallel_speedup")
        .and_then(Value::as_f64)
        .unwrap_or(1.0);
    // A provisional baseline (estimated, not measured — see the file's
    // "note") reports the comparison but never fails the run; the gate
    // arms itself once a measured baseline drops the flag.
    let provisional = base
        .get("provisional")
        .and_then(Value::as_bool)
        .unwrap_or(false);
    let cur = |name: &str| -> Result<f64> {
        results
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.mean_ns)
            .with_context(|| format!("current run lacks benchmark {name}"))
    };
    let cur_round = cur(ROUND)?;
    let cur_canary = cur(CANARY)?.max(1.0);

    let raw_ratio = base_round / cur_round.max(1.0);
    let norm_ratio =
        (base_round / base_canary) / (cur_round / cur_canary).max(1e-12);
    let speedup_delta = speedup - base_speedup;
    println!("\n=== baseline comparison ({path}) ===");
    println!(
        "{ROUND}: baseline {} -> current {}  ({raw_ratio:.2}x raw, \
         {norm_ratio:.2}x canary-normalized)",
        fmt_ns(base_round),
        fmt_ns(cur_round),
    );
    println!(
        "sequential-vs-parallel speedup: baseline {base_speedup:.2}x -> \
         current {speedup:.2}x (delta {speedup_delta:+.2}x)"
    );
    // informational only — the hard ceiling on disabled-telemetry cost is
    // the inline canary-multiple gate above; pre-telemetry baselines
    // simply lack the key
    match bench_mean(&base, TELEMETRY) {
        Ok(base_tel) => {
            let cur_tel = cur(TELEMETRY)?;
            let tel_norm = (base_tel / base_canary)
                / (cur_tel / cur_canary).max(1e-12);
            println!(
                "{TELEMETRY}: baseline {} -> current {} \
                 ({tel_norm:.2}x canary-normalized)",
                fmt_ns(base_tel),
                fmt_ns(cur_tel),
            );
        }
        Err(_) => println!(
            "note: baseline lacks {TELEMETRY} — refresh it with \
             BENCH_OUT={path} cargo bench --bench perf_hotpath to record"
        ),
    }

    if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        if let Ok(mut fh) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(summary)
        {
            let _ = writeln!(
                fh,
                "### perf_hotpath vs `{path}`\n\n\
                 | metric | baseline | current | ratio |\n\
                 |---|---|---|---|\n\
                 | `{ROUND}` mean | {} | {} | {raw_ratio:.2}x raw / {norm_ratio:.2}x normalized |\n\
                 | parallel speedup | {base_speedup:.2}x | {speedup:.2}x | {speedup_delta:+.2}x |\n",
                fmt_ns(base_round),
                fmt_ns(cur_round),
            );
        }
    }

    if norm_ratio < 1.0 / REGRESSION_LIMIT {
        if provisional {
            println!(
                "WARNING: {ROUND} is {:.0}% slower (normalized) than the \
                 provisional baseline — not failing because {path} is \
                 estimated, not measured; refresh it with \
                 BENCH_OUT={path} cargo bench --bench perf_hotpath and \
                 drop its \"provisional\" flag to arm the gate",
                100.0 * (1.0 / norm_ratio - 1.0),
            );
        } else {
            bail!(
                "{ROUND} regressed {:.0}% (normalized) against {path} — \
                 limit is {:.0}%",
                100.0 * (1.0 / norm_ratio - 1.0),
                100.0 * (REGRESSION_LIMIT - 1.0),
            );
        }
    }
    Ok(())
}

fn golden_input_for_bench(
    session: &Session,
    variant: &str,
    spec: &heron_sfl::runtime::manifest::TensorSpec,
    idx: usize,
    task: &str,
) -> Result<heron_sfl::runtime::tensor::TensorValue> {
    // reuse the golden-input construction (deterministic, well-conditioned)
    golden::bench_input(session, variant, spec, idx, task)
}
