//! Paper Table I: client-side resource costs per local update (analytic).
//!
//! Regenerates the symbolic table instantiated with the real model sizes of
//! both task families, and verifies the paper's qualitative orderings:
//! HERON has the smallest memory and the decoupled comm pattern, and its
//! FLOPs sit at 2/3 of the decoupled-FO baselines for two-point probes.

use heron_sfl::bench_harness::Table;
use heron_sfl::coordinator::accounting::{table1_row, CostBook};
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_default()?;

    for variant in ["cnn_c1", "gpt2micro_c2_a1"] {
        let v = manifest.variant(variant)?;
        let mut t = Table::new(&[
            "Method",
            "Comms. per Client",
            "Peak Memory",
            "FLOPs",
        ]);
        for alg in [
            Algorithm::SflV2,
            Algorithm::CseFsl,
            Algorithm::FslSage,
            Algorithm::Heron,
        ] {
            t.row(table1_row(v, alg, 2));
        }
        t.print(&format!(
            "TABLE I — client-side resource costs per local update ({variant})"
        ));

        // qualitative assertions (the paper's ordering claims)
        let heron = CostBook::new(v, Algorithm::Heron, 1);
        let cse = CostBook::new(v, Algorithm::CseFsl, 1);
        let sfl = CostBook::new(v, Algorithm::SflV2, 1);
        assert!(heron.peak_mem_bytes < cse.peak_mem_bytes);
        assert!(heron.peak_mem_bytes < sfl.peak_mem_bytes);
        assert!(heron.flops_per_step < cse.flops_per_step);
        assert!(
            heron.comm_per_step(true) < sfl.comm_per_step(true),
            "decoupled upload must beat two-way exchange"
        );
        let ratio = heron.flops_per_step as f64 / cse.flops_per_step as f64;
        println!(
            "HERON/CSE FLOPs ratio: {ratio:.3} (paper: 2/3 for two-point ZO)"
        );
    }
    println!("\ntable1_costs OK");
    Ok(())
}
