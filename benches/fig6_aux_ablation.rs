//! Paper Fig 6: effect of auxiliary-model complexity on GPT2-micro
//! fine-tuning — final training loss after a fixed round budget for
//! aux ∈ {0..3} transformer blocks under both client partitions
//! (client = 2 or 3 of 6 blocks), HERON-SFL vs CSE-FSL.
//!
//! Expected shape: HERON is largely insensitive to aux capacity (strong
//! even with the minimal LN+unembed aux) while the FO baseline benefits
//! from a bigger aux network.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::experiments::{full_mode, lm_base, run, scaled_rounds};
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(3, 20);

    println!("=== Fig 6 — aux-model complexity ablation (GPT2-micro) ===");
    println!("csv: client_blocks,aux_blocks,algo,final_train_loss");
    let clients: &[usize] = if full_mode() { &[2, 3] } else { &[2] };
    let auxes: &[usize] = if full_mode() { &[0, 1, 2, 3] } else { &[0, 1, 2] };
    for &cb in clients {
        for &ab in auxes {
            let variant = format!("gpt2micro_c{cb}_a{ab}");
            for alg in [Algorithm::Heron, Algorithm::CseFsl] {
                let mut cfg = lm_base(&variant, rounds);
                cfg.algorithm = alg;
                cfg.eval_every = rounds; // final eval only; loss is per-round
                let rec =
                    run(&session, cfg, &format!("{variant}-{}", alg.name()))?;
                let final_loss = rec
                    .rounds
                    .last()
                    .map(|r| r.train_loss)
                    .unwrap_or(f64::NAN);
                println!("{cb},{ab},{},{final_loss:.4}", alg.name());
            }
        }
    }
    println!("\nfig6_aux_ablation OK");
    Ok(())
}
