//! §serve-storm: event-core load benchmark (`cargo bench --bench
//! serve_storm`, also reachable as `heron-sfl bench serve-storm`).
//!
//! Boots the real TCP dispatcher and sweeps the virtual-client count at a
//! fixed socket budget: 16 connections × {1, 4, 64} lanes each, i.e. 16 →
//! 1024 simulated edge devices through the same 16 sockets. Each point
//! runs the storm workload (population 1024, cohort 64 per round, lean
//! `--zo_wire seeds` uploads) to completion and reports rounds/sec plus
//! the p99 per-round latency. The headline point — 1024 virtual clients
//! on 16 sockets — is the tentpole property: client multiplexing through
//! the sharded poll loops, no thread-per-reader.
//!
//! Set `BENCH_OUT=path.json` to merge the results into the shared
//! `heron-sfl-bench-v1` report (perf_hotpath writes the same file; the
//! merge replaces same-name entries and preserves everything else).
//!
//! Set `BENCH_BASELINE=path.json` to gate against a committed baseline:
//! the run fails when `serve_storm_rounds_per_sec`, normalized by the
//! `perturb_stream_fill_64k` machine-speed canary, regresses by more than
//! 25%. A baseline marked `"provisional": true` (or one predating the
//! storm keys) reports the comparison but never fails the run.

use anyhow::{bail, Context, Result};
use heron_sfl::bench_harness::{merge_report, Bench, Table};
use heron_sfl::net::storm::{run_storm, storm_config, StormPoint};
use heron_sfl::runtime::Session;
use heron_sfl::util::json::{self, Value};
use heron_sfl::zo::stream::PerturbStream;

/// Same machine-speed canary as perf_hotpath: untouched by the net/event
/// loop work, so baseline-vs-current ratios of (rounds/sec × canary)
/// cancel host speed.
const CANARY: &str = "perturb_stream_fill_64k";
/// Fail the gate when normalized rounds/sec regresses >25%.
const REGRESSION_LIMIT: f64 = 1.25;
/// Socket budget for the whole sweep — the acceptance bar is ≥1000
/// virtual clients through ≤16 sockets.
const CONNS: usize = 16;
const LANE_SWEEP: [usize; 3] = [1, 4, 64];

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let mut b = Bench::new();

    Bench::header("machine-speed canary");
    let mut buf = vec![0.0f32; 1 << 16];
    b.run(CANARY, || {
        PerturbStream::new(7).fill(&mut buf);
        std::hint::black_box(&buf);
    });
    let canary_ns = b.results().last().unwrap().mean_ns;

    Bench::header(&format!(
        "serve-storm sweep ({CONNS} sockets, population {})",
        storm_config().n_clients
    ));
    let mut points: Vec<StormPoint> = Vec::new();
    for lanes in LANE_SWEEP {
        let p = run_storm(&session, storm_config(), CONNS, lanes)
            .with_context(|| format!("storm point: {CONNS}x{lanes} lanes"))?;
        println!(
            "{:>5} virtual clients / {CONNS} sockets: {:.2} rounds/s, \
             p99 round {:.1} ms, {} lanes complete, {} NACKs",
            p.total_lanes,
            p.rounds_per_sec,
            p.p99_round_seconds * 1e3,
            p.lanes_complete,
            p.nacks,
        );
        points.push(p);
    }

    let mut t = Table::new(&[
        "virtual clients",
        "sockets",
        "rounds/s",
        "mean round (ms)",
        "p99 round (ms)",
        "lanes complete",
        "NACKs",
        "wire MB",
    ]);
    for p in &points {
        t.row(vec![
            p.total_lanes.to_string(),
            p.conns.to_string(),
            format!("{:.2}", p.rounds_per_sec),
            format!("{:.1}", p.mean_round_seconds * 1e3),
            format!("{:.1}", p.p99_round_seconds * 1e3),
            p.lanes_complete.to_string(),
            p.nacks.to_string(),
            format!("{:.2}", p.wire_bytes as f64 / 1e6),
        ]);
    }
    t.print("serve-storm: round throughput vs virtual-client count");

    // headline = the densest point: 1024 virtual clients on 16 sockets
    let head = points.last().expect("sweep is non-empty");
    if head.total_lanes < 1000 {
        bail!(
            "storm sweep topped out at {} virtual clients — the tentpole \
             bar is >=1000 through <={CONNS} sockets",
            head.total_lanes
        );
    }
    println!(
        "\nheadline: {} virtual clients / {} sockets -> {:.2} rounds/s, \
         p99 round {:.1} ms",
        head.total_lanes,
        head.conns,
        head.rounds_per_sec,
        head.p99_round_seconds * 1e3,
    );

    if let Ok(path) = std::env::var("BENCH_OUT") {
        let point_objs: Vec<Value> = points
            .iter()
            .map(|p| {
                Value::obj(vec![
                    ("conns", Value::Num(p.conns as f64)),
                    ("lanes_per_conn", Value::Num(p.lanes_per_conn as f64)),
                    ("total_lanes", Value::Num(p.total_lanes as f64)),
                    ("rounds", Value::Num(p.rounds as f64)),
                    ("wall_seconds", Value::Num(p.wall_seconds)),
                    ("rounds_per_sec", Value::Num(p.rounds_per_sec)),
                    (
                        "mean_round_seconds",
                        Value::Num(p.mean_round_seconds),
                    ),
                    ("p99_round_seconds", Value::Num(p.p99_round_seconds)),
                    ("lanes_complete", Value::Num(p.lanes_complete as f64)),
                    ("nacks", Value::Num(p.nacks as f64)),
                    ("wire_bytes", Value::Num(p.wire_bytes as f64)),
                ])
            })
            .collect();
        merge_report(
            &path,
            b.results(),
            &[
                (
                    "serve_storm_rounds_per_sec",
                    Value::Num(head.rounds_per_sec),
                ),
                (
                    "serve_storm_p99_round_latency_seconds",
                    Value::Num(head.p99_round_seconds),
                ),
                (
                    "serve_storm_virtual_clients",
                    Value::Num(head.total_lanes as f64),
                ),
                ("serve_storm_conns", Value::Num(head.conns as f64)),
                ("serve_storm_points", Value::Arr(point_objs)),
            ],
        )?;
        println!("merged storm results into {path}");
    }

    if let Ok(baseline) = std::env::var("BENCH_BASELINE") {
        compare_with_baseline(&baseline, head, canary_ns)?;
    }

    println!("\nserve_storm OK");
    Ok(())
}

/// Gate the headline rounds/sec against the committed baseline. The
/// metric is higher-is-better, so the normalized score is
/// `rounds_per_sec × canary_mean_ns` (a slower host has a bigger canary
/// and a smaller rounds/sec — the product cancels machine speed) and the
/// run fails when `current/baseline` drops below `1/REGRESSION_LIMIT`.
fn compare_with_baseline(
    path: &str,
    head: &StormPoint,
    cur_canary_ns: f64,
) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading baseline {path}"))?;
    let base = json::parse(&text)
        .with_context(|| format!("parsing baseline {path}"))?;
    let provisional = base
        .get("provisional")
        .and_then(Value::as_bool)
        .unwrap_or(false);
    let base_rps = base
        .get("serve_storm_rounds_per_sec")
        .and_then(Value::as_f64);
    let base_canary = base
        .get("benchmarks")
        .and_then(Value::as_arr)
        .and_then(|arr| {
            arr.iter().find(|e| {
                e.get("name").and_then(Value::as_str) == Some(CANARY)
            })
        })
        .and_then(|e| e.get("mean_ns"))
        .and_then(Value::as_f64);
    let (Some(base_rps), Some(base_canary)) = (base_rps, base_canary) else {
        println!(
            "\nbaseline {path} has no serve_storm keys (predates the storm \
             bench) — skipping the storm gate; refresh it via the \
             record-baseline workflow to arm this gate"
        );
        return Ok(());
    };

    let ratio = (head.rounds_per_sec * cur_canary_ns)
        / (base_rps * base_canary.max(1.0)).max(1e-12);
    println!("\n=== storm baseline comparison ({path}) ===");
    println!(
        "serve_storm_rounds_per_sec: baseline {base_rps:.2} -> current \
         {:.2}  ({ratio:.2}x canary-normalized; >1 is faster)",
        head.rounds_per_sec,
    );

    if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        if let Ok(mut fh) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(summary)
        {
            let _ = writeln!(
                fh,
                "### serve_storm vs `{path}`\n\n\
                 | metric | baseline | current | ratio |\n\
                 |---|---|---|---|\n\
                 | rounds/s ({} virtual clients) | {base_rps:.2} | {:.2} | {ratio:.2}x normalized |\n\
                 | p99 round latency | — | {:.1} ms | — |\n",
                head.total_lanes,
                head.rounds_per_sec,
                head.p99_round_seconds * 1e3,
            );
        }
    }

    if ratio < 1.0 / REGRESSION_LIMIT {
        if provisional {
            println!(
                "WARNING: storm throughput is {:.0}% below the provisional \
                 baseline — not failing because {path} is estimated, not \
                 measured; refresh via the record-baseline workflow to arm \
                 the gate",
                100.0 * (1.0 / ratio - 1.0),
            );
        } else {
            bail!(
                "serve_storm_rounds_per_sec regressed {:.0}% (normalized) \
                 against {path} — limit is {:.0}%",
                100.0 * (1.0 / ratio - 1.0),
                100.0 * (REGRESSION_LIMIT - 1.0),
            );
        }
    }
    Ok(())
}
