//! Paper Fig 2: MiniResNet test accuracy vs communication rounds, IID and
//! non-IID (Dirichlet 0.5), all five algorithms.
//!
//! Smoke mode runs a few rounds per setting; REPRO_FULL=1 widens the budget
//! so the parity shape (HERON ~ CSE-FSL ~ FSL-SAGE, slightly below SFLV2)
//! becomes visible. Series print as CSV so curves can be replotted.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::data::partition::Scheme;
use heron_sfl::experiments::{curve_summary, run, scaled_rounds, vision_base};
use heron_sfl::metrics::sparkline;
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(6, 60);

    for (setting, scheme) in [
        ("IID", Scheme::Iid),
        ("non-IID (Dirichlet 0.5)", Scheme::Dirichlet { alpha: 0.5 }),
    ] {
        println!("\n=== Fig 2 ({setting}) — accuracy vs rounds ===");
        println!("series CSV: algo,round,accuracy");
        for alg in Algorithm::all() {
            let mut cfg = vision_base(rounds);
            cfg.algorithm = alg;
            cfg.scheme = scheme;
            let rec = run(&session, cfg, alg.name())?;
            for r in &rec.rounds {
                if r.eval_metric.is_finite() {
                    println!(
                        "{},{},{:.4}",
                        alg.name(),
                        r.round,
                        r.eval_metric
                    );
                }
            }
            let accs: Vec<f64> = rec
                .rounds
                .iter()
                .filter(|r| r.eval_metric.is_finite())
                .map(|r| r.eval_metric)
                .collect();
            println!(
                "# {:<10} {} {}",
                alg.name(),
                sparkline(&accs, 40),
                curve_summary(&rec, true)
            );
        }
    }
    println!("\nfig2_convergence OK");
    Ok(())
}
