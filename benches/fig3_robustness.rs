//! Paper Fig 3: robustness of HERON-SFL vs its FO counterpart under
//! (a) data heterogeneity — Dirichlet alpha sweep,
//! (b) client scalability — total client count sweep,
//! (c) partial participation — per-round participation fraction sweep.
//!
//! Each sub-figure prints a CSV series (setting,algo,value,accuracy).

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::data::partition::Scheme;
use heron_sfl::experiments::{run, scaled_rounds, vision_base};
use heron_sfl::runtime::Session;

fn main() -> anyhow::Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds = scaled_rounds(5, 40);
    let full = heron_sfl::experiments::full_mode();
    let algos = [Algorithm::Heron, Algorithm::CseFsl];

    // --- (a) Dirichlet alpha sweep (10 clients full participation) -------
    println!("=== Fig 3a — heterogeneity (Dirichlet alpha) ===");
    println!("csv: alpha,algo,best_acc");
    let alphas: &[f64] = if full {
        &[0.1, 0.3, 0.5, 1.0, 10.0]
    } else {
        &[0.1, 1.0]
    };
    for &alpha in alphas {
        for alg in algos {
            let mut cfg = vision_base(rounds);
            cfg.algorithm = alg;
            cfg.n_clients = 10;
            cfg.scheme = Scheme::Dirichlet { alpha };
            cfg.eval_every = rounds; // final eval
            let rec = run(&session, cfg, &format!("a{alpha}-{}", alg.name()))?;
            println!(
                "{alpha},{},{:.4}",
                alg.name(),
                rec.best_metric(true).unwrap_or(0.0)
            );
        }
    }

    // --- (b) client-count sweep (IID, full participation) ----------------
    println!("\n=== Fig 3b — scalability (total clients) ===");
    println!("csv: n_clients,algo,best_acc");
    let counts: &[usize] = if full { &[10, 30, 50, 100] } else { &[5, 20] };
    for &n in counts {
        for alg in algos {
            let mut cfg = vision_base(rounds);
            cfg.algorithm = alg;
            cfg.n_clients = n;
            cfg.dataset_size = (n as u64) * 400;
            cfg.eval_every = rounds;
            let rec = run(&session, cfg, &format!("n{n}-{}", alg.name()))?;
            println!(
                "{n},{},{:.4}",
                alg.name(),
                rec.best_metric(true).unwrap_or(0.0)
            );
        }
    }

    // --- (c) participation-fraction sweep (10 IID clients) ---------------
    println!("\n=== Fig 3c — partial participation ===");
    println!("csv: fraction,algo,best_acc");
    let fracs: &[f64] = if full {
        &[0.1, 0.2, 0.5, 0.8, 1.0]
    } else {
        &[0.2, 1.0]
    };
    for &f in fracs {
        for alg in algos {
            let mut cfg = vision_base(rounds);
            cfg.algorithm = alg;
            cfg.n_clients = 10;
            cfg.participation = f;
            cfg.eval_every = rounds;
            let rec = run(&session, cfg, &format!("p{f}-{}", alg.name()))?;
            println!(
                "{f},{},{:.4}",
                alg.name(),
                rec.best_metric(true).unwrap_or(0.0)
            );
        }
    }

    println!("\nfig3_robustness OK");
    Ok(())
}
