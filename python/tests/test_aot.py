"""AOT pipeline checks: variant registry sanity and (when built) manifest
consistency with the live model definitions."""

import json
import os

import numpy as np
import pytest

from compile import synth, variants
from compile.aot import build_model, summarize
from compile.entries import CORE_ENTRIES, FULL_ENTRIES, build_entries

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../artifacts")
MANIFEST = os.path.join(ARTIFACTS, "manifest.json")


class TestVariantRegistry:
    def test_names_unique(self):
        names = [v.name for v in variants.VARIANTS]
        assert len(names) == len(set(names))

    def test_get_known_and_unknown(self):
        assert variants.get("cnn_c1").family == "cnn"
        with pytest.raises(KeyError):
            variants.get("nope")

    def test_entry_lists_are_known(self):
        for v in variants.VARIANTS:
            for e in v.entries:
                assert e in FULL_ENTRIES, f"{v.name}: unknown entry {e}"

    def test_core_is_subset_of_full(self):
        assert set(CORE_ENTRIES) <= set(FULL_ENTRIES)

    @pytest.mark.parametrize("name", ["cnn_c1", "gpt2nano_c1_a1",
                                      "gpt2micro_c3_a2"])
    def test_models_build_and_entries_construct(self, name):
        v = variants.get(name)
        model = build_model(v)
        entries = build_entries(model, v.optimizer, which=v.entries)
        assert set(entries) == set(v.entries)
        for e in entries.values():
            names = [n for n, _, _ in e.inputs]
            assert len(names) == len(set(names)), f"dup inputs in {e.name}"

    def test_heron_runnable_everywhere(self):
        need = {"zo_step", "client_fwd", "server_step", "eval_full"}
        for v in variants.VARIANTS:
            if v.name.endswith("_pallas"):
                continue
            assert need <= set(v.entries), v.name


class TestSummarize:
    def test_summary_fields(self):
        s = summarize(np.arange(6, dtype=np.float32).reshape(2, 3))
        assert s["shape"] == [2, 3]
        assert s["head"] == [0.0, 1.0, 2.0, 3.0]
        assert s["sum"] == 15.0
        assert abs(s["l2"] - np.sqrt(55)) < 1e-9


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run make artifacts")
class TestManifestConsistency:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(MANIFEST) as f:
            return json.load(f)

    def test_every_variant_present(self, manifest):
        for v in variants.VARIANTS:
            assert v.name in manifest["variants"], v.name

    def test_sizes_match_live_models(self, manifest):
        for name in ["cnn_c1", "cnn_c2", "gpt2nano_c1_a1",
                     "gpt2micro_c2_a3"]:
            v = variants.get(name)
            model = build_model(v)
            m = manifest["variants"][name]
            assert m["sizes"]["client"] == model.spec_client.size
            assert m["sizes"]["aux"] == model.spec_aux.size
            assert m["sizes"]["server"] == model.spec_server.size

    def test_hlo_files_exist(self, manifest):
        for name, mv in manifest["variants"].items():
            for ename, e in mv["entries"].items():
                p = os.path.join(ARTIFACTS, name, e["file"])
                assert os.path.exists(p), f"{name}/{ename}"
                # HLO text sanity: must contain an entry computation
                with open(p) as f:
                    head = f.read(4096)
                assert "HloModule" in head, f"{name}/{ename}"

    def test_blobs_match_sizes(self, manifest):
        for name, mv in manifest["variants"].items():
            d = os.path.join(ARTIFACTS, name)
            nl = mv["sizes"]["client"] + mv["sizes"]["aux"]
            init_l = np.fromfile(
                os.path.join(d, mv["files"]["init_theta_l"]), dtype="<f4"
            )
            assert init_l.size == nl, name
            if mv["sizes"]["base"]:
                base = np.fromfile(
                    os.path.join(d, mv["files"]["frozen_base"]), dtype="<f4"
                )
                assert base.size == mv["sizes"]["base"], name
                assert np.isfinite(base).all(), name

    def test_synth_goldens_reproduce(self, manifest):
        g = manifest["synth"]
        assert g["vision_labels_seed42"] == [
            synth.vision_label(42, i) for i in range(32)
        ]
        assert g["text_record0"] == synth.e2e_record(42, 0)
        img = synth.vision_image(42, 0)
        assert abs(g["vision_img0_sum"] - float(img.sum())) < 1e-4
