"""L2 model-family checks: shapes, gradients, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import synth
from compile.models import cnn, transformer


@pytest.fixture(scope="module")
def cnn_model():
    return cnn.build(cut=1, batch=8, eval_batch=16)


@pytest.fixture(scope="module")
def cnn_params(cnn_model):
    rng = np.random.default_rng(1)
    return cnn_model.init(rng)


class TestCnn:
    def test_spec_sizes_positive(self, cnn_model):
        assert cnn_model.spec_client.size > 1000
        assert cnn_model.spec_aux.size == 16 * 10 + 10
        assert cnn_model.spec_server.size > 10000

    def test_forward_shapes(self, cnn_model, cnn_params):
        tc, ta, ts = cnn_params
        x = jnp.asarray(synth.vision_batch(0, 0, 8)[0])
        sm = cnn_model.client_fwd(tc, x)
        assert sm.shape == (8, 16, 16, 16)
        la = cnn_model.aux_fwd(ta, sm)
        assert la.shape == (8, 10)
        ls = cnn_model.server_fwd(ts, sm)
        assert ls.shape == (8, 10)

    def test_cut2_smashed_shape(self):
        m = cnn.build(cut=2, batch=4)
        tc, _, _ = m.init(np.random.default_rng(0))
        x = jnp.asarray(synth.vision_batch(0, 0, 4)[0])
        assert m.client_fwd(tc, x).shape == (4, 8, 8, 32)

    def test_loss_at_init_near_log10(self, cnn_model, cnn_params):
        tc, ta, ts = cnn_params
        x, y = synth.vision_batch(0, 0, 8)
        sm = cnn_model.client_fwd(tc, jnp.asarray(x))
        loss = cnn_model.loss(cnn_model.server_fwd(ts, sm), jnp.asarray(y))
        assert abs(float(loss) - np.log(10)) < 0.7

    def test_gradients_finite_and_nonzero(self, cnn_model, cnn_params):
        tc, ta, ts = cnn_params
        x, y = synth.vision_batch(0, 0, 8)
        x, y = jnp.asarray(x), jnp.asarray(y)

        def loss_fn(tc):
            sm = cnn_model.client_fwd(tc, x)
            return cnn_model.loss(cnn_model.aux_fwd(ta, sm), y)

        g = jax.grad(loss_fn)(tc)
        flat = jnp.concatenate([jnp.ravel(v) for v in g.values()])
        assert bool(jnp.isfinite(flat).all())
        assert float(jnp.abs(flat).max()) > 0

    def test_few_fo_steps_reduce_loss(self, cnn_model, cnn_params):
        tc, ta, ts = [dict(t) for t in cnn_params]
        x, y = synth.vision_batch(0, 0, 8)
        x, y = jnp.asarray(x), jnp.asarray(y)

        def loss_fn(params):
            tc, ta = params
            sm = cnn_model.client_fwd(tc, x)
            return cnn_model.loss(cnn_model.aux_fwd(ta, sm), y)

        params = (tc, ta)
        l0 = float(loss_fn(params))
        vg = jax.jit(jax.value_and_grad(loss_fn))
        for _ in range(20):
            l, g = vg(params)
            params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        assert float(l) < l0 - 0.1

    def test_metric_counts_correct(self, cnn_model):
        logits = jnp.asarray(np.eye(10, dtype=np.float32)[:8] * 5)
        y = jnp.arange(8, dtype=jnp.int32)
        assert float(cnn_model.metric(logits, y)) == 8.0
        y_bad = (y + 1) % 10
        assert float(cnn_model.metric(logits, y_bad)) == 0.0

    def test_cost_model_consistency(self, cnn_model):
        c = cnn_model.cost
        assert c.params_client == cnn_model.spec_client.size
        assert c.params_server == cnn_model.spec_server.size
        assert c.flops_fwd_server > c.flops_fwd_client > c.flops_fwd_aux > 0
        assert c.act_cache_client > c.act_peak_client > 0
        assert c.smashed_elems == 16 * 16 * 16


class TestTransformer:
    @pytest.fixture(scope="class")
    def nano(self):
        return transformer.build(transformer.NANO, 1, 1, batch=2, eval_batch=2)

    @pytest.fixture(scope="class")
    def base_vec_tree(self, nano):
        rng = np.random.default_rng(3)
        base = transformer.init_base(transformer.NANO, rng)
        full = transformer.attach_aux_base(base, transformer.NANO, 1, 1)
        return {k: jnp.asarray(v) for k, v in full.items()}

    def test_lora_specs(self, nano):
        d, r = 64, 4
        assert nano.spec_client.size == 4 * d * r  # q.A q.B v.A v.B
        assert nano.spec_aux.size == 4 * d * r + 2 * d

    def test_forward_shapes(self, nano, base_vec_tree):
        tc, ta, ts = nano.init(np.random.default_rng(0))
        tc = {k: jnp.asarray(v) for k, v in tc.items()}
        toks = jnp.asarray(synth.text_batch(0, 0, 2))
        sm = nano.client_fwd(tc, toks, base_vec_tree)
        assert sm.shape == (2, synth.SEQ_LEN, 64)
        la = nano.aux_fwd({k: jnp.asarray(v) for k, v in ta.items()}, sm,
                          base_vec_tree)
        assert la.shape == (2, synth.SEQ_LEN, synth.VOCAB)

    def test_zero_lora_b_matches_frozen(self, nano, base_vec_tree):
        """LoRA init (B=0) must not change the base forward."""
        tc, _, _ = nano.init(np.random.default_rng(0))
        tc = {k: jnp.asarray(v) for k, v in tc.items()}
        toks = jnp.asarray(synth.text_batch(0, 0, 2))
        sm_lora = nano.client_fwd(tc, toks, base_vec_tree)
        h = transformer.embed(base_vec_tree, toks, transformer.NANO)
        h = transformer.block_fwd(
            base_vec_tree, None, "blk0", transformer.NANO, h, False
        )
        np.testing.assert_allclose(sm_lora, h, rtol=1e-5, atol=1e-5)

    def test_loss_masked_by_pad(self, nano):
        logits = jnp.zeros((1, synth.SEQ_LEN, synth.VOCAB))
        y = jnp.zeros((1, synth.SEQ_LEN), jnp.int32)  # all PAD
        y = y.at[0, :4].set(5)
        loss = nano.loss(logits, y)
        # uniform logits -> CE = log(vocab) over the 3 valid targets
        assert abs(float(loss) - np.log(synth.VOCAB)) < 1e-4

    def test_pretrain_reduces_loss(self):
        base0 = transformer.init_base(
            transformer.NANO, np.random.default_rng(7)
        )
        b0 = {k: jnp.asarray(v) for k, v in base0.items()}
        toks = jnp.asarray(synth.text_batch(0xE2E0 + 7, 0, 8))

        def eval_loss(base):
            logits = transformer.full_fwd(base, toks, transformer.NANO)
            lp = jax.nn.log_softmax(logits[:, :-1], -1)
            tgt = toks[:, 1:]
            nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
            mask = (tgt != synth.PAD).astype(jnp.float32)
            return float(jnp.sum(nll * mask) / jnp.sum(mask))

        l0 = eval_loss(b0)
        base, _ = transformer.pretrain(transformer.NANO, steps=25, seed=7)
        l1 = eval_loss({k: jnp.asarray(v) for k, v in base.items()})
        assert l1 < l0 - 0.5

    def test_aux_base_copied_from_server(self):
        base = transformer.init_base(transformer.NANO, np.random.default_rng(1))
        full = transformer.attach_aux_base(base, transformer.NANO, 1, 2)
        assert (full["aux0.q.w"] == base["blk1.q.w"]).all()
        assert (full["aux1.q.w"] == base["blk2.q.w"]).all()
        assert (full["auxlnf.g"] == base["lnf.g"]).all()

    def test_cost_model_scales_with_blocks(self):
        m2 = transformer.build(transformer.MICRO, 2, 1)
        m3 = transformer.build(transformer.MICRO, 3, 1)
        assert m3.cost.flops_fwd_client > m2.cost.flops_fwd_client
        assert m3.cost.flops_fwd_server < m2.cost.flops_fwd_server
        assert m3.cost.params_client == m3.spec_client.size
