"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and block configurations; the perturbation stream
is additionally checked for bit-exactness between the per-tile kernel
generation and the flat oracle generation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lora_linear import lora_linear
from compile.kernels.perturb import fold_seed, hash_u32, perturbation
from compile.kernels.zo_linear import zo_perturbed_linear, vmem_bytes

settings.register_profile("kernels", deadline=None, max_examples=20)
settings.load_profile("kernels")


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# perturbation stream
# ---------------------------------------------------------------------------


class TestPerturbStream:
    def test_deterministic(self):
        a = perturbation(123, 256)
        b = perturbation(123, 256)
        assert (np.asarray(a) == np.asarray(b)).all()

    def test_seed_sensitivity(self):
        a = np.asarray(perturbation(123, 256))
        b = np.asarray(perturbation(124, 256))
        assert np.abs(a - b).max() > 0.5

    def test_moments(self):
        u = np.asarray(perturbation(7, 1 << 16), dtype=np.float64)
        assert abs(u.mean()) < 0.02
        assert abs(u.std() - 1.0) < 0.02
        # Irwin-Hall(4) support is bounded: |u| <= 2*sqrt(3)
        assert np.abs(u).max() <= 2 * np.sqrt(3) + 1e-6

    def test_hash_avalanche(self):
        h0 = int(hash_u32(jnp.uint32(1), jnp.uint32(0)))
        h1 = int(hash_u32(jnp.uint32(1), jnp.uint32(1)))
        assert bin(h0 ^ h1).count("1") > 8

    def test_fold_seed_independence(self):
        s = jnp.uint32(99)
        u0 = np.asarray(perturbation(fold_seed(s, 0), 4096), np.float64)
        u1 = np.asarray(perturbation(fold_seed(s, 1), 4096), np.float64)
        corr = np.corrcoef(u0, u1)[0, 1]
        assert abs(corr) < 0.05

    def test_gauss_prefix_stability(self):
        """Stream element i does not depend on the vector length."""
        long = np.asarray(perturbation(5, 512))
        short = np.asarray(perturbation(5, 64))
        assert (long[:64] == short).all()


# ---------------------------------------------------------------------------
# zo_perturbed_linear
# ---------------------------------------------------------------------------


class TestZoLinear:
    @given(
        m=st.sampled_from([1, 4, 8]),
        k=st.sampled_from([16, 32, 64]),
        n=st.sampled_from([8, 16, 48]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle(self, m, k, n, seed):
        x = rand(1, m, k)
        w = rand(2, k, n)
        out = zo_perturbed_linear(x, w, seed, 0.01)
        exp = ref.zo_perturbed_linear_ref(x, w, seed, 0.01)
        np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("bm,bn,bk", [(4, 8, 16), (8, 16, 32), (2, 4, 8)])
    def test_block_shapes_equivalent(self, bm, bn, bk):
        """Tiling must not change the generated U (flat-index addressing)."""
        x = rand(3, 8, 32)
        w = rand(4, 32, 16)
        full = zo_perturbed_linear(x, w, 42, 0.5, bm=8, bn=16, bk=32)
        tiled = zo_perturbed_linear(x, w, 42, 0.5, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(full, tiled, rtol=2e-5, atol=2e-5)

    def test_mu_zero_is_plain_matmul(self):
        x = rand(5, 4, 16)
        w = rand(6, 16, 8)
        out = zo_perturbed_linear(x, w, 9, 0.0)
        np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-6)

    def test_perturbation_scales_linearly(self):
        x = rand(7, 4, 16)
        w = jnp.zeros((16, 8))
        o1 = np.asarray(zo_perturbed_linear(x, w, 11, 1.0))
        o2 = np.asarray(zo_perturbed_linear(x, w, 11, 2.0))
        np.testing.assert_allclose(o2, 2 * o1, rtol=1e-4, atol=1e-5)

    def test_vmem_estimate_monotone(self):
        assert vmem_bytes(128, 128, 128) > vmem_bytes(64, 64, 64)


# ---------------------------------------------------------------------------
# lora_linear
# ---------------------------------------------------------------------------


class TestLoraLinear:
    @given(
        m=st.sampled_from([2, 8]),
        k=st.sampled_from([16, 64]),
        n=st.sampled_from([16, 32]),
        r=st.sampled_from([2, 4, 8]),
    )
    def test_matches_oracle(self, m, k, n, r):
        x = rand(1, m, k)
        w = rand(2, k, n)
        a = rand(3, k, r, scale=0.1)
        b = rand(4, r, n, scale=0.1)
        out = lora_linear(x, w, a, b, 2.0)
        exp = ref.lora_linear_ref(x, w, a, b, 2.0)
        np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)

    def test_zero_adapter_is_identity(self):
        x = rand(5, 4, 32)
        w = rand(6, 32, 16)
        a = jnp.zeros((32, 4))
        b = jnp.zeros((4, 16))
        out = lora_linear(x, w, a, b, 8.0)
        np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-6)

    def test_scale_applies_to_adapter_only(self):
        x = rand(7, 4, 16)
        w = jnp.zeros((16, 8))
        a = rand(8, 16, 4, scale=0.2)
        b = rand(9, 4, 8, scale=0.2)
        o1 = np.asarray(lora_linear(x, w, a, b, 1.0))
        o3 = np.asarray(lora_linear(x, w, a, b, 3.0))
        np.testing.assert_allclose(o3, 3 * o1, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("bk", [8, 16, 32])
    def test_k_tiling_equivalent(self, bk):
        x = rand(1, 4, 32)
        w = rand(2, 32, 16)
        a = rand(3, 32, 4, scale=0.1)
        b = rand(4, 4, 16, scale=0.1)
        full = lora_linear(x, w, a, b, 2.0, bk=32)
        tiled = lora_linear(x, w, a, b, 2.0, bk=bk)
        np.testing.assert_allclose(full, tiled, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ZO estimator sanity (reference-level)
# ---------------------------------------------------------------------------


class TestZoEstimator:
    def test_zo_grad_points_downhill_quadratic(self):
        """On f(x) = ||x||^2/2 the ZO estimate correlates with x."""
        theta = jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)
        f = lambda t: 0.5 * jnp.sum(t * t)
        dots = []
        for s in range(50):
            g, _ = ref.zo_grad_ref(f, theta, s, 1e-3)
            dots.append(float(jnp.dot(g, theta)))
        assert np.mean(dots) > 0  # E[g] ~ grad = theta

    def test_zo_grad_unbiasedness(self):
        """Averaged ZO estimates approach the true gradient direction."""
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal(32), jnp.float32)
        theta = jnp.asarray(rng.standard_normal(32), jnp.float32)
        f = lambda t: jnp.dot(a, t)  # linear: grad == a exactly
        acc = np.zeros(32)
        n = 400
        for s in range(n):
            g, _ = ref.zo_grad_ref(f, theta, s, 1e-2)
            acc += np.asarray(g)
        est = acc / n
        cos = est @ np.asarray(a) / (
            np.linalg.norm(est) * np.linalg.norm(a)
        )
        assert cos > 0.8
