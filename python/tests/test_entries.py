"""Entry-point semantics: the protocol surface Rust drives.

These run the jitted entry functions directly (not through HLO text) and
check the optimization semantics each SFL algorithm relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import synth, variants
from compile.aot import build_model, golden_input
from compile.entries import build_entries


@pytest.fixture(scope="module")
def cnn_setup():
    v = variants.get("cnn_c1")
    model = build_model(v)
    entries = build_entries(model, "adam")
    return model, entries


def make_args(model, e, overrides=None):
    args = []
    for idx, (nm, s, d) in enumerate(e.inputs):
        if overrides and nm in overrides:
            args.append(overrides[nm])
        else:
            args.append(golden_input(model, nm, s, d, 101 + idx * 13))
    return args


def zeros_opt(model, dim):
    return {
        "opt_m": jnp.zeros((dim,), jnp.float32),
        "opt_v": jnp.zeros((dim,), jnp.float32),
        "opt_t": jnp.asarray(0.0, jnp.float32),
    }


class TestZoStep:
    def test_changes_params_and_returns_loss(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["zo_step"]
        nl = model.spec_client.size + model.spec_aux.size
        args = make_args(model, e, zeros_opt(model, nl))
        outs = jax.jit(e.fn)(*args)
        theta2, loss = outs[0], outs[-1]
        assert theta2.shape == (nl,)
        assert float(jnp.abs(theta2 - args[0]).max()) > 0
        assert 1.0 < float(loss) < 4.0

    def test_deterministic_given_seed(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["zo_step"]
        nl = model.spec_client.size + model.spec_aux.size
        args = make_args(model, e, zeros_opt(model, nl))
        o1 = jax.jit(e.fn)(*args)
        o2 = jax.jit(e.fn)(*args)
        assert (np.asarray(o1[0]) == np.asarray(o2[0])).all()

    def test_seed_changes_update(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["zo_step"]
        nl = model.spec_client.size + model.spec_aux.size
        base = zeros_opt(model, nl)
        a1 = make_args(model, e, {**base, "seed": jnp.asarray(1, jnp.int32)})
        a2 = make_args(model, e, {**base, "seed": jnp.asarray(2, jnp.int32)})
        o1 = jax.jit(e.fn)(*a1)
        o2 = jax.jit(e.fn)(*a2)
        assert float(jnp.abs(o1[0] - o2[0]).max()) > 0

    def test_n_pert_is_dynamic(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["zo_step"]
        nl = model.spec_client.size + model.spec_aux.size
        base = zeros_opt(model, nl)
        fn = jax.jit(e.fn)
        outs = {}
        for n in (1, 2, 4):
            a = make_args(
                model, e, {**base, "n_pert": jnp.asarray(n, jnp.int32)}
            )
            outs[n] = np.asarray(fn(*a)[0])
        assert np.abs(outs[1] - outs[2]).max() > 0
        assert np.abs(outs[2] - outs[4]).max() > 0

    def test_zo_direction_correlates_with_fo(self):
        """Averaged over seeds, raw (SGD) ZO deltas should point like the FO
        delta. Expected cosine after N probes in dimension d is ~sqrt(N/d);
        with N=150, d~5.3k that is ~0.17, so 0.08 is a robust floor.
        (The Adam variant sign-normalizes updates, which destroys this
        signal — hence the SGD entries here.)"""
        v = variants.get("cnn_c1_sgd")
        model = build_model(v)
        entries = build_entries(model, "sgd", which=["zo_step", "fo_step"])
        ez, ef = entries["zo_step"], entries["fo_step"]
        fo_args = make_args(model, ef)
        fo_delta = np.asarray(jax.jit(ef.fn)(*fo_args)[0] - fo_args[0])
        zfn = jax.jit(ez.fn)
        acc = np.zeros(fo_delta.size)
        for s in range(150):
            a = make_args(
                model, ez, {"seed": jnp.asarray(1000 + s, jnp.int32)}
            )
            acc += np.asarray(zfn(*a)[0] - a[0])
        cos = acc @ fo_delta / (
            np.linalg.norm(acc) * np.linalg.norm(fo_delta) + 1e-12
        )
        assert cos > 0.08


class TestFoAndServer:
    def test_fo_step_reduces_loss_iterated(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["fo_step"]
        nl = model.spec_client.size + model.spec_aux.size
        ov = zeros_opt(model, nl)
        ov["lr"] = jnp.asarray(3e-3, jnp.float32)
        args = make_args(model, e, ov)
        fn = jax.jit(e.fn)
        losses = []
        for _ in range(30):
            out = fn(*args)
            losses.append(float(out[-1]))
            args[0], args[1], args[2], args[3] = out[0], out[1], out[2], out[3]
        assert losses[-1] < losses[0] - 0.1

    def test_server_step_reduces_loss_iterated(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["server_step"]
        ns = model.spec_server.size
        ov = zeros_opt(model, ns)
        ov["lr"] = jnp.asarray(3e-3, jnp.float32)
        args = make_args(model, e, ov)
        fn = jax.jit(e.fn)
        losses = []
        for _ in range(30):
            out = fn(*args)
            losses.append(float(out[-1]))
            args[0], args[1], args[2], args[3] = out[0], out[1], out[2], out[3]
        assert losses[-1] < losses[0] - 0.1

    def test_cutgrad_matches_server_step_params(self, cnn_setup):
        model, entries = cnn_setup
        e1, e2 = entries["server_step"], entries["server_step_cutgrad"]
        ns = model.spec_server.size
        ov = zeros_opt(model, ns)
        a1 = make_args(model, e1, dict(ov))
        a2 = make_args(model, e2, dict(ov))
        o1 = jax.jit(e1.fn)(*a1)
        o2 = jax.jit(e2.fn)(*a2)
        np.testing.assert_allclose(o1[0], o2[0], rtol=1e-6, atol=1e-7)
        g_sm = o2[-1]
        assert g_sm.shape[0] == model.batch
        assert float(jnp.abs(g_sm).max()) > 0

    def test_client_bp_step_moves_toward_cut_gradient(self, cnn_setup):
        """bp step with the true cut gradient reduces the full local loss
        computed through the server path."""
        model, entries = cnn_setup
        ecut = entries["server_step_cutgrad"]
        ebp = entries["client_bp_step"]
        ns, nc = model.spec_server.size, model.spec_client.size
        a_cut = make_args(model, ecut, zeros_opt(model, ns))
        g_sm = jax.jit(ecut.fn)(*a_cut)[-1]
        ov = zeros_opt(model, nc)
        ov["g_smashed"] = g_sm
        a_bp = make_args(model, ebp, ov)
        out = jax.jit(ebp.fn)(*a_bp)
        assert float(jnp.abs(out[0] - a_bp[0]).max()) > 0


class TestEvalAndDiagnostics:
    def test_eval_stats_bounds(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["eval_full"]
        args = make_args(model, e)
        s1, s2 = jax.jit(e.fn)(*args)
        assert 0 <= float(s1) <= float(s2) == model.eval_batch

    def test_hvp_linear_in_v(self, cnn_setup):
        model, entries = cnn_setup
        e = entries["hvp"]
        args = make_args(model, e)
        fn = jax.jit(e.fn)
        v = args[-1]
        h1 = np.asarray(fn(*args)[0])
        args2 = args[:-1] + [2.0 * v]
        h2 = np.asarray(fn(*args2)[0])
        np.testing.assert_allclose(h2, 2 * h1, rtol=1e-3, atol=1e-5)

    def test_hvp_symmetry(self, cnn_setup):
        """v^T H w == w^T H v (Hessian symmetry through the entry)."""
        model, entries = cnn_setup
        e = entries["hvp"]
        args = make_args(model, e)
        nl = model.spec_client.size + model.spec_aux.size
        v = jnp.asarray(synth.golden_vec(nl, 7))
        w = jnp.asarray(synth.golden_vec(nl, 19))
        fn = jax.jit(e.fn)
        hv = np.asarray(fn(*args[:-1], v)[0])
        hw = np.asarray(fn(*args[:-1], w)[0])
        lhs = float(np.asarray(w, np.float64) @ hv)
        rhs = float(np.asarray(v, np.float64) @ hw)
        assert abs(lhs - rhs) < 1e-3 * max(abs(lhs), abs(rhs), 1e-6)

    def test_aux_align_improves_gradient_cosine(self, cnn_setup):
        """Align steps against the *true* server cut-gradient must raise the
        per-sample cosine between aux and server cut-gradients."""
        model, entries = cnn_setup
        ecut = entries["server_step_cutgrad"]
        ns = model.spec_server.size
        a_cut = make_args(model, ecut, zeros_opt(model, ns))
        g_sm = jax.jit(ecut.fn)(*a_cut)[-1]

        e = entries["aux_align"]
        fn = jax.jit(e.fn)
        args = make_args(
            model, e,
            {"g_smashed": g_sm, "lr": jnp.asarray(0.5, jnp.float32)},
        )
        nc = model.spec_client.size

        def mean_cos(theta_l):
            sm, y = args[1], args[2]
            pa = model.spec_aux.unpack(theta_l[nc:])

            def aux_loss(s):
                return model.loss(model.aux_fwd(pa, s), y)

            ga = jax.grad(aux_loss)(sm).reshape(sm.shape[0], -1)
            gs = np.asarray(g_sm).reshape(sm.shape[0], -1)
            ga = np.asarray(ga)
            num = (ga * gs).sum(-1)
            den = np.linalg.norm(ga, axis=-1) * np.linalg.norm(gs, axis=-1)
            return float((num / (den + 1e-20)).mean())

        c0 = mean_cos(args[0])
        theta = args[0]
        for _ in range(25):
            theta = fn(theta, *args[1:])[0]
        c1 = mean_cos(theta)
        assert c1 > c0 + 1e-3


class TestSgdVariant:
    def test_sgd_entries_have_no_opt_state(self):
        v = variants.get("cnn_c1_sgd")
        model = build_model(v)
        entries = build_entries(model, "sgd", which=["zo_step", "fo_step"])
        names = [n for n, _, _ in entries["zo_step"].inputs]
        assert "opt_m" not in names
        e = entries["fo_step"]
        args = make_args(model, e)
        out = jax.jit(e.fn)(*args)
        assert len(out) == 2  # theta, loss
