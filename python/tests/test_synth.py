"""Determinism and distribution checks for the synthetic data generators."""

import numpy as np
import pytest

from compile import synth


def test_mix64_deterministic():
    assert synth.mix64(42, 0) == synth.mix64(42, 0)
    assert synth.mix64(42, 0) != synth.mix64(42, 1)
    assert synth.mix64(42, 0) != synth.mix64(43, 0)


def test_mix64_range():
    for k in range(100):
        v = synth.mix64(7, k)
        assert 0 <= v <= synth.MASK64


def test_u01_bounds():
    vals = [synth.u01(3, k) for k in range(1000)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert abs(np.mean(vals) - 0.5) < 0.05


def test_vision_label_distribution():
    labels = [synth.vision_label(1, i) for i in range(2000)]
    counts = np.bincount(labels, minlength=10)
    assert counts.min() > 120  # roughly uniform over 10 classes
    assert set(labels) == set(range(10))


def test_vision_image_shape_and_range():
    img = synth.vision_image(1, 0)
    assert img.shape == (16, 16, 3)
    assert img.dtype == np.float32
    assert np.abs(img).max() < 1.5


def test_vision_images_differ_between_classes():
    # find two indices with different labels; their images should differ a lot
    i0, i1 = 0, 1
    while synth.vision_label(5, i1) == synth.vision_label(5, i0):
        i1 += 1
    a, b = synth.vision_image(5, i0), synth.vision_image(5, i1)
    assert np.abs(a - b).mean() > 0.1


def test_vision_same_class_not_pixel_correlated():
    # the per-sample random phase is a translation nuisance: two same-class
    # images must NOT be trivially pixel-correlated (otherwise the task
    # saturates within one federated round), yet share a frequency signature
    by_label = {}
    for i in range(200):
        by_label.setdefault(synth.vision_label(9, i), []).append(i)
    lab = next(k for k, v in by_label.items() if len(v) >= 8)
    idxs = by_label[lab][:8]
    corrs = []
    for i0, i1 in zip(idxs[:-1], idxs[1:]):
        a = synth.vision_image(9, i0).ravel()
        b = synth.vision_image(9, i1).ravel()
        corrs.append(abs(np.corrcoef(a, b)[0, 1]))
    assert np.mean(corrs) < 0.5, corrs


def test_vision_class_determines_spectrum():
    # same-class images share dominant FFT frequencies even though the
    # random phase decorrelates raw pixels
    def spectrum(i):
        img = synth.vision_image(11, i)[:, :, 0]
        return np.abs(np.fft.fft2(img))

    by_label = {}
    for i in range(300):
        by_label.setdefault(synth.vision_label(11, i), []).append(i)
    # two classes with different (fu, fv): labels 0 -> (1,1), 4 -> (2,2)
    a0, a1 = by_label[0][:2]
    b0 = by_label[4][0]
    s_a0, s_a1, s_b0 = spectrum(a0), spectrum(a1), spectrum(b0)
    same = np.corrcoef(s_a0.ravel(), s_a1.ravel())[0, 1]
    diff = np.corrcoef(s_a0.ravel(), s_b0.ravel())[0, 1]
    assert same > diff, (same, diff)


def test_e2e_record_structure():
    # style 1 (fine-tune distribution, the default)
    rec = synth.e2e_record(42, 0)
    assert ">" in rec and ";" in rec
    mr, text = rec.split(">", 1)
    assert mr.count(";") == 5
    assert len(text) > 10
    # style 0 (pretraining distribution)
    rec0 = synth.e2e_record(42, 0, style=0)
    assert "=" in rec0 and "|" in rec0
    assert rec0 != rec


def test_e2e_styles_share_fields():
    # both styles draw the same underlying fields for the same index
    r0 = synth.e2e_record(7, 3, style=0)
    r1 = synth.e2e_record(7, 3, style=1)
    name = r1.split(">", 1)[0].rsplit(";", 1)[1]
    assert name in r0


def test_records_fit_seq_len():
    for style in (0, 1):
        lens = [len(synth.e2e_record(1, i, style)) for i in range(300)]
        assert max(lens) <= synth.SEQ_LEN


def test_encode_roundtrippable():
    toks = synth.encode("Hello, world!")
    assert toks.shape == (synth.SEQ_LEN,)
    assert toks.max() < synth.VOCAB
    decoded = "".join(
        chr(t + 31) if t > 0 else " " for t in toks[: len("Hello, world!")]
    )
    assert decoded == "Hello, world!"


def test_text_batch_deterministic():
    a = synth.text_batch(3, 0, 4)
    b = synth.text_batch(3, 0, 4)
    assert (a == b).all()
    c = synth.text_batch(4, 0, 4)
    assert not (a == c).all()


def test_golden_vec_values():
    v = synth.golden_vec(8, 101)
    assert v.dtype == np.float32
    # exact formula check
    for i in range(8):
        assert v[i] == np.float32(((i * 31 + 101) % 17 - 8) / 100.0)


@pytest.mark.parametrize("seed", [0, 1, 0xDEADBEEF])
def test_vision_batch_matches_scalar_api(seed):
    xs, ys = synth.vision_batch(seed, 5, 3)
    for j in range(3):
        assert ys[j] == synth.vision_label(seed, 5 + j)
        assert np.allclose(xs[j], synth.vision_image(seed, 5 + j))
