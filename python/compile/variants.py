"""Variant registry: which (model, split, aux, optimizer) combinations get
lowered to HLO by aot.py.

Each variant maps to a directory ``artifacts/<name>/`` holding one HLO text
file per entry plus binary init/frozen-base blobs. The entry subset is
``FULL`` for the variants the main experiments drive and ``CORE`` for
ablation-only variants (keeps `make artifacts` to a few minutes).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from .entries import CORE_ENTRIES, FULL_ENTRIES


@dataclass
class Variant:
    name: str
    family: str          # "cnn" | "gpt2nano" | "gpt2micro"
    cut: int             # client residual blocks / transformer blocks
    aux: int = 0         # transformer aux blocks (cnn: fixed linear head)
    optimizer: str = "adam"
    entries: List[str] = field(default_factory=lambda: list(FULL_ENTRIES))
    batch: int = 0       # 0 = family default
    use_pallas: bool = False
    zo_mode: str = "gaussian"
    pretrain_key: Optional[str] = None  # share pretrained bases per family


VARIANTS: List[Variant] = [
    # --- vision (Fig 2, 3, 4, 7; Table II) --------------------------------
    Variant("cnn_c1", "cnn", cut=1, entries=list(FULL_ENTRIES)),
    Variant("cnn_c1_sgd", "cnn", cut=1, optimizer="sgd",
            entries=["zo_step", "fo_step", "server_step", "eval_full",
                     "client_fwd"]),
    Variant("cnn_c2", "cnn", cut=2, entries=list(CORE_ENTRIES)),
    # --- language: nano = GPT2-Small analog (Fig 5 left) ------------------
    Variant("gpt2nano_c1_a1", "gpt2nano", cut=1, aux=1,
            entries=list(FULL_ENTRIES), pretrain_key="nano"),
    Variant("gpt2nano_c1_a0", "gpt2nano", cut=1, aux=0,
            entries=list(CORE_ENTRIES), pretrain_key="nano"),
    # --- language: micro = GPT2-Medium analog (Fig 5 right, 6; Table III) -
    Variant("gpt2micro_c2_a1", "gpt2micro", cut=2, aux=1,
            entries=list(FULL_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c2_a0", "gpt2micro", cut=2, aux=0,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c2_a2", "gpt2micro", cut=2, aux=2,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c2_a3", "gpt2micro", cut=2, aux=3,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c3_a0", "gpt2micro", cut=3, aux=0,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c3_a1", "gpt2micro", cut=3, aux=1,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c3_a2", "gpt2micro", cut=3, aux=2,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    Variant("gpt2micro_c3_a3", "gpt2micro", cut=3, aux=3,
            entries=list(CORE_ENTRIES), pretrain_key="micro"),
    # --- kernel-path artifact: pallas lowered into the same HLO -----------
    Variant("gpt2nano_c1_a1_pallas", "gpt2nano", cut=1, aux=1,
            entries=["client_fwd", "zo_step", "eval_full"],
            use_pallas=True, pretrain_key="nano"),
]


def get(name: str) -> Variant:
    for v in VARIANTS:
        if v.name == name:
            return v
    raise KeyError(name)
