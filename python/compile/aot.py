"""AOT pipeline: lower every (variant, entry) to HLO text + manifest.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile does
this). For each variant in ``variants.VARIANTS``:

1. build the SplitModel and its entry family,
2. (transformers) pretrain / load-cached the frozen base on SynthE2E and
   attach aux-base copies,
3. jit-lower each entry to stablehlo, convert to an XlaComputation and dump
   **HLO text** — xla_extension 0.5.1 rejects jax>=0.5's serialized protos
   (64-bit instruction ids); the text parser reassigns ids and round-trips,
4. write binary blobs (frozen base, initial parameter vectors) and golden
   input/output digests for the Rust cross-language test,
5. emit ``manifest.json`` describing everything.

Python never runs after this step; the Rust coordinator is self-contained.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import synth, variants
from .entries import Entry, build_entries
from .models import cnn, transformer

GOLDEN_SEED_I32 = 0x5EED
GOLDEN_DATA_SEED = 777
GOLDEN_MU = 1e-3
GOLDEN_LR = 1e-2


def log(msg: str):
    print(msg, flush=True)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# model construction per variant
# ---------------------------------------------------------------------------


def build_model(v: variants.Variant):
    if v.family == "cnn":
        return cnn.build(v.cut, batch=v.batch or 32)
    dm = transformer.NANO if v.family == "gpt2nano" else transformer.MICRO
    return transformer.build(
        dm, v.cut, v.aux, batch=v.batch or 8, use_pallas=v.use_pallas,
        name=v.name,
    )


_PRETRAIN_CACHE = {}


def pretrained_base(v: variants.Variant, model, cache_dir: str, steps: int):
    """Return the flat frozen-base vector for a transformer variant."""
    dm = model.extra["dims"]
    key = v.pretrain_key or v.family
    path = os.path.join(cache_dir, f"base_{key}.npz")
    if key in _PRETRAIN_CACHE:
        base = _PRETRAIN_CACHE[key]
    elif os.path.exists(path):
        base = dict(np.load(path))
        log(f"  loaded cached pretrained base {path}")
        _PRETRAIN_CACHE[key] = base
    else:
        t0 = time.time()
        base, final = transformer.pretrain(dm, steps=steps, log=log)
        log(f"  pretrained {key}: loss {final:.3f} in {time.time()-t0:.0f}s")
        os.makedirs(cache_dir, exist_ok=True)
        np.savez(path, **base)
        _PRETRAIN_CACHE[key] = base
    full = transformer.attach_aux_base(base, dm, v.cut, v.aux)
    spec = model.extra["base_spec"]
    return np.concatenate(
        [np.ravel(full[n]).astype(np.float32) for n, _ in spec.entries]
    )


# ---------------------------------------------------------------------------
# golden inputs: deterministic, regenerated identically by the Rust tests
# ---------------------------------------------------------------------------


def golden_input(model, name, shape, dtype, salt):
    if name == "x":
        b = shape[0]
        if model.task == "vision":
            xs, _ = synth.vision_batch(GOLDEN_DATA_SEED, 0, b)
            return jnp.asarray(xs)
        return jnp.asarray(synth.text_batch(GOLDEN_DATA_SEED, 0, b))
    if name == "y":
        b = shape[0]
        if model.task == "vision":
            _, ys = synth.vision_batch(GOLDEN_DATA_SEED, 0, b)
            return jnp.asarray(ys)
        return jnp.asarray(synth.text_batch(GOLDEN_DATA_SEED, 0, b))
    if name == "seed":
        return jnp.asarray(GOLDEN_SEED_I32, jnp.int32)
    if name == "n_pert":
        return jnp.asarray(1, jnp.int32)
    if name == "mu":
        return jnp.asarray(GOLDEN_MU, jnp.float32)
    if name == "lr":
        return jnp.asarray(GOLDEN_LR, jnp.float32)
    if name == "opt_t":
        # mature step count: keeps bias-correction factors O(1)
        return jnp.asarray(10.0, jnp.float32)
    if name == "opt_v":
        # Adam second moment: non-negative (sqrt) AND floored away from 0 —
        # v ~ 0 makes the update ~ m/|g|, amplifying XLA-version rounding
        # differences in conv backward by 1/|g| and breaking the
        # cross-language golden comparison.
        n = int(np.prod(shape)) if shape else 1
        v = jnp.abs(jnp.asarray(synth.golden_vec(n, salt))) + 0.05
        return v.reshape(shape)
    if dtype == "i32":
        return jnp.zeros(shape, jnp.int32)
    n = int(np.prod(shape)) if shape else 1
    return jnp.asarray(synth.golden_vec(n, salt)).reshape(shape)


def summarize(arr) -> dict:
    a = np.asarray(arr, dtype=np.float64).ravel()
    return {
        "shape": list(np.asarray(arr).shape),
        "head": [float(x) for x in a[:4]],
        "sum": float(a.sum()),
        "l2": float(np.sqrt((a * a).sum())),
    }


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def lower_variant(v: variants.Variant, out_dir: str, cache_dir: str,
                  golden: bool, pretrain_steps: int) -> dict:
    log(f"[variant] {v.name}")
    model = build_model(v)
    entries = build_entries(
        model, optimizer=v.optimizer, zo_mode=v.zo_mode, which=v.entries
    )
    vdir = os.path.join(out_dir, v.name)
    os.makedirs(vdir, exist_ok=True)

    has_base = "base_spec" in model.extra
    files = {}
    base_vec = None
    if has_base:
        base_vec = pretrained_base(v, model, cache_dir, pretrain_steps)
        files["frozen_base"] = "frozen_base.bin"
        base_vec.astype("<f4").tofile(os.path.join(vdir, "frozen_base.bin"))

    # initial parameter vectors (shared across all algorithms in Rust)
    rng = np.random.default_rng(0xC0FFEE)
    tc, ta, ts = model.init(rng)
    init_l = np.concatenate(
        [np.ravel(tc[n]) for n, _ in model.spec_client.entries]
        + [np.ravel(ta[n]) for n, _ in model.spec_aux.entries]
    ).astype("<f4")
    init_s = np.concatenate(
        [np.ravel(ts[n]) for n, _ in model.spec_server.entries]
    ).astype("<f4")
    init_l.tofile(os.path.join(vdir, "init_theta_l.bin"))
    init_s.tofile(os.path.join(vdir, "init_theta_s.bin"))
    files["init_theta_l"] = "init_theta_l.bin"
    files["init_theta_s"] = "init_theta_s.bin"

    man_entries = {}
    goldens = {}
    for name, e in entries.items():
        t0 = time.time()
        specs = [
            jax.ShapeDtypeStruct(
                tuple(s), jnp.int32 if d == "i32" else jnp.float32
            )
            for _, s, d in e.inputs
        ]
        lowered = jax.jit(e.fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
        man = e.manifest()
        man["file"] = fname
        man_entries[name] = man
        dt = time.time() - t0

        if golden:
            args = []
            for idx, (nm, s, d) in enumerate(e.inputs):
                if nm == "base":
                    args.append(jnp.asarray(base_vec))
                else:
                    args.append(golden_input(model, nm, s, d, 101 + idx * 13))
            outs = jax.jit(e.fn)(*args)
            goldens[name] = {"outputs": [summarize(o) for o in outs]}
        log(f"  lowered {name}: {len(text)//1024} KiB in {dt:.1f}s")

    sizes = {
        "client": model.spec_client.size,
        "aux": model.spec_aux.size,
        "server": model.spec_server.size,
        "base": model.extra["base_spec"].size if has_base else 0,
    }
    return {
        "family": v.family,
        "task": model.task,
        "optimizer": v.optimizer,
        "opt_state": 3 if v.optimizer == "adam" else 0,
        "zo_mode": v.zo_mode,
        "use_pallas": v.use_pallas,
        "batch": model.batch,
        "eval_batch": model.eval_batch,
        "x_shape": list(model.x_shape),
        "y_shape": list(model.y_shape),
        "x_dtype": model.x_dtype,
        "y_dtype": model.y_dtype,
        "smashed_shape": list(model.smashed_shape),
        "sizes": sizes,
        "cost": model.cost.manifest(),
        "layout_client": model.spec_client.manifest(),
        "layout_aux": model.spec_aux.manifest(),
        "layout_server": model.spec_server.manifest(),
        "entries": man_entries,
        "files": files,
        "golden": goldens,
    }


def synth_golden() -> dict:
    """Cross-language digests of the synthetic data generators."""
    labels = [synth.vision_label(42, i) for i in range(32)]
    img0 = synth.vision_image(42, 0)
    toks = synth.text_batch(42, 0, 2)
    return {
        "vision_labels_seed42": labels,
        "vision_img0_sum": float(img0.sum()),
        "vision_img0_first": [float(x) for x in img0.ravel()[:6]],
        "text_record0": synth.e2e_record(42, 0),
        "text_tokens0": [int(t) for t in toks[0][:24]],
        "mix64_42_0": str(synth.mix64(42, 0)),
        "golden_vec8_salt101": [float(x) for x in synth.golden_vec(8, 101)],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--cache-dir", default="../artifacts/.cache")
    ap.add_argument("--only", default="", help="comma-separated variant names")
    ap.add_argument("--no-golden", action="store_true")
    ap.add_argument("--pretrain-steps", type=int, default=250)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = [s for s in args.only.split(",") if s]
    t0 = time.time()
    manifest = {"version": 1, "variants": {}, "synth": synth_golden()}

    # merge with existing manifest when lowering a subset
    man_path = os.path.join(args.out_dir, "manifest.json")
    if wanted and os.path.exists(man_path):
        with open(man_path) as f:
            manifest["variants"] = json.load(f).get("variants", {})

    for v in variants.VARIANTS:
        if wanted and v.name not in wanted:
            continue
        manifest["variants"][v.name] = lower_variant(
            v, args.out_dir, args.cache_dir,
            golden=not args.no_golden, pretrain_steps=args.pretrain_steps,
        )

    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"wrote {man_path} ({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
