"""L2 model zoo: split-federated model definitions over the flat-param ABI.

Each model family exposes a ``SplitModel`` (see ``base.py``): client forward,
aux forward, server forward, loss/metric functions, parameter specs, and the
analytic cost model (activation bytes + FLOPs) that feeds the Rust resource
accounting (paper Tables I-III).
"""

from .base import SplitModel, CostModel  # noqa: F401
