"""MiniResNet: the vision family (paper §VI-B, ResNet-18/CIFAR-10 analog).

Architecture (NHWC, 16x16x3 SynthCIFAR input):

    stem:   conv3x3 3->16, BN, relu
    block1: residual [conv3x3 16->16, BN, relu, conv3x3 16->16, BN] + id
    block2: residual stride-2 16->32 (1x1 stride-2 projection skip)
    block3: residual stride-2 32->64
    head:   global avg pool, fc 64->10

Split points (paper Fig 4 "Client Size 1 / 2"):

    cut1: client = stem + block1          (smashed 16x16x16)
    cut2: client = stem + block1 + block2 (smashed  8x8x32)

Aux head per the paper's minimal design: global pool + fc(C_cut -> 10).

BatchNorm uses batch statistics only (no running buffers) so every entry
point stays a pure function of (params, batch); see DESIGN.md §5 for why this
substitution is algorithm-neutral.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..params import Spec, fan_in_init
from .base import CostModel, SplitModel

H = W = 16
CIN = 3
NCLASS = 10
BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# layer primitives (functional, NHWC)
# ---------------------------------------------------------------------------


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def batchnorm(x, gamma, beta):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + BN_EPS)
    return xn * gamma + beta


# ---------------------------------------------------------------------------
# parameterized blocks: each block contributes spec entries + a forward fn
# ---------------------------------------------------------------------------


def _stem_spec(prefix: str) -> List[Tuple[str, Tuple[int, ...]]]:
    return [
        (f"{prefix}.conv.w", (3, 3, CIN, 16)),
        (f"{prefix}.bn.g", (16,)),
        (f"{prefix}.bn.b", (16,)),
    ]


def _stem_fwd(p: Dict, prefix: str, x):
    x = conv(x, p[f"{prefix}.conv.w"])
    x = batchnorm(x, p[f"{prefix}.bn.g"], p[f"{prefix}.bn.b"])
    return jax.nn.relu(x)


def _block_spec(prefix, cin, cout, stride):
    s = [
        (f"{prefix}.conv1.w", (3, 3, cin, cout)),
        (f"{prefix}.bn1.g", (cout,)),
        (f"{prefix}.bn1.b", (cout,)),
        (f"{prefix}.conv2.w", (3, 3, cout, cout)),
        (f"{prefix}.bn2.g", (cout,)),
        (f"{prefix}.bn2.b", (cout,)),
    ]
    if stride != 1 or cin != cout:
        s.append((f"{prefix}.proj.w", (1, 1, cin, cout)))
    return s


def _block_fwd(p, prefix, x, cin, cout, stride):
    h = conv(x, p[f"{prefix}.conv1.w"], stride)
    h = jax.nn.relu(batchnorm(h, p[f"{prefix}.bn1.g"], p[f"{prefix}.bn1.b"]))
    h = conv(h, p[f"{prefix}.conv2.w"])
    h = batchnorm(h, p[f"{prefix}.bn2.g"], p[f"{prefix}.bn2.b"])
    skip = x
    if stride != 1 or cin != cout:
        skip = conv(x, p[f"{prefix}.proj.w"], stride)
    return jax.nn.relu(h + skip)


BLOCKS = [  # (name, cin, cout, stride, out_hw)
    ("block1", 16, 16, 1, 16),
    ("block2", 16, 32, 2, 8),
    ("block3", 32, 64, 2, 4),
]


# ---------------------------------------------------------------------------
# cost model helpers
# ---------------------------------------------------------------------------


def _conv_flops(hw, cin, cout, k=3):
    return 2 * hw * hw * cin * cout * k * k


def _stem_cost():
    acts = H * W * 16 * 4 * 3  # conv out, bn out, relu out retained for bwd
    flops = _conv_flops(H, CIN, 16) + 4 * H * W * 16  # conv + bn/relu elemwise
    return acts, flops, H * W * 16 * 4


def _block_cost(cin, cout, stride, hw_out):
    hw_in = hw_out * stride
    # retained: conv1/bn1/relu1, conv2/bn2, skip, sum-relu (per-sample f32)
    acts = (6 * hw_out * hw_out * cout) * 4
    flops = (
        _conv_flops(hw_out, cin, cout)
        + _conv_flops(hw_out, cout, cout)
        + ((2 * hw_out * hw_out * cin * cout) if (stride != 1 or cin != cout) else 0)
        + 8 * hw_out * hw_out * cout
    )
    peak = hw_in * hw_in * cin * 4 + hw_out * hw_out * cout * 4
    return acts, flops, peak


# ---------------------------------------------------------------------------
# model factory
# ---------------------------------------------------------------------------


def build(cut: int, batch: int = 32, eval_batch: int = 256) -> SplitModel:
    """cut = number of residual blocks on the client (1 or 2)."""
    assert cut in (1, 2)
    client_blocks = BLOCKS[:cut]
    server_blocks = BLOCKS[cut:]
    c_cut = client_blocks[-1][2]
    hw_cut = client_blocks[-1][4]

    spec_c = Spec(
        _stem_spec("stem")
        + [e for b in client_blocks for e in _block_spec(b[0], b[1], b[2], b[3])]
    )
    spec_a = Spec([("aux.fc.w", (c_cut, NCLASS)), ("aux.fc.b", (NCLASS,))])
    spec_s = Spec(
        [e for b in server_blocks for e in _block_spec(b[0], b[1], b[2], b[3])]
        + [("head.fc.w", (64, NCLASS)), ("head.fc.b", (NCLASS,))]
    )

    def client_fwd(p, x):
        h = _stem_fwd(p, "stem", x)
        for name, cin, cout, stride, _ in client_blocks:
            h = _block_fwd(p, name, h, cin, cout, stride)
        return h

    def aux_fwd(p, smashed):
        pooled = jnp.mean(smashed, axis=(1, 2))
        return pooled @ p["aux.fc.w"] + p["aux.fc.b"]

    def server_fwd(p, smashed):
        h = smashed
        for name, cin, cout, stride, _ in server_blocks:
            h = _block_fwd(p, name, h, cin, cout, stride)
        pooled = jnp.mean(h, axis=(1, 2))
        return pooled @ p["head.fc.w"] + p["head.fc.b"]

    def loss(logits, y):
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    def metric(logits, y):
        return jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))

    def init(rng: np.random.Generator):
        def tree_for(spec: Spec):
            t = {}
            for name, shape in spec.entries:
                if name.endswith(".g"):
                    t[name] = np.ones(shape, np.float32)
                elif name.endswith(".b"):
                    t[name] = np.zeros(shape, np.float32)
                elif name.endswith(".w") and len(shape) == 4:
                    fan = shape[0] * shape[1] * shape[2]
                    t[name] = fan_in_init(rng, shape, fan)
                else:  # fc weight / bias
                    fan = shape[0] if len(shape) == 2 else 1
                    t[name] = (
                        fan_in_init(rng, shape, fan)
                        if len(shape) == 2
                        else np.zeros(shape, np.float32)
                    )
            return t

        return tree_for(spec_c), tree_for(spec_a), tree_for(spec_s)

    # ---- cost model -------------------------------------------------------
    cost = CostModel()
    cost.params_client = spec_c.size
    cost.params_aux = spec_a.size
    cost.params_server = spec_s.size
    a, f, p = _stem_cost()
    cost.act_cache_client += a
    cost.flops_fwd_client += f
    cost.act_peak_client = max(cost.act_peak_client, p)
    for name, cin, cout, stride, hw in client_blocks:
        a, f, p = _block_cost(cin, cout, stride, hw)
        cost.act_cache_client += a
        cost.flops_fwd_client += f
        cost.act_peak_client = max(cost.act_peak_client, p)
    for name, cin, cout, stride, hw in server_blocks:
        a, f, p = _block_cost(cin, cout, stride, hw)
        cost.act_cache_server += a
        cost.flops_fwd_server += f
        cost.act_peak_server = max(cost.act_peak_server, p)
    cost.act_cache_aux = (c_cut + NCLASS) * 4
    cost.act_peak_aux = hw_cut * hw_cut * c_cut * 4
    cost.flops_fwd_aux = 2 * c_cut * NCLASS + hw_cut * hw_cut * c_cut
    cost.flops_fwd_server += 2 * 64 * NCLASS
    cost.act_cache_server += (64 + NCLASS) * 4
    cost.smashed_elems = hw_cut * hw_cut * c_cut
    cost.target_elems = 1

    return SplitModel(
        name=f"cnn_c{cut}",
        spec_client=spec_c,
        spec_aux=spec_a,
        spec_server=spec_s,
        client_fwd=client_fwd,
        aux_fwd=aux_fwd,
        server_fwd=server_fwd,
        loss=loss,
        metric=metric,
        init=init,
        cost=cost,
        batch=batch,
        eval_batch=eval_batch,
        x_shape=(H, W, CIN),
        y_shape=(),
        smashed_shape=(hw_cut, hw_cut, c_cut),
        task="vision",
    )
