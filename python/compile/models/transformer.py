"""GPT2-nano/micro: the language family (paper §VI-C, GPT2-Small/Medium analog).

A byte-level GPT-2-shaped decoder, pretrained *in-repo* on the SynthE2E
corpus (aot.py caches the pretrained base), then LoRA fine-tuned under SFL:

* frozen base weights travel as one flat f32 "base" input tensor (stored as
  ``artifacts/<variant>/frozen_base.bin``; baking ~1M floats into HLO text
  constants would explode artifact size),
* trainable parameters are LoRA adapters (rank r on the q and v projections
  of every block) plus the aux head's final-LN scale/shift,
* the aux network is ``m`` transformer blocks + LN + tied unembedding, its
  base initialized by *copying the first server blocks* (paper §VI-A).

Splits mirror the paper: nano (4 blocks) client=1; micro (6 blocks)
client∈{2,3} with aux∈{0..3} blocks for the Fig 6 ablation.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import synth
from ..kernels.lora_linear import lora_linear
from ..kernels.ref import lora_linear_ref
from ..params import Spec, fan_in_init
from .base import CostModel, SplitModel

LN_EPS = 1e-5


class Dims:
    def __init__(self, d, heads, blocks, mlp, seq=synth.SEQ_LEN,
                 vocab=synth.VOCAB, rank=4, alpha=8.0):
        self.d, self.heads, self.blocks, self.mlp = d, heads, blocks, mlp
        self.seq, self.vocab, self.rank, self.alpha = seq, vocab, rank, alpha
        self.head_dim = d // heads


NANO = Dims(d=64, heads=4, blocks=4, mlp=256)
MICRO = Dims(d=96, heads=6, blocks=6, mlp=384)


# ---------------------------------------------------------------------------
# base parameter spec (frozen)
# ---------------------------------------------------------------------------


def _block_base(prefix: str, dm: Dims):
    d, m = dm.d, dm.mlp
    return [
        (f"{prefix}.ln1.g", (d,)), (f"{prefix}.ln1.b", (d,)),
        (f"{prefix}.q.w", (d, d)), (f"{prefix}.k.w", (d, d)),
        (f"{prefix}.v.w", (d, d)), (f"{prefix}.o.w", (d, d)),
        (f"{prefix}.ln2.g", (d,)), (f"{prefix}.ln2.b", (d,)),
        (f"{prefix}.fc.w", (d, m)), (f"{prefix}.fc.b", (m,)),
        (f"{prefix}.proj.w", (m, d)), (f"{prefix}.proj.b", (d,)),
    ]


def base_spec(dm: Dims, aux_blocks: int) -> Spec:
    entries = [("emb", (dm.vocab, dm.d)), ("pos", (dm.seq, dm.d))]
    for i in range(dm.blocks):
        entries += _block_base(f"blk{i}", dm)
    entries += [("lnf.g", (dm.d,)), ("lnf.b", (dm.d,))]
    for j in range(aux_blocks):
        entries += _block_base(f"aux{j}", dm)
    entries += [("auxlnf.g", (dm.d,)), ("auxlnf.b", (dm.d,))]
    return Spec(entries)


def _block_lora(prefix: str, dm: Dims):
    d, r = dm.d, dm.rank
    return [
        (f"{prefix}.q.A", (d, r)), (f"{prefix}.q.B", (r, d)),
        (f"{prefix}.v.A", (d, r)), (f"{prefix}.v.B", (r, d)),
    ]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g + b


def _lora_proj(x2d, w, lora, pa, pb, scale, use_pallas):
    """(T*B, d) LoRA projection; pallas kernel or jnp oracle path."""
    if lora is None:
        return x2d @ w
    fn = lora_linear if use_pallas else lora_linear_ref
    return fn(x2d, w, lora[pa], lora[pb], scale)


def block_fwd(base, lora, prefix, dm: Dims, h, use_pallas):
    """h: (B, T, d). lora may be None (frozen block) or a tree with
    {prefix}.{q,v}.{A,B}."""
    b, t, d = h.shape
    scale = dm.alpha / dm.rank
    x = layer_norm(h, base[f"{prefix}.ln1.g"], base[f"{prefix}.ln1.b"])
    x2 = x.reshape(b * t, d)
    q = _lora_proj(x2, base[f"{prefix}.q.w"], lora,
                   f"{prefix}.q.A", f"{prefix}.q.B", scale, use_pallas)
    k = x2 @ base[f"{prefix}.k.w"]
    v = _lora_proj(x2, base[f"{prefix}.v.w"], lora,
                   f"{prefix}.v.A", f"{prefix}.v.B", scale, use_pallas)

    def split(z):
        return z.reshape(b, t, dm.heads, dm.head_dim).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    att = jnp.einsum("bhtd,bhsd->bhts", q, k) / np.float32(
        np.sqrt(dm.head_dim)
    )
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    att = jnp.where(mask[None, None] > 0, att, np.float32(-1e9))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b * t, d)
    h = h + (out @ base[f"{prefix}.o.w"]).reshape(b, t, d)

    x = layer_norm(h, base[f"{prefix}.ln2.g"], base[f"{prefix}.ln2.b"])
    x2 = x.reshape(b * t, d)
    ff = jax.nn.gelu(x2 @ base[f"{prefix}.fc.w"] + base[f"{prefix}.fc.b"])
    h = h + (ff @ base[f"{prefix}.proj.w"] + base[f"{prefix}.proj.b"]).reshape(
        b, t, d
    )
    return h


def embed(base, tokens, dm: Dims):
    h = base["emb"][tokens] + base["pos"][None, : tokens.shape[1]]
    return h


def unembed(base, h):
    return h @ base["emb"].T


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def _block_flops(dm: Dims, lora: bool):
    d, t, m = dm.d, dm.seq, dm.mlp
    f = 2 * d * d * 4  # qkvo per token
    f += 2 * 2 * t * d  # attention scores + mix per token
    f += 2 * d * m * 2  # mlp per token
    if lora:
        f += 2 * 2 * (d * dm.rank + dm.rank * d)  # q,v adapters
    return f * t  # per sample (t tokens)


def _block_act_cache(dm: Dims):
    d, t, m = dm.d, dm.seq, dm.mlp
    per_tok = 10 * d + 2 * m + dm.heads * t  # ln/qkv/att/out/mlp retained
    return per_tok * t * 4


# ---------------------------------------------------------------------------
# model factory
# ---------------------------------------------------------------------------


def build(dm: Dims, client_blocks: int, aux_blocks: int, *, batch=8,
          eval_batch=32, use_pallas=False, name=None) -> SplitModel:
    nb = dm.blocks
    assert 1 <= client_blocks < nb
    server_ids = list(range(client_blocks, nb))
    client_ids = list(range(client_blocks))

    spec_c = Spec([e for i in client_ids for e in _block_lora(f"blk{i}", dm)])
    spec_a = Spec(
        [e for j in range(aux_blocks) for e in _block_lora(f"aux{j}", dm)]
        + [("auxlnf_d.g", (dm.d,)), ("auxlnf_d.b", (dm.d,))]
    )
    spec_s = Spec([e for i in server_ids for e in _block_lora(f"blk{i}", dm)])
    bspec = base_spec(dm, aux_blocks)

    def client_fwd(p, x, base):
        h = embed(base, x, dm)
        for i in client_ids:
            h = block_fwd(base, p, f"blk{i}", dm, h, use_pallas)
        return h

    def aux_fwd(p, smashed, base):
        h = smashed
        for j in range(aux_blocks):
            h = block_fwd(base, p, f"aux{j}", dm, h, use_pallas)
        g = base["auxlnf.g"] + p["auxlnf_d.g"]
        b = base["auxlnf.b"] + p["auxlnf_d.b"]
        h = layer_norm(h, g, b)
        return unembed(base, h)

    def server_fwd(p, smashed, base):
        h = smashed
        for i in server_ids:
            h = block_fwd(base, p, f"blk{i}", dm, h, use_pallas)
        h = layer_norm(h, base["lnf.g"], base["lnf.b"])
        return unembed(base, h)

    def loss(logits, y):
        # next-token CE, pad-masked mean
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = y[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = (tgt != synth.PAD).astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def metric(logits, y):
        # (nll_sum, token_count) folded into one call by entries.py
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = y[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = (tgt != synth.PAD).astype(jnp.float32)
        return jnp.sum(nll * mask), jnp.sum(mask)

    def init(rng: np.random.Generator):
        def lora_tree(spec: Spec):
            t = {}
            for nm, shape in spec.entries:
                if nm.endswith(".A"):
                    t[nm] = fan_in_init(rng, shape, shape[0])
                else:  # .B and LN deltas start at zero (LoRA convention)
                    t[nm] = np.zeros(shape, np.float32)
            return t

        return lora_tree(spec_c), lora_tree(spec_a), lora_tree(spec_s)

    # ---- cost model -------------------------------------------------------
    cost = CostModel()
    cost.params_client = spec_c.size
    cost.params_aux = spec_a.size
    cost.params_server = spec_s.size
    t, d = dm.seq, dm.d
    cost.flops_fwd_client = len(client_ids) * _block_flops(dm, True) + 2 * t * d
    cost.flops_fwd_aux = (
        aux_blocks * _block_flops(dm, True) + 2 * t * d * dm.vocab
    )
    cost.flops_fwd_server = (
        len(server_ids) * _block_flops(dm, True) + 2 * t * d * dm.vocab
    )
    cost.act_cache_client = len(client_ids) * _block_act_cache(dm) + t * d * 4
    cost.act_cache_aux = aux_blocks * _block_act_cache(dm) + t * dm.vocab * 4
    cost.act_cache_server = (
        len(server_ids) * _block_act_cache(dm) + t * dm.vocab * 4
    )
    cost.act_peak_client = t * max(4 * d, dm.heads * t) * 4
    cost.act_peak_aux = t * dm.vocab * 4
    cost.act_peak_server = t * dm.vocab * 4
    cost.smashed_elems = t * d
    cost.target_elems = t

    fam = "gpt2nano" if dm is NANO else "gpt2micro"
    return SplitModel(
        name=name or f"{fam}_c{client_blocks}_a{aux_blocks}",
        spec_client=spec_c,
        spec_aux=spec_a,
        spec_server=spec_s,
        client_fwd=client_fwd,
        aux_fwd=aux_fwd,
        server_fwd=server_fwd,
        loss=loss,
        metric=metric,
        init=init,
        cost=cost,
        batch=batch,
        eval_batch=eval_batch,
        x_shape=(dm.seq,),
        y_shape=(dm.seq,),
        x_dtype="i32",
        y_dtype="i32",
        smashed_shape=(dm.seq, dm.d),
        task="lm",
        extra={
            "dims": dm,
            "base_spec": bspec,
            "client_ids": client_ids,
            "server_ids": server_ids,
            "aux_blocks": aux_blocks,
        },
    )


# ---------------------------------------------------------------------------
# in-repo pretraining of the frozen base (full-parameter, pure jax)
# ---------------------------------------------------------------------------


def init_base(dm: Dims, rng: np.random.Generator) -> Dict[str, np.ndarray]:
    base = {}
    spec = base_spec(dm, aux_blocks=0)  # aux copies appended later
    for nm, shape in spec.entries:
        if nm.endswith(".g"):
            base[nm] = np.ones(shape, np.float32)
        elif nm.endswith((".b",)):
            base[nm] = np.zeros(shape, np.float32)
        elif nm in ("emb", "pos"):
            base[nm] = rng.standard_normal(shape).astype(np.float32) * 0.02
        else:
            base[nm] = fan_in_init(rng, shape, shape[0])
    return base


def full_fwd(base, tokens, dm: Dims):
    h = embed(base, tokens, dm)
    for i in range(dm.blocks):
        h = block_fwd(base, None, f"blk{i}", dm, h, False)
    h = layer_norm(h, base["lnf.g"], base["lnf.b"])
    return unembed(base, h)


def pretrain(dm: Dims, steps: int = 250, batch: int = 16, seed: int = 7,
             lr: float = 3e-3, log=lambda s: None):
    """Adam pretraining on SynthE2E; returns (base_tree, final_loss)."""
    rng = np.random.default_rng(seed)
    base = {k: jnp.asarray(v) for k, v in init_base(dm, rng).items()}

    def loss_fn(params, toks):
        logits = full_fwd(params, toks, dm)
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = toks[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = (tgt != synth.PAD).astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    m = jax.tree.map(jnp.zeros_like, base)
    v = jax.tree.map(jnp.zeros_like, base)

    @jax.jit
    def adam(params, m, v, g, t):
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        params = jax.tree.map(
            lambda p, mm, vv: p
            - lr * (mm / (1 - b1**t)) / (jnp.sqrt(vv / (1 - b2**t)) + eps),
            params, m, v,
        )
        return params, m, v

    final = 0.0
    for step in range(steps):
        toks = jnp.asarray(
            synth.text_batch(0xE2E0 + seed, step * batch, batch, style=0)
        )
        final, g = grad_fn(base, toks)
        base, m, v = adam(base, m, v, g, step + 1.0)
        if step % 50 == 0:
            log(f"  pretrain[{dm.d}d/{dm.blocks}b] step {step}: loss {float(final):.3f}")
    return {k: np.asarray(x) for k, x in base.items()}, float(final)


def attach_aux_base(base: Dict[str, np.ndarray], dm: Dims,
                    client_blocks: int, aux_blocks: int):
    """Copy the first server blocks into the aux base (paper's aux init)."""
    out = dict(base)
    for j in range(aux_blocks):
        src = f"blk{min(client_blocks + j, dm.blocks - 1)}"
        for nm, _ in _block_base("X", dm):
            leaf = nm[2:]  # strip "X."
            out[f"aux{j}.{leaf}"] = base[f"{src}.{leaf}"].copy()
    out["auxlnf.g"] = base["lnf.g"].copy()
    out["auxlnf.b"] = base["lnf.b"].copy()
    return out
