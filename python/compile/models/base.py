"""Common interface for split-federated models.

A ``SplitModel`` is a purely functional description of the three sub-models
the SFL protocol shuffles around:

    client_fwd(theta_c_tree, x)            -> smashed
    aux_fwd(theta_a_tree, smashed)         -> logits over targets
    server_fwd(theta_s_tree, smashed)      -> logits over targets
    loss(logits, y)                        -> scalar mean loss
    metric(logits, y)                      -> scalar sum-statistic
                                              (correct count / token nll sum)

plus the parameter specs and the cost model. The ZO/FO/server entry points in
``entries.py`` are generated from this interface only — model families never
see the protocol.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

import numpy as np

from ..params import Spec


@dataclass
class CostModel:
    """Analytic per-sample resource model emitted into the manifest.

    All byte figures are f32 (4 bytes/elt), per *sample* (multiply by batch
    in Rust). ``act_cache_bytes`` is the total activation footprint retained
    for a backward pass; ``act_peak_bytes`` is the largest single transient
    activation (the inference/ZO peak). ``flops_fwd`` is one forward pass.
    These feed the paper's Table I formulas in
    rust/src/coordinator/accounting.rs.
    """

    params_client: int = 0
    params_aux: int = 0
    params_server: int = 0
    act_cache_client: int = 0
    act_cache_aux: int = 0
    act_cache_server: int = 0
    act_peak_client: int = 0
    act_peak_aux: int = 0
    act_peak_server: int = 0
    flops_fwd_client: int = 0
    flops_fwd_aux: int = 0
    flops_fwd_server: int = 0
    smashed_elems: int = 0
    target_elems: int = 1

    def manifest(self) -> dict:
        return {k: int(v) for k, v in self.__dict__.items()}


@dataclass
class SplitModel:
    name: str
    spec_client: Spec
    spec_aux: Spec
    spec_server: Spec
    client_fwd: Callable
    aux_fwd: Callable
    server_fwd: Callable
    loss: Callable
    metric: Callable
    init: Callable  # (np_rng) -> (tree_c, tree_a, tree_s)
    cost: CostModel
    batch: int
    eval_batch: int
    x_shape: Tuple[int, ...]  # per-sample input shape
    y_shape: Tuple[int, ...]  # per-sample target shape ( () for class id )
    x_dtype: str = "f32"
    y_dtype: str = "i32"
    smashed_shape: Tuple[int, ...] = ()
    task: str = "vision"  # "vision" | "lm"
    extra: Dict = field(default_factory=dict)
