"""Deterministic synthetic data generators, mirrored bit-for-bit in Rust.

Both the vision set ("SynthCIFAR") and the text corpus ("SynthE2E") are pure
functions of ``(seed, index)`` built on a splitmix64 finalizer, so the Rust
coordinator (rust/src/data/) and this module generate identical streams.
Integer draws (labels, field choices) match exactly across languages; float
images match to ~1e-5 (libm sin differs in ulps).

The cross-language contract is pinned by golden tests:
``aot.py`` writes sample digests into ``artifacts/manifest.json`` and the Rust
test suite regenerates and compares them.
"""

from __future__ import annotations

import math

import numpy as np

MASK64 = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15

# ---------------------------------------------------------------------------
# splitmix64-style mixing
# ---------------------------------------------------------------------------


def mix64(seed: int, k: int) -> int:
    """Finalize ``seed`` xored with stream position ``k`` (splitmix64 core)."""
    z = (seed + (k + 1) * GOLDEN) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


def u01(seed: int, k: int) -> float:
    """Uniform in [0, 1) from the top 53 bits of mix64."""
    return (mix64(seed, k) >> 11) * (1.0 / (1 << 53))


# ---------------------------------------------------------------------------
# SynthCIFAR: 10-class procedural images, shape (H, W, 3)
# ---------------------------------------------------------------------------

VISION_H = 16
VISION_W = 16
VISION_C = 3
VISION_CLASSES = 10
# Signal/noise mix + per-sample nuisance parameters chosen so MiniResNet
# starts at chance and climbs over tens of federated rounds (a fixed
# pattern per class saturates to 100% within one round).
VISION_SIGNAL = 0.55
VISION_NOISE = 1.0


def vision_label(seed: int, index: int) -> int:
    return mix64(seed, index * 3) % VISION_CLASSES


def vision_image(seed: int, index: int) -> np.ndarray:
    """One image as float32 (H, W, 3).

    Class determines the grating frequencies (fu, fv) and the chroma tint;
    each *sample* additionally draws a random spatial phase and amplitude
    (translation/contrast nuisance) plus strong pixel noise, so the class
    must be inferred from the pattern structure, not raw pixel values.
    """
    label = vision_label(seed, index)
    fu = 1 + label % 3
    fv = 1 + (label // 3) % 3
    tint = (label % 4) * (2.0 * math.pi / 3.0 / 4.0)
    noise_seed = mix64(seed, index * 3 + 1)
    nuis_seed = mix64(seed, index * 3 + 2)
    two_pi = 2.0 * math.pi
    r_phase = u01(nuis_seed, 0) * two_pi
    r_amp = 0.6 + 0.4 * u01(nuis_seed, 1)

    img = np.empty((VISION_H, VISION_W, VISION_C), dtype=np.float32)
    for h in range(VISION_H):
        for w in range(VISION_W):
            base_arg = (
                two_pi * (fu * h / VISION_H + fv * w / VISION_W) + r_phase
            )
            for c in range(VISION_C):
                base = math.sin(base_arg + c * tint)
                p = (h * VISION_W + w) * VISION_C + c
                noise = 2.0 * (u01(noise_seed, p) - 0.5)
                img[h, w, c] = np.float32(
                    r_amp * VISION_SIGNAL * base + VISION_NOISE * noise
                )
    return img


def vision_batch(seed: int, start: int, count: int):
    xs = np.stack([vision_image(seed, start + i) for i in range(count)])
    ys = np.array(
        [vision_label(seed, start + i) for i in range(count)], dtype=np.int32
    )
    return xs, ys


# ---------------------------------------------------------------------------
# SynthE2E: slot-grammar restaurant descriptions (E2E-NLG shaped)
# ---------------------------------------------------------------------------

E2E_NAMES = [
    "Alimentum", "Aromi", "Blue Spice", "Clowns", "Cocum", "Cotto",
    "Fitzbillies", "Giraffe", "Green Man", "Loch Fyne", "Strada", "Zizzi",
    "The Mill", "The Eagle", "The Punter", "Wildwood",
]
E2E_EATTYPE = ["pub", "restaurant", "coffee shop"]
E2E_FOOD = ["Chinese", "English", "French", "Indian", "Italian", "Japanese"]
E2E_PRICE = ["cheap", "moderate", "expensive"]
E2E_AREA = ["city centre", "riverside"]
E2E_RATING = ["low", "average", "high"]

SEQ_LEN = 96
VOCAB = 96  # printable ASCII 32..126 -> 1..95, pad/other -> 0
PAD = 0


def e2e_record(seed: int, index: int, style: int = 1) -> str:
    """MR-then-realisation string, fields drawn deterministically.

    ``style=0`` is the *pretraining* distribution; ``style=1`` (the default,
    used by the SFL fine-tuning data in Rust and by the goldens) reorders the
    MR fields and uses different realisation templates — the domain shift
    that makes LoRA fine-tuning meaningful (paper §VI-C: adapt a pretrained
    LM to a new task). MRs use 3-char abbreviations so the worst-case record
    (94 chars) fits SEQ_LEN=96 without truncation.
    """
    base = index * 8
    name = E2E_NAMES[mix64(seed, base) % len(E2E_NAMES)]
    eat = E2E_EATTYPE[mix64(seed, base + 1) % len(E2E_EATTYPE)]
    food = E2E_FOOD[mix64(seed, base + 2) % len(E2E_FOOD)]
    price = E2E_PRICE[mix64(seed, base + 3) % len(E2E_PRICE)]
    area = E2E_AREA[mix64(seed, base + 4) % len(E2E_AREA)]
    rating = E2E_RATING[mix64(seed, base + 5) % len(E2E_RATING)]
    form = mix64(seed, base + 6) % 3
    if style == 0:
        mr = (
            f"{name}|{eat[:3]}|{food[:3]}|{price[:3]}|{area[:3]}"
            f"|{rating[:3]}="
        )
        if form == 0:
            text = f"{name} is a {price} {food} {eat}."
        elif form == 1:
            text = f"{name} serves {price} {food} food in the {area}."
        else:
            text = f"{name} is a {rating} rated {food} {eat}."
    else:
        mr = (
            f"{food[:3]};{price[:3]};{area[:3]};{eat[:3]}"
            f";{rating[:3]};{name}>"
        )
        if form == 0:
            text = f"In {area}, {name} offers {price} {food} dishes."
        elif form == 1:
            text = f"{name}: {price} {food} cuisine, {rating} rating."
        else:
            text = f"Visit {name} for {food} food at {price} prices."
    return mr + text


def encode(s: str) -> np.ndarray:
    """Byte-level tokenizer: printable ASCII -> 1..95, else PAD; pad/truncate
    to SEQ_LEN."""
    toks = np.full(SEQ_LEN, PAD, dtype=np.int32)
    for i, ch in enumerate(s[:SEQ_LEN]):
        o = ord(ch)
        toks[i] = (o - 31) if 32 <= o <= 126 else PAD
    return toks


def text_batch(
    seed: int, start: int, count: int, style: int = 1
) -> np.ndarray:
    return np.stack(
        [encode(e2e_record(seed, start + i, style)) for i in range(count)]
    )


# ---------------------------------------------------------------------------
# Deterministic pseudo-inputs for golden IO (no RNG, trivially portable)
# ---------------------------------------------------------------------------


def golden_vec(n: int, salt: int) -> np.ndarray:
    """Exact-match pattern both languages compute: ((i*31+salt) % 17 - 8)/100."""
    i = np.arange(n, dtype=np.int64)
    return (((i * 31 + salt) % 17 - 8) / 100.0).astype(np.float32)
