"""In-graph optimizers over flat parameter vectors.

Both optimizers operate on the flat f32 ABI of ``params.py`` so the optimizer
state threads through HLO entry points as plain tensors:

* SGD — stateless, ``opt = ()``.
* Adam — ``opt = (m, v, t)`` with m, v the same length as the params and t a
  scalar step counter carried as f32. Matches the paper's ResNet setup
  (Adam on both sides, lr 1e-4).

``make_optimizer(name)`` returns ``(init_fn, update_fn, n_state)`` where
``update_fn(theta, grad, opt, lr) -> (theta', opt')`` and ``n_state`` is the
number of extra state tensors (used by entries.py to shape the HLO
signature).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

ADAM_B1 = np.float32(0.9)
ADAM_B2 = np.float32(0.999)
ADAM_EPS = np.float32(1e-8)


def sgd_init(dim: int):
    return ()


def sgd_update(theta, grad, opt, lr):
    return theta - lr * grad, ()


def adam_init(dim: int):
    return (
        jnp.zeros((dim,), jnp.float32),
        jnp.zeros((dim,), jnp.float32),
        jnp.zeros((), jnp.float32),
    )


def adam_update(theta, grad, opt, lr):
    m, v, t = opt
    t = t + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grad
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    mhat = m / (1.0 - ADAM_B1**t)
    vhat = v / (1.0 - ADAM_B2**t)
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return theta, (m, v, t)


def make_optimizer(name: str):
    if name == "sgd":
        return sgd_init, sgd_update, 0
    if name == "adam":
        return adam_init, adam_update, 3
    raise ValueError(f"unknown optimizer {name!r}")
