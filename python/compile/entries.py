"""HLO entry-point builders: the protocol surface the Rust coordinator calls.

Every entry is a pure function over flat f32 vectors (+ int32 batches and
scalars), generated from a ``SplitModel``. The set of entries *is* the
client/server ABI — see DESIGN.md §3 for the table.

Conventions:
* ``theta_l`` = concat(client params, aux params); ``theta_c`` / ``theta_s``
  are the client/server vectors alone.
* Transformer variants take the frozen ``base`` vector as the first input;
  CNN variants have no base (``has_base=False``).
* ``seed`` arrives as i32 (the xla crate's scalar path) and is bitcast to
  u32 in-graph; ``n_pert`` is a runtime i32 driving a ``fori_loop``.
* Optimizer state (Adam: m, v, t) threads through as explicit tensors.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.perturb import fold_seed, perturbation
from .models.base import SplitModel
from .optim import make_optimizer

F32, I32 = "f32", "i32"


class Entry:
    """A lowered-entry description: python fn + typed input/output specs."""

    def __init__(self, name: str, fn: Callable,
                 inputs: List[Tuple[str, Tuple[int, ...], str]],
                 outputs: List[Tuple[str, Tuple[int, ...], str]]):
        self.name, self.fn, self.inputs, self.outputs = name, fn, inputs, outputs

    def manifest(self) -> dict:
        def fmt(items):
            return [
                {"name": n, "shape": list(s), "dtype": d} for n, s, d in items
            ]

        return {"inputs": fmt(self.inputs), "outputs": fmt(self.outputs)}


def _seed_u32(seed_i32):
    return jax.lax.bitcast_convert_type(seed_i32, jnp.uint32)


def build_entries(model: SplitModel, optimizer: str = "adam",
                  zo_mode: str = "gaussian",
                  which: List[str] | None = None) -> Dict[str, Entry]:
    """Construct the entry family for one model variant."""
    spec_c, spec_a, spec_s = model.spec_client, model.spec_aux, model.spec_server
    nc, na, ns = spec_c.size, spec_a.size, spec_s.size
    nl = nc + na
    has_base = "base_spec" in model.extra
    nbase = model.extra["base_spec"].size if has_base else 0
    B, EB = model.batch, model.eval_batch
    xs, ys = model.x_shape, model.y_shape
    xd, yd = model.x_dtype, model.y_dtype
    sm_shape = (B,) + tuple(model.smashed_shape)

    opt_init, opt_update, n_opt = make_optimizer(optimizer)

    base_in = [("base", (nbase,), F32)] if has_base else []

    def call_client(pc_flat, x, base_tree):
        pc = spec_c.unpack(pc_flat)
        if has_base:
            return model.client_fwd(pc, x, base_tree)
        return model.client_fwd(pc, x)

    def call_aux(pa_flat, smashed, base_tree):
        pa = spec_a.unpack(pa_flat)
        if has_base:
            return model.aux_fwd(pa, smashed, base_tree)
        return model.aux_fwd(pa, smashed)

    def call_server(ps_flat, smashed, base_tree):
        ps = spec_s.unpack(ps_flat)
        if has_base:
            return model.server_fwd(ps, smashed, base_tree)
        return model.server_fwd(ps, smashed)

    def base_tree_of(args):
        if has_base:
            return model.extra["base_spec"].unpack(args[0]), args[1:]
        return None, args

    def local_loss_fn(theta_l, x, y, bt):
        sm = call_client(theta_l[:nc], x, bt)
        logits = call_aux(theta_l[nc:], sm, bt)
        return model.loss(logits, y)

    def opt_inputs(prefix, dim):
        if n_opt == 0:
            return []
        return [
            (f"{prefix}_m", (dim,), F32),
            (f"{prefix}_v", (dim,), F32),
            (f"{prefix}_t", (), F32),
        ]

    entries: Dict[str, Entry] = {}

    def add(e: Entry):
        if which is None or e.name in which:
            entries[e.name] = e

    # -- client_fwd ---------------------------------------------------------
    def client_fwd(*args):
        bt, (pc, x) = base_tree_of(args)
        return (call_client(pc, x, bt),)

    add(Entry(
        "client_fwd", client_fwd,
        base_in + [("theta_c", (nc,), F32), ("x", (B,) + xs, xd)],
        [("smashed", sm_shape, F32)],
    ))

    # -- zo_step -------------------------------------------------------------
    def zo_step(*args):
        bt, (theta_l, *rest) = base_tree_of(args)
        if n_opt:
            m, v, t, x, y, seed_i, mu, lr, n_pert = rest
            opt = (m, v, t)
        else:
            x, y, seed_i, mu, lr, n_pert = rest
            opt = ()
        seed = _seed_u32(seed_i)
        base_loss = local_loss_fn(theta_l, x, y, bt)

        def probe(p, acc):
            sp = fold_seed(seed, p)
            u = perturbation(sp, nl)
            if zo_mode == "sphere":
                u = u * jax.lax.rsqrt(jnp.sum(u * u)) * np.float32(1.0)
                scale = np.float32(nl)
            else:
                scale = np.float32(1.0)
            lp = local_loss_fn(theta_l + mu * u, x, y, bt)
            return acc + (scale * (lp - base_loss) / mu) * u

        g = jax.lax.fori_loop(
            0, n_pert, probe, jnp.zeros((nl,), jnp.float32)
        ) / jnp.maximum(n_pert.astype(jnp.float32), 1.0)
        theta2, opt2 = opt_update(theta_l, g, opt, lr)
        return (theta2, *opt2, base_loss)

    add(Entry(
        "zo_step", zo_step,
        base_in + [("theta_l", (nl,), F32)] + opt_inputs("opt", nl) + [
            ("x", (B,) + xs, xd), ("y", (B,) + ys, yd),
            ("seed", (), I32), ("mu", (), F32), ("lr", (), F32),
            ("n_pert", (), I32),
        ],
        [("theta_l", (nl,), F32)]
        + [(n, s, d) for n, s, d in opt_inputs("opt", nl)]
        + [("loss", (), F32)],
    ))

    # -- fo_step -------------------------------------------------------------
    def fo_step(*args):
        bt, (theta_l, *rest) = base_tree_of(args)
        if n_opt:
            m, v, t, x, y, lr = rest
            opt = (m, v, t)
        else:
            x, y, lr = rest
            opt = ()
        loss, g = jax.value_and_grad(local_loss_fn)(theta_l, x, y, bt)
        theta2, opt2 = opt_update(theta_l, g, opt, lr)
        return (theta2, *opt2, loss)

    add(Entry(
        "fo_step", fo_step,
        base_in + [("theta_l", (nl,), F32)] + opt_inputs("opt", nl) + [
            ("x", (B,) + xs, xd), ("y", (B,) + ys, yd), ("lr", (), F32),
        ],
        [("theta_l", (nl,), F32)]
        + [(n, s, d) for n, s, d in opt_inputs("opt", nl)]
        + [("loss", (), F32)],
    ))

    # -- server_step / server_step_cutgrad ------------------------------------
    def server_loss_fn(theta_s, smashed, y, bt):
        return model.loss(call_server(theta_s, smashed, bt), y)

    def server_step(*args):
        bt, (theta_s, *rest) = base_tree_of(args)
        if n_opt:
            m, v, t, smashed, y, lr = rest
            opt = (m, v, t)
        else:
            smashed, y, lr = rest
            opt = ()
        loss, g = jax.value_and_grad(server_loss_fn)(theta_s, smashed, y, bt)
        theta2, opt2 = opt_update(theta_s, g, opt, lr)
        return (theta2, *opt2, loss)

    add(Entry(
        "server_step", server_step,
        base_in + [("theta_s", (ns,), F32)] + opt_inputs("opt", ns) + [
            ("smashed", sm_shape, F32), ("y", (B,) + ys, yd), ("lr", (), F32),
        ],
        [("theta_s", (ns,), F32)]
        + [(n, s, d) for n, s, d in opt_inputs("opt", ns)]
        + [("loss", (), F32)],
    ))

    def server_step_cutgrad(*args):
        bt, (theta_s, *rest) = base_tree_of(args)
        if n_opt:
            m, v, t, smashed, y, lr = rest
            opt = (m, v, t)
        else:
            smashed, y, lr = rest
            opt = ()
        loss, (g_s, g_sm) = jax.value_and_grad(
            server_loss_fn, argnums=(0, 1)
        )(theta_s, smashed, y, bt)
        theta2, opt2 = opt_update(theta_s, g_s, opt, lr)
        return (theta2, *opt2, loss, g_sm)

    add(Entry(
        "server_step_cutgrad", server_step_cutgrad,
        base_in + [("theta_s", (ns,), F32)] + opt_inputs("opt", ns) + [
            ("smashed", sm_shape, F32), ("y", (B,) + ys, yd), ("lr", (), F32),
        ],
        [("theta_s", (ns,), F32)]
        + [(n, s, d) for n, s, d in opt_inputs("opt", ns)]
        + [("loss", (), F32), ("g_smashed", sm_shape, F32)],
    ))

    # -- client_bp_step (traditional SFL: update from relayed cut gradient) ---
    def client_bp_step(*args):
        bt, (theta_c, *rest) = base_tree_of(args)
        if n_opt:
            m, v, t, x, g_sm, lr = rest
            opt = (m, v, t)
        else:
            x, g_sm, lr = rest
            opt = ()
        _, vjp = jax.vjp(lambda tc: call_client(tc, x, bt), theta_c)
        (g_c,) = vjp(g_sm)
        theta2, opt2 = opt_update(theta_c, g_c, opt, lr)
        return (theta2, *opt2)

    add(Entry(
        "client_bp_step", client_bp_step,
        base_in + [("theta_c", (nc,), F32)] + opt_inputs("opt", nc) + [
            ("x", (B,) + xs, xd), ("g_smashed", sm_shape, F32),
            ("lr", (), F32),
        ],
        [("theta_c", (nc,), F32)]
        + [(n, s, d) for n, s, d in opt_inputs("opt", nc)],
    ))

    # -- aux_align (FSL-SAGE: fit aux's cut-gradient to the server's) ---------
    def aux_align(*args):
        bt, (theta_l, smashed, y, g_sm, lr) = base_tree_of(args)

        def align_loss(theta_a):
            # FSL-SAGE-style alignment: make the aux head's cut-layer
            # gradient *direction* match the server's. Cosine (per sample)
            # is scale-free — raw MSE between two ~1e-3-magnitude gradients
            # has vanishing curvature and trains at float32 noise level.
            def aux_loss_of_sm(sm):
                return model.loss(call_aux(theta_a, sm, bt), y)

            g_aux = jax.grad(aux_loss_of_sm)(smashed)
            ga = g_aux.reshape(g_aux.shape[0], -1)
            gs = g_sm.reshape(g_sm.shape[0], -1)
            cos = jnp.sum(ga * gs, -1) * jax.lax.rsqrt(
                jnp.sum(ga * ga, -1) * jnp.sum(gs * gs, -1) + 1e-20
            )
            return 1.0 - jnp.mean(cos)

        g_a = jax.grad(align_loss)(theta_l[nc:])
        theta_a2 = theta_l[nc:] - lr * g_a
        return (jnp.concatenate([theta_l[:nc], theta_a2]),)

    add(Entry(
        "aux_align", aux_align,
        base_in + [
            ("theta_l", (nl,), F32), ("smashed", sm_shape, F32),
            ("y", (B,) + ys, yd), ("g_smashed", sm_shape, F32),
            ("lr", (), F32),
        ],
        [("theta_l", (nl,), F32)],
    ))

    # -- eval_full -------------------------------------------------------------
    ev_sm = (EB,) + tuple(model.smashed_shape)[0:]

    def eval_full(*args):
        bt, (theta_c, theta_s, x, y) = base_tree_of(args)
        sm = call_client(theta_c, x, bt)
        logits = call_server(theta_s, sm, bt)
        if model.task == "lm":
            s1, s2 = model.metric(logits, y)
        else:
            s1 = model.metric(logits, y)
            s2 = jnp.asarray(float(EB), jnp.float32)
        return (s1, s2)

    add(Entry(
        "eval_full", eval_full,
        base_in + [
            ("theta_c", (nc,), F32), ("theta_s", (ns,), F32),
            ("x", (EB,) + xs, xd), ("y", (EB,) + ys, yd),
        ],
        [("stat1", (), F32), ("stat2", (), F32)],
    ))

    # -- local_loss / hvp (diagnostics + Fig 7 Lanczos) ------------------------
    def local_loss(*args):
        bt, (theta_l, x, y) = base_tree_of(args)
        return (local_loss_fn(theta_l, x, y, bt),)

    add(Entry(
        "local_loss", local_loss,
        base_in + [("theta_l", (nl,), F32), ("x", (B,) + xs, xd),
                   ("y", (B,) + ys, yd)],
        [("loss", (), F32)],
    ))

    def hvp(*args):
        bt, (theta_l, x, y, vdir) = base_tree_of(args)
        gfn = lambda t: jax.grad(local_loss_fn)(t, x, y, bt)
        _, hv = jax.jvp(gfn, (theta_l,), (vdir,))
        return (hv,)

    add(Entry(
        "hvp", hvp,
        base_in + [("theta_l", (nl,), F32), ("x", (B,) + xs, xd),
                   ("y", (B,) + ys, yd), ("v", (nl,), F32)],
        [("hv", (nl,), F32)],
    ))

    return entries


CORE_ENTRIES = ["client_fwd", "zo_step", "fo_step", "server_step", "eval_full"]
FULL_ENTRIES = CORE_ENTRIES + [
    "server_step_cutgrad", "client_bp_step", "aux_align", "local_loss", "hvp",
]
