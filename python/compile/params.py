"""Flat-parameter packing: the L2 <-> L3 parameter ABI.

Rust holds every parameter group (client / aux / server) as one flat f32
vector and passes it to HLO entries verbatim. This module defines the layout:
a ``Spec`` is an ordered list of ``(name, shape)``; ``pack``/``unpack``
convert between a dict of arrays and the flat vector with *static* offsets
(so unpack lowers to pure slices — no gathers).

The layout is exported into the artifact manifest so Rust can initialize,
checkpoint, and aggregate parameters without ever materializing shapes.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

Shape = Tuple[int, ...]


class Spec:
    """Ordered (name, shape) layout of one flat parameter vector."""

    def __init__(self, entries: Sequence[Tuple[str, Shape]]):
        self.entries: List[Tuple[str, Shape]] = [
            (n, tuple(s)) for n, s in entries
        ]
        self.offsets: Dict[str, int] = {}
        off = 0
        for name, shape in self.entries:
            if name in self.offsets:
                raise ValueError(f"duplicate param name {name!r}")
            self.offsets[name] = off
            off += int(np.prod(shape)) if shape else 1
        self.size = off

    def __len__(self):
        return len(self.entries)

    def shape(self, name: str) -> Shape:
        for n, s in self.entries:
            if n == name:
                return s
        raise KeyError(name)

    def pack(self, tree: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        missing = [n for n, _ in self.entries if n not in tree]
        if missing:
            raise KeyError(f"missing params: {missing}")
        return jnp.concatenate(
            [jnp.ravel(tree[n]).astype(jnp.float32) for n, _ in self.entries]
        ) if self.entries else jnp.zeros((0,), jnp.float32)

    def unpack(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out = {}
        for name, shape in self.entries:
            off = self.offsets[name]
            n = int(np.prod(shape)) if shape else 1
            out[name] = flat[off : off + n].reshape(shape)
        return out

    def manifest(self) -> dict:
        return {
            "size": self.size,
            "entries": [
                {"name": n, "shape": list(s)} for n, s in self.entries
            ],
        }


def fan_in_init(rng, shape: Shape, fan_in: int) -> np.ndarray:
    """He-style init used by both model families (numpy RNG, build-time)."""
    std = math.sqrt(2.0 / max(fan_in, 1))
    return rng.standard_normal(shape).astype(np.float32) * np.float32(std)
