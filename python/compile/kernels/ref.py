"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package has an oracle here with an identical signature;
pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis and
asserts allclose (bit-exact for the procedural perturbation, tolerance for
matmul accumulation order).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .perturb import gauss, perturbation


def perturbed_weight(w, seed, mu, offset=0):
    """W + mu * U where U is the procedural stream starting at ``offset``."""
    n = w.size
    idx = jnp.arange(n, dtype=jnp.uint32) + np.uint32(offset)
    u = gauss(jnp.asarray(seed, jnp.uint32), idx).reshape(w.shape)
    return w + mu * u


def zo_perturbed_linear_ref(x, w, seed, mu, offset=0):
    """Oracle for the perturbed-forward kernel: x @ (W + mu*U(seed))."""
    return x @ perturbed_weight(w, seed, mu, offset)


def lora_linear_ref(x, w, a, b, scale):
    """Oracle for the fused LoRA projection: x@W + (x@A)@B * scale."""
    return x @ w + (x @ a) @ b * scale


def zo_grad_ref(loss_fn, theta, seed, mu):
    """Reference two-point ZO gradient estimate on a flat parameter vector.

    g_hat = (loss(theta + mu*u) - loss(theta)) / mu * u,  u = U(seed).
    """
    u = perturbation(seed, theta.size)
    lp = loss_fn(theta + mu * u)
    lb = loss_fn(theta)
    return (lp - lb) / mu * u, lb
