"""Counter-based perturbation generator used by the ZO estimator.

The paper's Remark 4 observes that the ZO perturbation vector u never needs
to be stored: it can be regenerated from a seed and applied in place.  We make
that concrete with a *counter-based* generator: element ``idx`` of the
perturbation stream for ``seed`` is a pure function ``gauss(seed, idx)``.

The same function is implemented three times, bit-identically:

* here in jnp (used inside the lowered ``zo_step`` HLO and as the kernel
  oracle),
* inside the Pallas kernel (``zo_perturbed_linear``), generated per-tile so
  the full matrix U never exists in memory,
* in Rust (``rust/src/zo/stream.rs``) for the streaming O(1)-memory update
  demonstration and property tests.

The scalar pipeline is integer hash -> 4x uniform -> Irwin-Hall(4) gaussian
approximation, ``(sum - 2) * sqrt(3)`` (exact mean 0 / variance 1, and only
+,*,- on f32 so cross-language f32 results are bit-exact).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_SQRT3 = np.float32(1.7320508075688772)
_INV32 = np.float32(2.0**-32)
_C1 = np.uint32(0x9E3779B9)
_C2 = np.uint32(0x21F0AAAD)
_C3 = np.uint32(0x735A2D97)


def hash_u32(seed, idx):
    """murmur3-finalizer-style avalanche of (seed, idx); uint32 -> uint32."""
    x = (seed + idx * _C1).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C2
    x = x ^ (x >> 15)
    x = x * _C3
    x = x ^ (x >> 15)
    return x


def gauss(seed, idx):
    """Approximate N(0,1) draw for stream position ``idx`` (uint32 array).

    Irwin-Hall(4): mean 2, var 4/12; normalized to mean 0 var 1.
    """
    idx4 = idx * np.uint32(4)
    acc = jnp.zeros(idx.shape, jnp.float32)
    for k in range(4):
        h = hash_u32(seed, idx4 + np.uint32(k))
        acc = acc + h.astype(jnp.float32) * _INV32
    return (acc - np.float32(2.0)) * _SQRT3


def perturbation(seed, n: int):
    """Full perturbation vector u of length n for a uint32 scalar seed."""
    idx = jnp.arange(n, dtype=jnp.uint32)
    return gauss(jnp.asarray(seed, jnp.uint32), idx)


def fold_seed(seed, k):
    """Derive an independent sub-seed (e.g. per ZO probe index)."""
    return hash_u32(
        jnp.asarray(seed, jnp.uint32),
        jnp.asarray(k, jnp.uint32) + np.uint32(0x517C_C1B7),
    )
