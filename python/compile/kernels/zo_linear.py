"""Pallas kernel: perturbed linear forward for zeroth-order probes.

``zo_perturbed_linear(x, w, seed, mu)`` computes ``x @ (W + mu * U(seed))``
where ``U`` is the counter-based perturbation stream of ``perturb.py``.

TPU mapping of the paper's insight (see DESIGN.md §6):

* The full perturbation matrix U never exists in HBM — each grid step
  regenerates its (bk, bn) tile of U in VMEM from ``(seed, flat index)``.
  This is the kernel-level form of the paper's Remark 4 (O(1) perturbation
  memory), and it is what makes ZO probes memory-neutral relative to plain
  inference.
* Grid is (M/bm, N/bn, K/bk) with the K axis innermost; the output tile acts
  as the VMEM accumulator (its index map ignores k, so it stays resident
  across the K loop); x tiles stream HBM->VMEM once per (i, k).
* Block shapes default to MXU-shaped 128x128x128 when the operands are big
  enough and fall back to the full (small) dims otherwise — the CPU interpret
  path exercises the same BlockSpec schedule.

Numerics are bit-identical to ``ref.zo_perturbed_linear_ref`` because both
paths evaluate the same f32 +,*,- pipeline per element (matmul accumulation
order can differ; tests use tight allclose).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .perturb import _C1, _C2, _C3, _INV32, _SQRT3


def _tile_gauss(seed_u32, row0, col0, bk, bn, n_cols):
    """(bk, bn) tile of the perturbation stream U for a weight of n_cols."""
    i = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0) + row0
    j = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1) + col0
    idx4 = (i * np.uint32(n_cols) + j) * np.uint32(4)
    acc = jnp.zeros((bk, bn), jnp.float32)
    for k in range(4):
        x = (seed_u32 + (idx4 + np.uint32(k)) * _C1).astype(jnp.uint32)
        x = x ^ (x >> 16)
        x = x * _C2
        x = x ^ (x >> 15)
        x = x * _C3
        x = x ^ (x >> 15)
        acc = acc + x.astype(jnp.float32) * _INV32
    return (acc - np.float32(2.0)) * _SQRT3


def _kernel(x_ref, w_ref, seed_ref, mu_ref, o_ref, *, bk, bn, n_cols):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    row0 = (k * np.uint32(bk)).astype(jnp.uint32)
    col0 = (pl.program_id(1) * np.uint32(bn)).astype(jnp.uint32)
    u = _tile_gauss(seed_ref[0], row0, col0, bk, bn, n_cols)
    wp = w_ref[...] + mu_ref[0] * u
    o_ref[...] += jnp.dot(x_ref[...], wp, preferred_element_type=jnp.float32)


def _pick(block, dim):
    return block if dim % block == 0 and dim >= block else dim


def zo_perturbed_linear(x, w, seed, mu, *, bm=128, bn=128, bk=128,
                        interpret=True):
    """x:(M,K) @ (w:(K,N) + mu*U(seed)) with U generated per-tile in VMEM."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, kdim)
    grid = (m // bm, n // bn, kdim // bk)
    seed = jnp.asarray(seed, jnp.uint32).reshape((1,))
    mu = jnp.asarray(mu, jnp.float32).reshape((1,))
    kern = functools.partial(_kernel, bk=bk, bn=bn, n_cols=n)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, seed, mu)


def vmem_bytes(bm, bn, bk):
    """Estimated VMEM working set of one grid step (f32 operands + acc).

    x tile + w tile + u tile + accumulator/output tile. Used by the §Perf
    roofline notes in EXPERIMENTS.md.
    """
    return 4 * (bm * bk + bk * bn + bk * bn + bm * bn)
