"""Pallas kernel: fused LoRA projection ``x@W + (x@A)@B * scale``.

The adapter path is fused with the frozen-weight matmul so the rank-r panel
``x@A`` lives only in VMEM: per (i, j) output tile we accumulate over K both
the dense contribution ``x_tile @ w_tile`` and the adapter partial
``x_tile @ a_tile`` (a (bm, r) panel); on the last K step the panel is
contracted against ``B[:, j]`` and folded into the output.

Grid: (M/bm, N/bn, K/bk), K innermost. VMEM residents per step:
x(bm,bk), w(bk,bn), a(bk,r), b(r,bn), out(bm,bn), panel(bm,r).
Rank r is tiny (4-16) so the extra panel is noise next to the matmul tiles —
this is why fusing beats a second HBM pass over x.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, a_ref, b_ref, scale_ref, o_ref, panel_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)
        panel_ref[...] = jnp.zeros_like(panel_ref)

    x = x_ref[...]
    o_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    panel_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _fold():
        o_ref[...] += scale_ref[0] * jnp.dot(
            panel_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )


def _pick(block, dim):
    return block if dim % block == 0 and dim >= block else dim


def _scratch(shape, dtype):
    """VMEM scratch buffer (interpret-mode-portable MemoryRef)."""
    return pl.MemoryRef(jax.core.ShapedArray(shape, dtype), pl.MemorySpace.ANY)


def lora_linear(x, w, a, b, scale, *, bm=128, bn=128, bk=128, interpret=True):
    """x:(M,K), w:(K,N), a:(K,r), b:(r,N) -> x@w + (x@a)@b*scale."""
    m, kdim = x.shape
    _, n = w.shape
    r = a.shape[1]
    assert a.shape == (kdim, r) and b.shape == (r, n)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, kdim)
    grid = (m // bm, n // bn, kdim // bk)
    scale = jnp.asarray(scale, jnp.float32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[_scratch((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b, scale)
