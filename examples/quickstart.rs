//! Quickstart: train MiniResNet under HERON-SFL for a handful of rounds.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the minimal public-API path: open a session, configure a
//! run, drive rounds, read the curve. Takes ~1 minute on CPU.

use anyhow::Result;
use heron_sfl::coordinator::accounting::fmt_bytes;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::metrics::sparkline;
use heron_sfl::runtime::Session;

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;

    let cfg = RunConfig {
        variant: "cnn_c1".into(),
        algorithm: Algorithm::Heron,
        n_clients: 5,
        rounds: 12,
        local_steps: 2,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        ..Default::default()
    };
    println!("config: {}", cfg.describe());

    let mut driver = Driver::new(&session, cfg)?;
    let rec = driver.run("quickstart")?;

    let accs: Vec<f64> = rec
        .rounds
        .iter()
        .filter(|r| r.eval_metric.is_finite())
        .map(|r| r.eval_metric)
        .collect();
    println!("\naccuracy curve  {}", sparkline(&accs, 40));
    println!(
        "round 0 acc {:.3} -> round {} acc {:.3}",
        accs.first().unwrap(),
        accs.len() - 1,
        accs.last().unwrap()
    );
    println!(
        "client comm {} | client compute {:.1} GFLOPs | peak client mem {}",
        fmt_bytes(rec.summary["comm_bytes"] as u64),
        rec.summary["client_flops"] / 1e9,
        fmt_bytes(rec.summary["peak_mem_bytes"] as u64)
    );
    assert!(
        accs.last().unwrap() > accs.first().unwrap(),
        "training made no progress"
    );
    println!("\nquickstart OK");
    Ok(())
}
