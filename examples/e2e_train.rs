//! End-to-end validation driver (DESIGN.md deliverable): trains MiniResNet
//! under all five SFL algorithms on SynthCIFAR, logs the accuracy curves,
//! and reports the paper's headline metrics — accuracy parity, client peak
//! memory, client FLOPs, and communication volume — proving L1/L2/L3
//! compose on a real (small) workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! # full fidelity (longer): E2E_ROUNDS=80 cargo run --release --example e2e_train
//! ```
//!
//! The recorded output lives in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use heron_sfl::coordinator::accounting::fmt_bytes;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::metrics::{sparkline, RunRecord};
use heron_sfl::runtime::Session;

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let rounds: usize = std::env::var("E2E_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let session = Session::open_default()?;

    let mut records: Vec<(Algorithm, RunRecord)> = Vec::new();
    for alg in Algorithm::all() {
        let cfg = RunConfig {
            variant: "cnn_c1".into(),
            algorithm: alg,
            n_clients: 5,
            rounds,
            local_steps: 2,
            lr_client: 2e-3,
            lr_server: 2e-3,
            mu: 1e-2,
            n_pert: 1,
            eval_every: 1,
            ..Default::default()
        };
        log::info!("=== {} ===", alg.name());
        let mut driver = Driver::new(&session, cfg)?;
        let rec = driver.run(alg.name())?;
        records.push((alg, rec));
    }

    println!("\n================= END-TO-END SUMMARY =================");
    println!(
        "{:<10} {:>9} {:>12} {:>14} {:>14} {:>10}",
        "algo", "final acc", "best acc", "comm", "client GFLOPs", "peak mem"
    );
    let mut heron_acc = 0.0;
    let mut fo_best: f64 = 0.0;
    for (alg, rec) in &records {
        let accs: Vec<f64> = rec
            .rounds
            .iter()
            .filter(|r| r.eval_metric.is_finite())
            .map(|r| r.eval_metric)
            .collect();
        let fin = *accs.last().unwrap_or(&0.0);
        let best = rec.best_metric(true).unwrap_or(0.0);
        if *alg == Algorithm::Heron {
            heron_acc = best;
        } else {
            fo_best = fo_best.max(best);
        }
        println!(
            "{:<10} {:>9.3} {:>12.3} {:>14} {:>14.1} {:>10}",
            alg.name(),
            fin,
            best,
            fmt_bytes(rec.summary["comm_bytes"] as u64),
            rec.summary["client_flops"] / 1e9,
            fmt_bytes(rec.summary["peak_mem_bytes"] as u64),
        );
        println!("           {}", sparkline(&accs, 56));
    }

    // paper headline ratios (HERON vs CSE-FSL)
    let heron = &records
        .iter()
        .find(|(a, _)| *a == Algorithm::Heron)
        .unwrap()
        .1;
    let cse = &records
        .iter()
        .find(|(a, _)| *a == Algorithm::CseFsl)
        .unwrap()
        .1;
    let mem_red = 1.0
        - heron.summary["peak_mem_bytes"] / cse.summary["peak_mem_bytes"];
    let flops_red =
        1.0 - heron.summary["client_flops"] / cse.summary["client_flops"];
    println!(
        "\nHERON vs CSE-FSL: peak memory -{:.0}%  client FLOPs -{:.0}%  \
         (paper: -64% / -33%)",
        mem_red * 100.0,
        flops_red * 100.0
    );
    println!(
        "accuracy parity: HERON best {heron_acc:.3} vs best FO {fo_best:.3}"
    );
    Ok(())
}
