//! Non-IID robustness scenario (paper Fig 3a at example scale): sweep the
//! Dirichlet concentration α and watch HERON-SFL track its FO counterpart
//! under increasing label skew.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneity
//! ```

use anyhow::Result;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::data::partition::{Partition, Scheme};
use heron_sfl::runtime::Session;

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds: usize = std::env::var("HET_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    // visualize what the partitioner does before training
    println!("label histograms at alpha=0.1 (10 clients, 2000 samples):");
    let p = Partition::vision(42, 2000, 10, Scheme::Dirichlet { alpha: 0.1 });
    for (i, h) in p.label_histograms(42).iter().enumerate().take(4) {
        println!("  client {i}: {h:?}");
    }
    println!("  ... (max client share {:.2})", p.max_share());

    println!(
        "\n{:<8} {:>14} {:>14}",
        "alpha", "HERON acc", "CSE-FSL acc"
    );
    for alpha in [0.1, 0.5, 10.0] {
        let mut row = vec![format!("{alpha}")];
        for alg in [Algorithm::Heron, Algorithm::CseFsl] {
            let cfg = RunConfig {
                variant: "cnn_c1".into(),
                algorithm: alg,
                n_clients: 5,
                rounds,
                local_steps: 2,
                lr_client: 2e-3,
                lr_server: 2e-3,
                mu: 1e-2,
                scheme: Scheme::Dirichlet { alpha },
                eval_every: rounds.max(1), // final eval only
                ..Default::default()
            };
            let mut driver = Driver::new(&session, cfg)?;
            let rec = driver.run(&format!("{}-a{alpha}", alg.name()))?;
            row.push(format!("{:.3}", rec.best_metric(true).unwrap_or(0.0)));
        }
        println!("{:<8} {:>14} {:>14}", row[0], row[1], row[2]);
    }
    println!(
        "\nExpected shape (paper Fig 3a): both methods degrade gracefully as \
         alpha shrinks,\nwith HERON tracking the FO baseline at every skew level."
    );
    Ok(())
}
