//! Edge LM fine-tuning scenario (paper §VI-C): LoRA-adapt the pretrained
//! GPT2-nano to the SynthE2E task under HERON-SFL vs SplitLoRA, comparing
//! perplexity against communication volume — the Fig 5 story at example
//! scale.
//!
//! ```bash
//! make artifacts && cargo run --release --example edge_finetune
//! ```

use anyhow::Result;
use heron_sfl::coordinator::accounting::fmt_bytes;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::data::synth_text;
use heron_sfl::metrics::sparkline;
use heron_sfl::runtime::Session;

fn main() -> Result<()> {
    heron_sfl::util::logging::init();
    let session = Session::open_default()?;
    let rounds: usize = std::env::var("FT_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    println!(
        "sample of the fine-tuning corpus:\n  {}\n  {}",
        synth_text::record(42, 0),
        synth_text::record(42, 1)
    );

    for alg in [Algorithm::Heron, Algorithm::SflV2, Algorithm::CseFsl] {
        let cfg = RunConfig {
            variant: "gpt2nano_c1_a1".into(),
            algorithm: alg,
            n_clients: 3,
            rounds,
            local_steps: 2,
            lr_client: 1e-3,
            lr_server: 1e-3,
            mu: 1e-2,
            n_pert: 1,
            dataset_size: 1536,
            ..Default::default()
        };
        let mut driver = Driver::new(&session, cfg)?;
        let rec = driver.run(alg.name())?;
        let ppl: Vec<f64> = rec
            .rounds
            .iter()
            .filter(|r| r.eval_metric.is_finite())
            .map(|r| r.eval_metric)
            .collect();
        println!(
            "\n{:<10} ppl {} {:.2} -> {:.2} | comm {} | peak mem {}",
            alg.name(),
            sparkline(&ppl, 32),
            ppl.first().unwrap(),
            ppl.last().unwrap(),
            fmt_bytes(rec.summary["comm_bytes"] as u64),
            fmt_bytes(rec.summary["peak_mem_bytes"] as u64),
        );
    }
    println!(
        "\nHERON fine-tunes with forward-only clients at inference-level \
         memory;\nSplitLoRA pays a per-batch server round-trip (training lock)."
    );
    Ok(())
}
