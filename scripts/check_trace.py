#!/usr/bin/env python3
"""Validate a heron-sfl `--trace_out` flight-recorder trace.

The file is Chrome trace-event JSON array format: `[` on the first line,
one comma-terminated event object per line, and — after a *clean*
shutdown — a final `trace_done` metadata event plus `]`, making the file
strict JSON. A trace cut short (crash, kill) is missing the closer but
every complete line is still valid JSON; Perfetto tolerates that, and so
does this checker (`--allow-truncated`).

Checks:
  * parses (strict JSON, or line-by-line when truncated)
  * every event has ph/pid/tid/ts/name; ph:"X" events also have dur
  * within one tid, end timestamps (ts + dur) are monotone non-decreasing
    in file order (events are pushed at span end)
  * the required span/instant names for the run mode are present
    (`--mode serve` / `--mode run`); instants (ph:"i") satisfy a
    requirement too — wire receives and queue waits are points, not spans
  * a clean trace ends with the `trace_done` metadata event and reports
    how many ring-buffer events were dropped

Usage: check_trace.py trace.json [--mode serve|run] [--allow-truncated]
Exits non-zero on any violation; prints a one-line summary on success.
"""

import json
import sys

REQUIRED = {
    "serve": ["round", "wire_send", "wire_recv", "server_consume"],
    "run": ["round", "local_phase", "zo_step", "server_consume"],
    # connect side of a serve run: the client's own phases + wire traffic
    "connect": ["client_round", "local_phase", "wire_send", "wire_recv"],
}


def load_events(path, allow_truncated):
    with open(path) as f:
        text = f.read()
    try:
        events = json.loads(text)
        if not isinstance(events, list):
            sys.exit(f"{path}: top-level JSON is not an array")
        return events, True
    except json.JSONDecodeError:
        pass
    # truncated trace: strip the array scaffolding and parse per line
    events = []
    for ln, line in enumerate(text.splitlines(), 1):
        t = line.strip().rstrip(",")
        if not t or t in "[]":
            continue
        try:
            events.append(json.loads(t))
        except json.JSONDecodeError as e:
            sys.exit(f"{path}:{ln}: unparseable event line: {e}")
    if not allow_truncated:
        sys.exit(f"{path}: not strict JSON (missing `]`?) — a clean "
                 f"shutdown closes the array; pass --allow-truncated for "
                 f"crash traces")
    return events, False


def main():
    argv = sys.argv[1:]
    mode = None
    if "--mode" in argv:
        i = argv.index("--mode")
        try:
            mode = argv[i + 1]
        except IndexError:
            sys.exit("--mode needs serve|run|connect")
        if mode not in REQUIRED:
            sys.exit(f"unknown --mode {mode!r} (serve|run|connect)")
        del argv[i:i + 2]
    allow_truncated = "--allow-truncated" in argv
    argv = [a for a in argv if a != "--allow-truncated"]
    if len(argv) != 1:
        sys.exit(__doc__)
    path = argv[0]

    events, closed = load_events(path, allow_truncated)
    if not events:
        sys.exit(f"{path}: no events")

    failures = []
    names = set()
    spans = instants = meta = 0
    last_end = {}  # tid -> last (ts + dur) seen, per phase class
    for i, e in enumerate(events):
        for key in ("ph", "pid", "tid", "ts", "name"):
            if key not in e:
                failures.append(f"event {i}: missing {key!r}: {e}")
                break
        else:
            ph = e["ph"]
            if ph == "X":
                spans += 1
                if "dur" not in e:
                    failures.append(f"event {i}: ph:X without dur: {e}")
                    continue
                names.add(e["name"])
                tid = e["tid"]
                end = e["ts"] + e["dur"]
                if end < last_end.get(tid, 0):
                    failures.append(
                        f"event {i}: tid {tid} end {end} precedes prior "
                        f"end {last_end[tid]} — rings emit at span end, "
                        f"so per-tid end times must be monotone")
                last_end[tid] = end
            elif ph == "i":
                instants += 1
                names.add(e["name"])
            elif ph == "M":
                meta += 1
        if len(failures) > 20:
            break

    if mode is not None:
        for want in REQUIRED[mode]:
            if want not in names:
                failures.append(
                    f"mode {mode}: required event name {want!r} absent "
                    f"(saw: {', '.join(sorted(names)) or 'none'})")

    done = [e for e in events
            if e.get("ph") == "M" and e.get("name") == "trace_done"]
    if closed and not done:
        failures.append("strict-JSON trace lacks the trace_done closer")
    dropped = done[0]["args"].get("dropped", 0) if done else 0
    if dropped:
        print(f"warning: {dropped} event(s) dropped by full ring buffers")

    if failures:
        print(f"{path}: INVALID trace:")
        for line in failures:
            print(f"  {line}")
        sys.exit(1)
    tids = {e["tid"] for e in events}
    print(f"OK: {path}: {spans} span(s), {instants} instant(s), "
          f"{meta} metadata event(s) over {len(tids)} track(s)"
          + ("" if closed else " [truncated]")
          + (f" [mode {mode}]" if mode else ""))


if __name__ == "__main__":
    main()
