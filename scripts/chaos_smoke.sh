#!/usr/bin/env bash
# Chaos smoke: the fault-tolerance gate over real localhost TCP
# (`rust/tests/chaos.rs` pins the same contracts in-process; this script
# is the kill -9 version). Three legs, one config:
#
#   leg A  reference   serve + 2 connect, no faults -> reference record
#   leg B  client kill kill -9 one connect mid-run; a replacement
#                      reconnects, takes over the dead lane block, and
#                      the server finishes every round, reporting the
#                      churn in typed summary keys (net_disconnects,
#                      clients_cut)
#   leg C  server kill kill -9 the server right after its first on-disk
#                      checkpoint; `serve --restore` with fresh clients
#                      finishes the run BIT-IDENTICAL to leg A
#                      (scripts/diff_net_metrics.py, exact float bits)
#
# Usage: chaos_smoke.sh <port> <out_dir>
set -euo pipefail

PORT=$1
OUT=$2
BIN=${BIN:-target/release/heron-sfl}
CONFIG=${CONFIG:-configs/heron_chaos.json}
mkdir -p "$OUT/ref" "$OUT/churn" "$OUT/restore"

# no port probe — the clients themselves retry until the server listens
retry_connect() {
  for _ in $(seq 1 120); do
    if "$BIN" connect --addr "127.0.0.1:$PORT" --name "$1"; then
      return 0
    fi
    sleep 1
  done
  return 1
}

wait_for_file() {
  for _ in $(seq 1 240); do
    if [ -f "$1" ]; then return 0; fi
    sleep 0.25
  done
  echo "timed out waiting for $1" >&2
  return 1
}

echo "== chaos leg A: uninterrupted reference =="
# the reference leg runs traced (--trace_out + per-round registry
# snapshots) — telemetry must not perturb the record leg C is later
# bit-diffed against, and the trace itself is schema-validated below
"$BIN" serve --config "$CONFIG" --listen "127.0.0.1:$PORT" --conns 2 \
  --trace_out "$OUT/ref/trace.json" --stats_every 1 \
  --out "$OUT/ref" &
SERVER=$!
retry_connect ref-0 &
C0=$!
retry_connect ref-1 &
C1=$!
wait "$C0" "$C1" "$SERVER"
python3 scripts/check_trace.py "$OUT/ref/trace.json" --mode serve

echo "== chaos leg B: kill -9 a client mid-run, a replacement rejoins =="
"$BIN" serve --config "$CONFIG" --listen "127.0.0.1:$PORT" --conns 2 \
  --checkpoint_every 1 --checkpoint_path "$OUT/churn/progress.ckpt" \
  --out "$OUT/churn" &
SERVER=$!
retry_connect steady &
C0=$!
# the doomed client gets no retry wrapper — it exists to be killed
"$BIN" connect --addr "127.0.0.1:$PORT" --name doomed &
DOOMED=$!
# round 1's checkpoint on disk == the run is well past the handshake
wait_for_file "$OUT/churn/progress.ckpt"
kill -9 "$DOOMED" 2>/dev/null || true
wait "$DOOMED" 2>/dev/null || true
# the replacement takes over the dead connection's lane block between
# rounds (Assign{rejoin_round, phases} fast-forwards its data streams)
retry_connect revived &
C2=$!
wait "$C0" "$C2" "$SERVER"
python3 - "$OUT/churn/serve.json" <<'EOF'
import json, sys
rec = json.load(open(sys.argv[1]))
s = rec["summary"]
assert s.get("net_disconnects", 0) >= 1, "the kill was never seen as churn"
assert s.get("clients_cut", 0) >= 1, "the dead lanes were never cut"
print(f"churn leg: {s['net_disconnects']:.0f} disconnect(s), "
      f"{s['clients_cut']:.0f} client slot(s) cut, "
      f"{len(rec['rounds'])} rounds finalized")
EOF

echo "== chaos leg C: kill -9 the server after a checkpoint, restore =="
rm -f "$OUT/restore/server.ckpt"
"$BIN" serve --config "$CONFIG" --listen "127.0.0.1:$PORT" --conns 2 \
  --checkpoint_every 1 --checkpoint_path "$OUT/restore/server.ckpt" &
SERVER=$!
( retry_connect first-0 || true ) &
C0=$!
( retry_connect first-1 || true ) &
C1=$!
wait_for_file "$OUT/restore/server.ckpt"
kill -9 "$SERVER" 2>/dev/null || true
wait "$SERVER" 2>/dev/null || true
# reap the first cohort before the restored server opens the port again,
# so the fresh clients are the only ones competing for the 2 slots
kill -9 "$C0" "$C1" 2>/dev/null || true
pkill -9 -f "connect --addr 127.0.0.1:$PORT" 2>/dev/null || true
wait "$C0" "$C1" 2>/dev/null || true
"$BIN" serve --config "$CONFIG" --listen "127.0.0.1:$PORT" --conns 2 \
  --restore "$OUT/restore/server.ckpt" --out "$OUT/restore" &
SERVER=$!
retry_connect second-0 &
C0=$!
retry_connect second-1 &
C1=$!
wait "$C0" "$C1" "$SERVER"
python3 scripts/diff_net_metrics.py \
  "$OUT/ref/serve.json" "$OUT/restore/serve.json"
echo "chaos smoke OK: churn survived, restore bit-identical"
