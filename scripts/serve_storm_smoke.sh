#!/usr/bin/env bash
# serve-storm smoke choreography: one `heron-sfl serve` + one `connect
# --virtual N` client multiplexing N virtual clients (protocol lanes)
# through a single localhost socket. Asserts the client reported every
# lane complete ("N/N lanes complete"); the bit-identity diff against an
# in-process run is the caller's job (diff_net_metrics.py --virtual N).
#
# Usage: serve_storm_smoke.sh <port> <out_dir> <virtual_lanes> [extra serve/run flags...]
set -euo pipefail

PORT=$1
OUT=$2
LANES=$3
shift 3

BIN=${BIN:-target/release/heron-sfl}
CONFIG=${CONFIG:-configs/net_smoke.json}

mkdir -p "$OUT"

"$BIN" serve --config "$CONFIG" "$@" \
  --listen "127.0.0.1:$PORT" --conns 1 --out "$OUT" &
SERVER=$!

# no port probe — the server treats any accepted socket as a client
# connection, so the client itself retries instead (same choreography as
# net_smoke.sh)
retry_connect() {
  for _ in $(seq 1 60); do
    if "$BIN" connect --addr "127.0.0.1:$PORT" --name mux-edge \
        --virtual "$LANES" | tee "$OUT/connect.log"; then
      return 0
    fi
    sleep 1
  done
  return 1
}

retry_connect
wait "$SERVER"

# every requested lane must have either run a local phase or owned no
# clients — a stuck lane fails the job here
grep -q "^${LANES}/${LANES} lanes complete$" "$OUT/connect.log"
echo "serve-storm smoke: ${LANES}/${LANES} lanes complete"
