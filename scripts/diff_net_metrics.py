#!/usr/bin/env python3
"""Diff an in-process run record against a networked (serve) record.

The bit-identity contract of heron-net: per-round train_loss, eval_metric,
and analytic comm_bytes_cum must match EXACTLY (float bit patterns
included), as must the analytic summary counters. Wall-clock fields and
measured wire counters are expected to differ and are reported, not
compared.

With --stream the networked run used `--drain stream` (arrival-order
mid-round consumption), which keeps the client side deterministic but
makes theta_s depend on arrival order. Train losses and the analytic
counters must STILL match bitwise (the client phase never reads
theta_s); eval_metric is compared within a tolerance instead, and the
event-sim must report a strictly lower stream makespan than barrier.

With --virtual N the networked run multiplexed N virtual clients
(protocol lanes) through its socket(s): the net record must report
exactly N lanes (summary key net_lanes) plus a net_conns count, and the
bit-identity checks above must hold regardless — lanes are a transport
detail, not a semantic one.

With --lossy F32_NET.json the networked run used a lossy payload codec
(e.g. `--codec int8`), so the analytic comm counters legitimately differ
from the f32 reference and eval drifts by quantization noise. The
client-phase surface must STILL match the reference bitwise (per-round
train_loss on the decoupled path plus the client_flops / peak_mem /
queue summary counters), eval_metric is tolerance-checked, and the
measured client->server wire bytes must sit STRICTLY below the f32
networked leg whose record is passed as the --lossy argument (the
server->client direction carries no codec'd payload on the decoupled
path and must not grow).

With --lean-downlink SEEDS_NET.json the networked run used `--zo_wire
seed_agg` (wire v7): the server broadcasts an aggregated SeedSync roster
instead of the dense theta_l and clients rebuild the model by seed
replay. Replay is bit-exact, so the FULL bit-identity contract applies
unchanged — and on top of it the measured server->client wire bytes must
sit STRICTLY below what the `--zo_wire seeds` leg (whose record is
passed as the argument) actually moved, since seeds mode still ships the
dense broadcast every round.

Usage: diff_net_metrics.py <inproc.json> <net.json> [--stream]
       [--virtual N] [--lossy F32_NET.json]
       [--lean-downlink SEEDS_NET.json]
Exits non-zero on any mismatch.
"""

import json
import struct
import sys

COMPARED_SUMMARY = ["comm_bytes", "client_flops", "peak_mem_bytes",
                    "queue_enqueued", "queue_dropped"]
EVAL_TOLERANCE = 0.05


def bits(x):
    """f64 bit pattern — exact comparison, NaN-safe."""
    return struct.pack("<d", float(x))


def main():
    argv = sys.argv[1:]
    virtual = None
    if "--virtual" in argv:
        i = argv.index("--virtual")
        try:
            virtual = int(argv[i + 1])
        except (IndexError, ValueError):
            sys.exit("--virtual needs an integer lane count")
        del argv[i:i + 2]
    lossy_ref = None
    if "--lossy" in argv:
        i = argv.index("--lossy")
        try:
            with open(argv[i + 1]) as f:
                lossy_ref = json.load(f)
        except (IndexError, OSError) as e:
            sys.exit(f"--lossy needs the f32 networked record: {e}")
        del argv[i:i + 2]
    lean_ref = None
    if "--lean-downlink" in argv:
        i = argv.index("--lean-downlink")
        try:
            with open(argv[i + 1]) as f:
                lean_ref = json.load(f)
        except (IndexError, OSError) as e:
            sys.exit(f"--lean-downlink needs the seeds-mode networked "
                     f"record: {e}")
        del argv[i:i + 2]
    args = [a for a in argv if a != "--stream"]
    stream = "--stream" in argv
    if len(args) != 2:
        sys.exit(__doc__)
    with open(args[0]) as f:
        a = json.load(f)
    with open(args[1]) as f:
        b = json.load(f)

    lossy = lossy_ref is not None
    # a lossy codec legitimately changes the analytic byte counters; the
    # rest of the client-phase surface stays bitwise
    round_bitwise = ("train_loss",) if lossy else ("train_loss",
                                                   "comm_bytes_cum")
    summary_bitwise = [k for k in COMPARED_SUMMARY
                       if not (lossy and k == "comm_bytes")]

    failures = []
    ra, rb = a["rounds"], b["rounds"]
    if len(ra) != len(rb):
        failures.append(f"round count: {len(ra)} vs {len(rb)}")
    for i, (x, y) in enumerate(zip(ra, rb)):
        for key in round_bitwise:
            if bits(x[key]) != bits(y[key]):
                failures.append(
                    f"round {i} {key}: {x[key]!r} vs {y[key]!r}")
        if lossy:
            # quantized smashed uploads perturb theta_s and therefore
            # eval; the client phase they never touch stays bitwise
            if abs(x["eval_metric"] - y["eval_metric"]) > EVAL_TOLERANCE:
                failures.append(
                    f"round {i} eval_metric: {x['eval_metric']!r} vs "
                    f"{y['eval_metric']!r} (tolerance {EVAL_TOLERANCE})")
        elif stream:
            # theta_s absorbs batches in arrival order: eval (which
            # reads theta_s) is tolerance-checked, not bit-diffed
            if abs(x["eval_metric"] - y["eval_metric"]) > EVAL_TOLERANCE:
                failures.append(
                    f"round {i} eval_metric: {x['eval_metric']!r} vs "
                    f"{y['eval_metric']!r} (tolerance {EVAL_TOLERANCE})")
        elif bits(x["eval_metric"]) != bits(y["eval_metric"]):
            failures.append(
                f"round {i} eval_metric: {x['eval_metric']!r} vs "
                f"{y['eval_metric']!r}")
    for key in summary_bitwise:
        x, y = a["summary"].get(key), b["summary"].get(key)
        if x is None or y is None or bits(x) != bits(y):
            failures.append(f"summary {key}: {x!r} vs {y!r}")

    if virtual is not None:
        # multiplexed run: the dispatcher records how many protocol lanes
        # the cohort rode in on — every lane of the requested fan-out must
        # have registered, over however many sockets were used
        lanes = b["summary"].get("net_lanes")
        conns = b["summary"].get("net_conns")
        if lanes != virtual:
            failures.append(
                f"summary net_lanes: {lanes!r} vs requested {virtual}")
        if not conns or conns <= 0 or conns > virtual:
            failures.append(
                f"summary net_conns: {conns!r} (want 1..{virtual})")
        else:
            print(f"multiplexed: {lanes} virtual clients over "
                  f"{conns:.0f} socket(s)")

    wire_sent = b["summary"].get("wire_bytes_sent", 0)
    wire_recv = b["summary"].get("wire_bytes_recv", 0)
    if lossy:
        # the codec's whole point, measured: fewer client->server bytes
        # than the f32 leg actually moved
        ref_sent = lossy_ref["summary"].get("wire_bytes_sent", 0)
        ref_recv = lossy_ref["summary"].get("wire_bytes_recv", 0)
        if not 0 < wire_recv < ref_recv:
            failures.append(
                f"lossy client->server bytes {wire_recv:.0f} not strictly"
                f" below the f32 leg's {ref_recv:.0f}")
        if wire_sent > ref_sent:
            failures.append(
                f"lossy server->client bytes {wire_sent:.0f} grew past "
                f"the f32 leg's {ref_sent:.0f}")
        else:
            print(f"lossy wire bytes vs f32 leg: recv {wire_recv:.0f} < "
                  f"{ref_recv:.0f}, sent {wire_sent:.0f} <= {ref_sent:.0f}")
    if lean_ref is not None:
        # the dimension-free broadcast's whole point, measured: fewer
        # server->client bytes than the seeds-mode leg actually moved
        # (seeds mode keeps the uplink lean but still broadcasts dense)
        ref_sent = lean_ref["summary"].get("wire_bytes_sent", 0)
        if not 0 < wire_sent < ref_sent:
            failures.append(
                f"seed_agg server->client bytes {wire_sent:.0f} not "
                f"strictly below the seeds leg's {ref_sent:.0f}")
        else:
            print(f"lean downlink vs seeds leg: sent {wire_sent:.0f} < "
                  f"{ref_sent:.0f}")
        # when the server ran with metrics armed (--stats_every /
        # --trace_out) the record also carries the downlink counters —
        # a broadcast that saved nothing means SeedSync never happened
        saved = b["summary"].get("net.downlink.bytes_saved")
        if saved is not None and saved <= 0:
            failures.append(
                f"net.downlink.bytes_saved: {saved!r} (want > 0)")
    if stream:
        # the pipelining must have actually happened: arrivals recorded,
        # simulated stream schedule strictly below the barrier schedule
        mk_b = b["summary"].get("server_makespan_barrier_seconds", 0)
        mk_s = b["summary"].get("server_makespan_stream_seconds", 0)
        if not (0 < mk_s < mk_b):
            failures.append(
                f"stream makespan {mk_s} must be strictly below barrier "
                f"makespan {mk_b}")
        if wire_recv <= 0:
            failures.append("stream run moved no client->server bytes")
        print(f"stream vs barrier simulated server makespan: "
              f"{mk_s:.3f}s vs {mk_b:.3f}s")
    print(f"compared {len(ra)} rounds + {len(summary_bitwise)} summary keys"
          + (" [--stream tolerances]" if stream else "")
          + (" [--lossy codec tolerances]" if lossy else ""))
    print(f"analytic comm_bytes: {a['summary'].get('comm_bytes'):.0f}")
    print(f"measured wire bytes (networked run): "
          f"{wire_sent:.0f} sent / {wire_recv:.0f} recv")

    if failures:
        print("\nMISMATCH — networked run diverged from in-process run:")
        for line in failures:
            print(f"  {line}")
        sys.exit(1)
    if lossy:
        print("OK: lossy-codec run matches the reference on every "
              "client-phase surface (losses + counters bitwise, eval "
              "within tolerance, measured upload strictly below f32)")
    elif lean_ref is not None:
        print("OK: seed_agg run is bit-identical to in-process AND its "
              "measured downlink sits strictly below the seeds leg's")
    elif stream:
        print("OK: stream run matches the reference on every "
              "deterministic surface (client side bitwise, eval within "
              "tolerance, makespan strictly lower)")
    else:
        print("OK: networked trajectory is bit-identical to in-process")


if __name__ == "__main__":
    main()
