#!/usr/bin/env python3
"""Diff an in-process run record against a networked (serve) record.

The bit-identity contract of heron-net: per-round train_loss, eval_metric,
and analytic comm_bytes_cum must match EXACTLY (float bit patterns
included), as must the analytic summary counters. Wall-clock fields and
measured wire counters are expected to differ and are reported, not
compared.

Usage: diff_net_metrics.py <inproc.json> <net.json>
Exits non-zero on any mismatch.
"""

import json
import struct
import sys

COMPARED_SUMMARY = ["comm_bytes", "client_flops", "peak_mem_bytes",
                    "queue_enqueued", "queue_dropped"]


def bits(x):
    """f64 bit pattern — exact comparison, NaN-safe."""
    return struct.pack("<d", float(x))


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    with open(sys.argv[1]) as f:
        a = json.load(f)
    with open(sys.argv[2]) as f:
        b = json.load(f)

    failures = []
    ra, rb = a["rounds"], b["rounds"]
    if len(ra) != len(rb):
        failures.append(f"round count: {len(ra)} vs {len(rb)}")
    for i, (x, y) in enumerate(zip(ra, rb)):
        for key in ("train_loss", "eval_metric", "comm_bytes_cum"):
            if bits(x[key]) != bits(y[key]):
                failures.append(
                    f"round {i} {key}: {x[key]!r} vs {y[key]!r}")
    for key in COMPARED_SUMMARY:
        x, y = a["summary"].get(key), b["summary"].get(key)
        if x is None or y is None or bits(x) != bits(y):
            failures.append(f"summary {key}: {x!r} vs {y!r}")

    wire_sent = b["summary"].get("wire_bytes_sent", 0)
    wire_recv = b["summary"].get("wire_bytes_recv", 0)
    print(f"compared {len(ra)} rounds + {len(COMPARED_SUMMARY)} summary keys")
    print(f"analytic comm_bytes: {a['summary'].get('comm_bytes'):.0f}")
    print(f"measured wire bytes (networked run): "
          f"{wire_sent:.0f} sent / {wire_recv:.0f} recv")

    if failures:
        print("\nMISMATCH — networked run diverged from in-process run:")
        for line in failures:
            print(f"  {line}")
        sys.exit(1)
    print("OK: networked trajectory is bit-identical to in-process")


if __name__ == "__main__":
    main()
