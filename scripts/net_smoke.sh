#!/usr/bin/env bash
# One networked smoke run: `heron-sfl serve` + 2 `connect` client
# processes over localhost TCP. Shared by the CI net-smoke legs (theta
# and --zo_wire seeds) so the retry/wait choreography lives in one place.
#
# Usage: net_smoke.sh <port> <out_dir> [extra serve/run flags...]
#
# TRACE_DIR=dir — additionally record flight-recorder traces: the server
# writes $TRACE_DIR/serve_trace.json (with --stats_every 1 snapshots in
# its log) and the first client writes $TRACE_DIR/connect_trace.json,
# both Chrome trace-event JSON for scripts/check_trace.py / Perfetto.
set -euo pipefail

PORT=$1
OUT=$2
shift 2

BIN=${BIN:-target/release/heron-sfl}
CONFIG=${CONFIG:-configs/net_smoke.json}
TRACE_DIR=${TRACE_DIR:-}

SERVE_TRACE=()
if [ -n "$TRACE_DIR" ]; then
  mkdir -p "$TRACE_DIR"
  SERVE_TRACE=(--trace_out "$TRACE_DIR/serve_trace.json" --stats_every 1)
fi

"$BIN" serve --config "$CONFIG" "$@" ${SERVE_TRACE[@]+"${SERVE_TRACE[@]}"} \
  --listen "127.0.0.1:$PORT" --conns 2 --out "$OUT" &
SERVER=$!

# no port probe — the server treats any accepted socket as a client
# connection, so the clients themselves retry instead (a refused attempt
# truncates its trace file; the successful attempt rewrites it whole)
retry_connect() {
  for _ in $(seq 1 60); do
    if "$BIN" connect --addr "127.0.0.1:$PORT" --name "$1" "${@:2}"; then
      return 0
    fi
    sleep 1
  done
  return 1
}

if [ -n "$TRACE_DIR" ]; then
  retry_connect edge-0 --trace_out "$TRACE_DIR/connect_trace.json" &
else
  retry_connect edge-0 &
fi
C0=$!
retry_connect edge-1 &
C1=$!
wait "$C0" "$C1" "$SERVER"
