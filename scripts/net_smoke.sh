#!/usr/bin/env bash
# One networked smoke run: `heron-sfl serve` + 2 `connect` client
# processes over localhost TCP. Shared by the CI net-smoke legs (theta
# and --zo_wire seeds) so the retry/wait choreography lives in one place.
#
# Usage: net_smoke.sh <port> <out_dir> [extra serve/run flags...]
set -euo pipefail

PORT=$1
OUT=$2
shift 2

BIN=${BIN:-target/release/heron-sfl}
CONFIG=${CONFIG:-configs/net_smoke.json}

"$BIN" serve --config "$CONFIG" "$@" \
  --listen "127.0.0.1:$PORT" --conns 2 --out "$OUT" &
SERVER=$!

# no port probe — the server treats any accepted socket as a client
# connection, so the clients themselves retry instead
retry_connect() {
  for _ in $(seq 1 60); do
    if "$BIN" connect --addr "127.0.0.1:$PORT" --name "$1"; then
      return 0
    fi
    sleep 1
  done
  return 1
}

retry_connect edge-0 &
C0=$!
retry_connect edge-1 &
C1=$!
wait "$C0" "$C1" "$SERVER"
