//! Client-side local phase (substrate S10b): the per-client state and the
//! decoupled/locked step loops, shared by the in-process round driver
//! (`coordinator::round`) and the networked client endpoint
//! (`net::client`).
//!
//! The functions here are the *single* implementation of what a client
//! does between two model syncs. Both execution modes call them with the
//! same inputs in the same order, which is what makes a TCP-loopback run
//! bit-identical to `Driver::run_round`:
//!
//! * per-step randomness is `step_seed(run_seed, round, client, step)` —
//!   no ambient RNG, so it does not matter which process computes it;
//! * every entry invocation goes through the same `Session` code path
//!   (`invoke_into` on the hot loop, `Call` on the cold locked exchange);
//! * smashed uploads leave through the [`SmashedSink`] abstraction — the
//!   in-process sink is the Main-Server's [`ServerQueue`], the networked
//!   sink encodes a `SmashedBatch` wire message — and the server re-sorts
//!   by `(round, client, step)` either way.

use crate::coordinator::accounting::CostBook;
use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::config::RunConfig;
use crate::coordinator::eventsim::{ClientLane, DeviceProfile};
use crate::coordinator::round::OptState;
use crate::coordinator::server_queue::{ServerQueue, SmashedBatch};
use crate::data::loader::{Loader, Task};
use crate::data::partition::Partition;
use crate::runtime::manifest::{EntrySpec, VariantSpec};
use crate::runtime::tensor::{TensorRef, TensorValue};
use crate::runtime::{Call, Session};
use crate::util::rng::mix64;
use anyhow::{bail, Context, Result};

/// Everything a client owns across rounds: its data shard's loader, its
/// optimizer states, and the last uploaded batch (FSL-SAGE alignment).
pub struct ClientState {
    pub loader: Loader,
    pub opt_local: OptState,
    /// SFLV1/V2: separate optimizer for θ_c-only backprop updates
    pub opt_client: OptState,
    pub shard_weight: f64,
    /// last uploaded batch (FSL-SAGE alignment needs it)
    pub last_upload: Option<(Vec<f32>, Vec<i32>, Vec<i32>)>, // smashed, y, x
}

/// Build the full client-state table for a run. Deterministic in
/// `(variant, cfg)` — the driver and every networked client process build
/// byte-identical loaders/partitions from the same config, so a remote
/// client stepping its own state produces the exact trajectory the
/// in-process run would have.
pub fn build_client_states(
    v: &VariantSpec,
    cfg: &RunConfig,
    task: Task,
) -> Vec<ClientState> {
    let (nc, nl) = (v.size_client, v.size_local());
    let part = match task {
        Task::Vision => Partition::vision(
            cfg.data_seed,
            cfg.dataset_size,
            cfg.n_clients,
            cfg.scheme,
        ),
        Task::Lm => Partition::text(
            cfg.data_seed,
            cfg.dataset_size,
            cfg.n_clients,
            cfg.scheme,
        ),
    };
    let total: usize = part.sizes().iter().sum();
    part.clients
        .iter()
        .enumerate()
        .map(|(i, shard)| {
            let shard = if shard.is_empty() {
                vec![(i as u64) % cfg.dataset_size] // degenerate shard fallback
            } else {
                shard.clone()
            };
            let w = shard.len() as f64 / total.max(1) as f64;
            ClientState {
                loader: Loader::new(
                    task,
                    cfg.data_seed,
                    shard,
                    v.batch,
                    mix64(cfg.run_seed, 0x10AD ^ i as u64),
                ),
                opt_local: OptState::new(v.opt_state, nl),
                opt_client: OptState::new(v.opt_state, nc),
                shard_weight: w,
                last_upload: None,
            }
        })
        .collect()
}

/// Read-only context shared by all client worker threads (or remote
/// client processes) during the decoupled fan-out phase.
pub struct LocalCtx<'a> {
    pub session: &'a Session,
    pub cfg: &'a RunConfig,
    pub book: &'a CostBook,
    pub base: Option<&'a [f32]>,
    pub task: Task,
    pub round_idx: usize,
    pub profile: DeviceProfile,
    pub nc: usize,
}

/// What one client's local phase produces, merged at the round barrier in
/// participant order.
pub struct LocalOutcome {
    pub ci: usize,
    pub theta: Vec<f32>,
    pub losses: Vec<f64>,
    /// per-step ZO seeds (the lean `ZoUpdate` wire record; FO algorithms
    /// carry the same counter-derived stream positions)
    pub seeds: Vec<i32>,
    pub comm_bytes: u64,
    pub flops: u64,
    pub lane: ClientLane,
}

/// Where a client's smashed uploads go. In-process this is the
/// Main-Server's [`ServerQueue`]; over the network it is a framed
/// `SmashedBatch` message (acknowledged, so capacity drops surface as
/// typed NACKs). Returns `false` when the batch was dropped.
pub trait SmashedSink: Sync {
    fn push_smashed(&self, batch: SmashedBatch) -> bool;
}

impl SmashedSink for ServerQueue {
    fn push_smashed(&self, batch: SmashedBatch) -> bool {
        self.push(batch)
    }
}

pub fn loader_batch_xy(task: Task, loader: &Loader) -> (TensorValue, Vec<i32>) {
    match task {
        Task::Vision => (
            TensorValue::F32(loader.xs_f32.clone()),
            loader.ys.clone(),
        ),
        Task::Lm => (
            TensorValue::I32(loader.xs_i32.clone()),
            loader.xs_i32.clone(),
        ),
    }
}

pub fn step_seed(cfg: &RunConfig, round_idx: usize, client: usize, step: usize) -> i32 {
    mix64(
        cfg.run_seed,
        (round_idx as u64) << 24 | (client as u64) << 12 | step as u64,
    ) as i32
}

/// Borrow the loader's reused batch buffer as the entry's `x` input.
fn x_ref(task: Task, loader: &Loader) -> TensorRef<'_> {
    match task {
        Task::Vision => TensorRef::F32(&loader.xs_f32),
        Task::Lm => TensorRef::I32(&loader.xs_i32),
    }
}

/// Borrow the loader's target buffer (LM entries take the token batch).
fn y_slice(task: Task, loader: &Loader) -> &[i32] {
    match task {
        Task::Vision => &loader.ys,
        Task::Lm => &loader.xs_i32,
    }
}

/// Build the positional input list for `espec` from named borrowed
/// buffers. Scalars travel by value; a spec input with no binding (e.g.
/// optimizer-state tensors the native manifest never emits) is an error.
pub fn bind_entry_inputs<'a>(
    espec: &EntrySpec,
    named: &[(&str, TensorRef<'a>)],
) -> Result<Vec<TensorRef<'a>>> {
    let mut out = Vec::with_capacity(espec.inputs.len());
    for spec in &espec.inputs {
        let r = named
            .iter()
            .find(|(n, _)| *n == spec.name)
            .map(|(_, r)| *r)
            .with_context(|| {
                format!("{}: no binding for input {}", espec.name, spec.name)
            })?;
        out.push(r);
    }
    Ok(out)
}

/// One client's full local phase (h steps + uploads), self-contained so it
/// can run on any worker thread or in a remote client process. Mutates
/// only this client's state; all cross-client effects go through the
/// smashed sink and the returned outcome.
///
/// The loop is allocation-lean: every input is a borrowed view (θ, the
/// loader's batch buffers, the frozen base), outputs land in the two
/// scratch arenas below, and the updated θ is swapped out of its slot —
/// the same two parameter buffers ping-pong through all h steps.
pub fn client_local_phase(
    ctx: &LocalCtx,
    ci: usize,
    cs: &mut ClientState,
    mut theta: Vec<f32>,
    sink: &dyn SmashedSink,
) -> Result<LocalOutcome> {
    let mut lane = ClientLane::new(&ctx.profile);
    let mut losses = Vec::with_capacity(ctx.cfg.local_steps);
    let mut seeds = Vec::with_capacity(ctx.cfg.local_steps);
    let mut comm_bytes = 0u64;
    let mut flops = 0u64;
    let zo = ctx.cfg.algorithm == Algorithm::Heron;
    let entry = if zo { "zo_step" } else { "fo_step" };
    if !matches!(cs.opt_local, OptState::None) {
        bail!(
            "local phase: stateful optimizers are not wired through the \
             native entries (manifest opt_state must be 0)"
        );
    }
    let vspec = ctx.session.variant(&ctx.cfg.variant)?;
    let step_espec = vspec.entry(entry)?;
    let fwd_espec = vspec.entry("client_fwd")?;
    let ti = step_espec.output_pos("theta_l")?;
    let li = step_espec.output_pos("loss")?;
    let si = fwd_espec.output_pos("smashed")?;
    // per-client scratch arenas, reused across all h steps
    let mut outs: Vec<TensorValue> = Vec::new();
    let mut fwd_outs: Vec<TensorValue> = Vec::new();

    for step in 1..=ctx.cfg.local_steps {
        cs.loader.next_batch();
        let seed = step_seed(ctx.cfg, ctx.round_idx, ci, step);
        seeds.push(seed);
        let mut named: Vec<(&str, TensorRef)> = Vec::with_capacity(8);
        if let Some(b) = ctx.base {
            named.push(("base", TensorRef::F32(b)));
        }
        named.push(("theta_l", TensorRef::F32(&theta)));
        named.push(("x", x_ref(ctx.task, &cs.loader)));
        named.push(("y", TensorRef::I32(y_slice(ctx.task, &cs.loader))));
        named.push(("lr", TensorRef::ScalarF32(ctx.cfg.lr_client)));
        if zo {
            named.push(("seed", TensorRef::ScalarI32(seed)));
            named.push(("mu", TensorRef::ScalarF32(ctx.cfg.mu)));
            named.push((
                "n_pert",
                TensorRef::ScalarI32(ctx.cfg.n_pert as i32),
            ));
        }
        let inputs = bind_entry_inputs(step_espec, &named)?;
        ctx.session
            .invoke_into(&ctx.cfg.variant, entry, &inputs, &mut outs)?;
        match &mut outs[ti] {
            TensorValue::F32(v) => std::mem::swap(&mut theta, v),
            other => bail!(
                "{entry}: theta_l output has wrong dtype {:?}",
                other.dtype()
            ),
        }
        losses.push(outs[li].scalar_f32()? as f64);
        flops += ctx.book.flops_per_step;
        lane.compute(ctx.book.flops_per_step);

        if step % ctx.cfg.upload_every == 0 {
            upload_smashed(
                ctx,
                ci,
                cs,
                &theta,
                fwd_espec,
                si,
                step,
                sink,
                &mut lane,
                &mut comm_bytes,
                &mut fwd_outs,
            )?;
        }
    }
    Ok(LocalOutcome {
        ci,
        theta,
        losses,
        seeds,
        comm_bytes,
        flops,
        lane,
    })
}

fn upload_smashed(
    ctx: &LocalCtx,
    ci: usize,
    cs: &mut ClientState,
    theta: &[f32],
    fwd_espec: &EntrySpec,
    smashed_idx: usize,
    step: usize,
    sink: &dyn SmashedSink,
    lane: &mut ClientLane,
    comm_bytes: &mut u64,
    fwd_outs: &mut Vec<TensorValue>,
) -> Result<()> {
    let mut named: Vec<(&str, TensorRef)> = Vec::with_capacity(3);
    if let Some(b) = ctx.base {
        named.push(("base", TensorRef::F32(b)));
    }
    named.push(("theta_c", TensorRef::F32(&theta[..ctx.nc])));
    named.push(("x", x_ref(ctx.task, &cs.loader)));
    let inputs = bind_entry_inputs(fwd_espec, &named)?;
    ctx.session.invoke_into(
        &ctx.cfg.variant,
        "client_fwd",
        &inputs,
        fwd_outs,
    )?;
    // the sink owns the smashed batch, so move it out of its slot (the
    // slot re-grows a buffer on the next upload)
    let smashed = match std::mem::replace(
        &mut fwd_outs[smashed_idx],
        TensorValue::ScalarF32(0.0),
    ) {
        TensorValue::F32(v) => v,
        other => bail!(
            "client_fwd: smashed output has wrong dtype {:?}",
            other.dtype()
        ),
    };
    // the upload forward is part of the protocol but NOT an extra
    // training cost in Table I (the paper's accounting charges the ZO /
    // FO step); we still charge its flops to the client sim for latency
    lane.compute(
        (ctx.book.flops_per_step / (ctx.cfg.n_pert as u64 + 1)).max(1),
    );
    *comm_bytes += ctx.book.comm_per_step(true);
    lane.upload(ctx.book.smashed_bytes);
    let targets = y_slice(ctx.task, &cs.loader).to_vec();
    // only the FSL-SAGE alignment ever reads last_upload — don't pay a
    // full smashed-batch copy per upload on the other algorithms
    if ctx.cfg.algorithm == Algorithm::FslSage {
        let x_i32 = match ctx.task {
            Task::Lm => cs.loader.xs_i32.clone(),
            Task::Vision => Vec::new(),
        };
        cs.last_upload =
            Some((smashed.clone(), targets.clone(), x_i32));
    }
    sink.push_smashed(SmashedBatch {
        client: ci,
        round: ctx.round_idx,
        step,
        smashed,
        targets,
    });
    Ok(())
}

// ---------------------------------------------------------------------------
// locked exchange (SFLV1/V2) — client half
// ---------------------------------------------------------------------------

/// Client forward to the cut layer on the loader's current batch.
/// Returns the smashed activations (cold `Call` path — the locked
/// exchange is the baselines' bottleneck by design, not ours).
pub fn locked_client_fwd(
    session: &Session,
    variant: &str,
    base: Option<&[f32]>,
    theta_c: &[f32],
    x: &TensorValue,
) -> Result<Vec<f32>> {
    let mut c = Call::new(session, variant, "client_fwd");
    if let Some(b) = base {
        c = c.arg("base", b.to_vec());
    }
    let mut outs = c
        .arg("theta_c", theta_c.to_vec())
        .arg("x", x.clone())
        .run()?;
    outs.remove("smashed").context("smashed")?.into_f32()
}

/// Client backprop step from the relayed cut gradient. Returns the
/// updated θ_c and threads the client optimizer state.
pub fn locked_client_bp(
    session: &Session,
    variant: &str,
    base: Option<&[f32]>,
    theta_c: &[f32],
    opt_c: &mut OptState,
    x: TensorValue,
    g_smashed: Vec<f32>,
    lr: f32,
) -> Result<Vec<f32>> {
    let mut c = Call::new(session, variant, "client_bp_step");
    if let Some(b) = base {
        c = c.arg("base", b.to_vec());
    }
    c = c.arg("theta_c", theta_c.to_vec());
    if let OptState::Adam { m, v, t } = &*opt_c {
        c = c
            .arg("opt_m", m.clone())
            .arg("opt_v", v.clone())
            .arg("opt_t", *t);
    }
    let mut outs = c
        .arg("x", x)
        .arg("g_smashed", g_smashed)
        .arg("lr", lr)
        .run()?;
    let new_c = outs
        .remove("theta_c")
        .context("bp theta_c")?
        .into_f32()?;
    take_opt(&mut outs, opt_c)?;
    Ok(new_c)
}

/// FSL-SAGE: realign the aux head of `theta` against the server's cut
/// gradient for the client's last uploaded batch. Runs on whichever
/// process holds `last_upload` (the driver in-process, the remote client
/// over the wire) — same entry, same inputs, same bits.
pub fn aux_align_apply(
    session: &Session,
    variant: &str,
    base: Option<&[f32]>,
    theta: Vec<f32>,
    smashed: Vec<f32>,
    y: Vec<i32>,
    g_smashed: Vec<f32>,
    lr: f32,
) -> Result<Vec<f32>> {
    let mut c = Call::new(session, variant, "aux_align");
    if let Some(b) = base {
        c = c.arg("base", b.to_vec());
    }
    let mut outs = c
        .arg("theta_l", theta)
        .arg("smashed", smashed)
        .arg("y", TensorValue::I32(y))
        .arg("g_smashed", g_smashed)
        .arg("lr", lr)
        .run()?;
    outs.remove("theta_l")
        .context("aux_align theta_l")?
        .into_f32()
}

/// Thread Adam state out of an entry's outputs (no-op for `OptState::None`).
pub fn take_opt(
    outs: &mut std::collections::HashMap<String, TensorValue>,
    opt: &mut OptState,
) -> Result<()> {
    if let OptState::Adam { m, v, t } = opt {
        *m = outs
            .remove("opt_m")
            .context("opt_m output")?
            .into_f32()?;
        *v = outs
            .remove("opt_v")
            .context("opt_v output")?
            .into_f32()?;
        *t = outs
            .remove("opt_t")
            .context("opt_t output")?
            .scalar_f32()?;
    }
    Ok(())
}
