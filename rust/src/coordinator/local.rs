//! Client-side local phase (substrate S10b): the per-client state and the
//! decoupled/locked step loops, shared by the in-process round driver
//! (`coordinator::round`) and the networked client endpoint
//! (`net::client`).
//!
//! The functions here are the *single* implementation of what a client
//! does between two model syncs. Both execution modes call them with the
//! same inputs in the same order, which is what makes a TCP-loopback run
//! bit-identical to `Driver::run_round`:
//!
//! * per-step randomness is `step_seed(run_seed, round, client, step)` —
//!   no ambient RNG, so it does not matter which process computes it;
//! * every model call goes through the typed
//!   [`crate::runtime::api::ClientRuntime`] surface resolved from the
//!   same `Session` — no entry-name strings, no per-call argument
//!   marshalling, and the ZO step hands back its per-probe
//!   [`ZoStepRecord`] (the lean `--zo_wire seeds` upload);
//! * smashed uploads leave through the [`SmashedSink`] abstraction — the
//!   in-process sink is the Main-Server's [`ServerQueue`], the networked
//!   sink encodes a `SmashedBatch` wire message — and the server re-sorts
//!   by `(round, client, step)` either way.

use crate::coordinator::accounting::CostBook;
use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::config::RunConfig;
use crate::coordinator::eventsim::{ClientLane, DeviceProfile};
use crate::coordinator::round::OptState;
use crate::coordinator::server_queue::{ServerQueue, SmashedBatch};
use crate::data::loader::{Loader, Task};
use crate::data::partition::Partition;
use crate::runtime::api::{ClientRuntime, ZoArgs, ZoStepRecord};
use crate::runtime::manifest::VariantSpec;
use crate::runtime::tensor::{TensorRef, TensorValue};
use crate::runtime::Session;
use crate::util::rng::mix64;
use anyhow::{bail, Result};

/// Everything a client owns across rounds: its data shard's loader, its
/// optimizer states, and the last uploaded batch (FSL-SAGE alignment).
pub struct ClientState {
    pub loader: Loader,
    pub opt_local: OptState,
    /// SFLV1/V2: separate optimizer for θ_c-only backprop updates
    pub opt_client: OptState,
    pub shard_weight: f64,
    /// last uploaded batch (FSL-SAGE alignment needs it)
    pub last_upload: Option<(Vec<f32>, Vec<i32>, Vec<i32>)>, // smashed, y, x
}

/// Lazily-materialized client-state table: the partition and per-client
/// shard weights are computed up front (index lists and `f64`s —
/// independent of the model size), but the model-sized [`ClientState`]
/// (loader batch buffers, optimizer slots) is built only when a client
/// first participates. Round state is therefore O(clients that ever ran)
/// — O(cohort · rounds seen) — not O(registered population), which is
/// what lets an orchestrator register a large population and sample a
/// small per-round cohort from it.
///
/// Materialization is deterministic in `(variant, cfg, client id)` and
/// independent of *when* it happens: a client built lazily in round 9 is
/// byte-identical to one built eagerly at startup, because a loader only
/// advances when that client steps. [`build_client_states`] is the eager
/// wrapper over the same construction, so the two paths cannot diverge.
pub struct ClientPool {
    task: Task,
    batch: usize,
    nc: usize,
    nl: usize,
    opt_state: usize,
    data_seed: u64,
    run_seed: u64,
    dataset_size: u64,
    /// per-client dataset shards from the partition (index lists)
    shards: Vec<Vec<u64>>,
    /// per-client FedAvg weights (population-sized, but 8 B each)
    weights: Vec<f64>,
    states: std::collections::BTreeMap<usize, ClientState>,
}

impl ClientPool {
    pub fn new(v: &VariantSpec, cfg: &RunConfig, task: Task) -> Self {
        let part = match task {
            Task::Vision => Partition::vision(
                cfg.data_seed,
                cfg.dataset_size,
                cfg.n_clients,
                cfg.scheme,
            ),
            Task::Lm => Partition::text(
                cfg.data_seed,
                cfg.dataset_size,
                cfg.n_clients,
                cfg.scheme,
            ),
        };
        let total: usize = part.sizes().iter().sum();
        let weights: Vec<f64> = part
            .clients
            .iter()
            .map(|shard| shard.len() as f64 / total.max(1) as f64)
            .collect();
        Self {
            task,
            batch: v.batch,
            nc: v.size_client,
            nl: v.size_local(),
            opt_state: v.opt_state,
            data_seed: cfg.data_seed,
            run_seed: cfg.run_seed,
            dataset_size: cfg.dataset_size,
            shards: part.clients,
            weights,
            states: std::collections::BTreeMap::new(),
        }
    }

    /// Registered population size.
    pub fn n(&self) -> usize {
        self.shards.len()
    }

    /// FedAvg weight for a client — never materializes its state.
    pub fn shard_weight(&self, ci: usize) -> f64 {
        self.weights[ci]
    }

    /// Number of client states actually materialized so far (the
    /// O(cohort) claim made observable: a networked orchestrator keeps
    /// this at zero, an in-process run at the number of distinct
    /// participants).
    pub fn built(&self) -> usize {
        self.states.len()
    }

    fn build_state(&self, i: usize) -> ClientState {
        let shard = if self.shards[i].is_empty() {
            vec![(i as u64) % self.dataset_size] // degenerate shard fallback
        } else {
            self.shards[i].clone()
        };
        ClientState {
            loader: Loader::new(
                self.task,
                self.data_seed,
                shard,
                self.batch,
                mix64(self.run_seed, 0x10AD ^ i as u64),
            ),
            opt_local: OptState::new(self.opt_state, self.nl),
            opt_client: OptState::new(self.opt_state, self.nc),
            shard_weight: self.weights[i],
            last_upload: None,
        }
    }

    /// This client's state, materialized on first use.
    pub fn state(&mut self, ci: usize) -> &mut ClientState {
        if !self.states.contains_key(&ci) {
            let s = self.build_state(ci);
            self.states.insert(ci, s);
        }
        self.states.get_mut(&ci).expect("just inserted")
    }

    /// Materialize every listed client, then hand out disjoint mutable
    /// borrows in ascending client order (the fan-out job order — the
    /// same ascending order the eager `Vec` enumeration produced).
    pub fn states_for(
        &mut self,
        clients: &[usize],
    ) -> Vec<(usize, &mut ClientState)> {
        for &ci in clients {
            self.state(ci);
        }
        self.states
            .iter_mut()
            .filter(|(ci, _)| clients.binary_search(ci).is_ok())
            .map(|(&ci, s)| (ci, s))
            .collect()
    }
}

/// Build the full client-state table for a run, eagerly. Deterministic in
/// `(variant, cfg)` — the driver and every networked client process build
/// byte-identical loaders/partitions from the same config, so a remote
/// client stepping its own state produces the exact trajectory the
/// in-process run would have. Implemented as "materialize every client of
/// a [`ClientPool`]" so the eager and lazy paths share one construction.
pub fn build_client_states(
    v: &VariantSpec,
    cfg: &RunConfig,
    task: Task,
) -> Vec<ClientState> {
    let pool = ClientPool::new(v, cfg, task);
    (0..cfg.n_clients).map(|i| pool.build_state(i)).collect()
}

/// Read-only context shared by all client worker threads (or remote
/// client processes) during the decoupled fan-out phase.
pub struct LocalCtx<'a> {
    pub session: &'a Session,
    pub cfg: &'a RunConfig,
    pub book: &'a CostBook,
    pub base: Option<&'a [f32]>,
    pub task: Task,
    pub round_idx: usize,
    pub profile: DeviceProfile,
    pub nc: usize,
}

/// What one client's local phase produces, merged at the round barrier in
/// participant order.
pub struct LocalOutcome {
    pub ci: usize,
    pub theta: Vec<f32>,
    pub losses: Vec<f64>,
    /// per-step ZO seeds (the lean `ZoUpdate` wire record; FO algorithms
    /// carry the same counter-derived stream positions)
    pub seeds: Vec<i32>,
    /// per-step, per-probe gradient scalars, flattened `h × n_p`
    /// (HERON only; empty for FO algorithms). Together with `seeds`
    /// this is the full `--zo_wire seeds` replay record: any holder of
    /// the round's broadcast θ reproduces `theta` bit-identically via
    /// `zo::replay_trajectory`.
    pub gscales: Vec<f32>,
    pub comm_bytes: u64,
    pub flops: u64,
    pub lane: ClientLane,
}

/// Per-upload metadata for the server's drain policy, stamped by
/// [`upload_smashed`] next to the batch itself:
///
/// * `seq` — the client's per-round upload index (1-based, strictly
///   increasing). The *wire* `SmashedSeq.seq` is stamped per connection
///   lane by the networked sink instead (one strictly increasing counter
///   across every upload the lane ships in a round); in `--drain stream`
///   the dispatcher validates that counter keyed on `(conn, lane)`, so a
///   reordering transport cannot silently reshuffle the arrival-order
///   consumption schedule and multiplexed lanes on one socket cannot
///   corrupt each other's ordering check.
/// * `sent_at` — the client's virtual lane time when the upload leaves
///   the device; drives the event-sim's arrival-order server schedule
///   on the networked path (in-process, the same value flows through
///   [`ClientLane::mark_arrival`] and the barrier lane merge — recorded
///   only when the queue accepted the upload, since dropped batches are
///   never serviced).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UploadTag {
    pub seq: usize,
    pub sent_at: f64,
}

/// Where a client's smashed uploads go. In-process this is the
/// Main-Server's [`ServerQueue`]; over the network it is a framed
/// `Smashed` (barrier) or `SmashedSeq` (stream) message (acknowledged,
/// so capacity drops surface as typed NACKs). Returns `false` when the
/// batch was dropped.
///
/// `enc` is the payload's already-encoded codec envelope when the run's
/// `--codec` is lossy (the encode-once rule, `net::codec`):
/// `batch.smashed` then holds the *decoded* values — exactly what the
/// dispatcher reconstructs — and a networked sink ships `enc` verbatim
/// instead of re-encoding (re-quantization would recompute the scale
/// from already-rounded values and break in-process/wire bit-identity).
/// `None` under the default f32 codec; a networked sink then encodes
/// the identity envelope itself.
pub trait SmashedSink: Sync {
    fn push_smashed(
        &self,
        batch: SmashedBatch,
        tag: UploadTag,
        enc: Option<Vec<u8>>,
    ) -> bool;
}

impl SmashedSink for ServerQueue {
    /// The in-process queue is FIFO, so the arrival order IS the push
    /// order and the tag carries no extra information here (arrival
    /// times reach the sim through the client's lane instead). The
    /// encoded envelope is dropped: `batch.smashed` already carries the
    /// post-roundtrip values.
    fn push_smashed(
        &self,
        batch: SmashedBatch,
        _tag: UploadTag,
        _enc: Option<Vec<u8>>,
    ) -> bool {
        self.push(batch)
    }
}

pub fn loader_batch_xy(task: Task, loader: &Loader) -> (TensorValue, Vec<i32>) {
    match task {
        Task::Vision => (
            TensorValue::F32(loader.xs_f32.clone()),
            loader.ys.clone(),
        ),
        Task::Lm => (
            TensorValue::I32(loader.xs_i32.clone()),
            loader.xs_i32.clone(),
        ),
    }
}

pub fn step_seed(cfg: &RunConfig, round_idx: usize, client: usize, step: usize) -> i32 {
    mix64(
        cfg.run_seed,
        (round_idx as u64) << 24 | (client as u64) << 12 | step as u64,
    ) as i32
}

/// Borrow the loader's reused batch buffer as the entry's `x` input.
fn x_ref(task: Task, loader: &Loader) -> TensorRef<'_> {
    match task {
        Task::Vision => TensorRef::F32(&loader.xs_f32),
        Task::Lm => TensorRef::I32(&loader.xs_i32),
    }
}

/// Borrow the loader's target buffer (LM entries take the token batch).
fn y_slice(task: Task, loader: &Loader) -> &[i32] {
    match task {
        Task::Vision => &loader.ys,
        Task::Lm => &loader.xs_i32,
    }
}

/// One client's full local phase (h steps + uploads), self-contained so it
/// can run on any worker thread or in a remote client process. Mutates
/// only this client's state; all cross-client effects go through the
/// smashed sink and the returned outcome.
///
/// The loop is allocation-lean: every input is a borrowed view (θ, the
/// loader's batch buffers, the frozen base), the updated θ ping-pongs
/// between `theta` and the `out` arena (a swap, never a copy), and the
/// ZO probe record reuses one [`ZoStepRecord`] across all h steps.
pub fn client_local_phase(
    ctx: &LocalCtx,
    ci: usize,
    cs: &mut ClientState,
    mut theta: Vec<f32>,
    sink: &dyn SmashedSink,
) -> Result<LocalOutcome> {
    let mut lane = ClientLane::new(&ctx.profile);
    let mut losses = Vec::with_capacity(ctx.cfg.local_steps);
    let mut seeds = Vec::with_capacity(ctx.cfg.local_steps);
    let mut gscales = Vec::new();
    let mut comm_bytes = 0u64;
    let mut flops = 0u64;
    let zo = ctx.cfg.algorithm == Algorithm::Heron;
    if !matches!(cs.opt_local, OptState::None) {
        bail!(
            "local phase: stateful optimizers are not wired through the \
             typed runtime (manifest opt_state must be 0)"
        );
    }
    let rt = ctx.session.client_runtime(&ctx.cfg.variant)?;
    // per-client scratch arenas, reused across all h steps
    let mut out: Vec<f32> = Vec::new();
    let mut fwd_out: Vec<f32> = Vec::new();
    let mut rec = ZoStepRecord::default();
    if zo {
        gscales.reserve(ctx.cfg.local_steps * ctx.cfg.n_pert.max(1));
    }
    let _phase = crate::span!("local_phase", client = ci, round = ctx.round_idx);

    for step in 1..=ctx.cfg.local_steps {
        cs.loader.next_batch();
        let seed = step_seed(ctx.cfg, ctx.round_idx, ci, step);
        seeds.push(seed);
        let x = x_ref(ctx.task, &cs.loader);
        let y = y_slice(ctx.task, &cs.loader);
        let loss = if zo {
            let _s = crate::span!("zo_step", client = ci, step = step);
            rt.zo_step(
                ctx.base,
                &theta,
                x,
                y,
                ZoArgs {
                    seed,
                    mu: ctx.cfg.mu,
                    lr: ctx.cfg.lr_client,
                    n_pert: ctx.cfg.n_pert as i32,
                },
                &mut out,
                &mut rec,
            )?;
            gscales.extend_from_slice(&rec.gscales);
            rec.loss
        } else {
            let _s = crate::span!("fo_step", client = ci, step = step);
            rt.fo_step(ctx.base, &theta, x, y, ctx.cfg.lr_client, &mut out)?
        };
        std::mem::swap(&mut theta, &mut out);
        losses.push(loss as f64);
        flops += ctx.book.flops_per_step;
        lane.compute(ctx.book.flops_per_step);

        if step % ctx.cfg.upload_every == 0 {
            upload_smashed(
                ctx,
                rt,
                ci,
                cs,
                &theta,
                step,
                sink,
                &mut lane,
                &mut comm_bytes,
                &mut fwd_out,
            )?;
        }
    }
    if crate::telemetry::metrics_enabled() {
        use crate::telemetry::registry::counter;
        counter("client.local_steps").add(losses.len() as u64);
        if zo {
            // one gscale per probe per step — the probe count is exactly
            // the lean-upload payload the paper's Remark 4 counts
            counter("client.zo.probes").add(gscales.len() as u64);
        }
    }
    Ok(LocalOutcome {
        ci,
        theta,
        losses,
        seeds,
        gscales,
        comm_bytes,
        flops,
        lane,
    })
}

#[allow(clippy::too_many_arguments)]
fn upload_smashed(
    ctx: &LocalCtx,
    rt: &dyn ClientRuntime,
    ci: usize,
    cs: &mut ClientState,
    theta: &[f32],
    step: usize,
    sink: &dyn SmashedSink,
    lane: &mut ClientLane,
    comm_bytes: &mut u64,
    fwd_out: &mut Vec<f32>,
) -> Result<()> {
    let _s = crate::span!("upload_smashed", client = ci, step = step);
    rt.client_fwd(
        ctx.base,
        &theta[..ctx.nc],
        x_ref(ctx.task, &cs.loader),
        fwd_out,
    )?;
    // the sink owns the smashed batch, so move it out of the arena (the
    // buffer re-grows on the next upload)
    let mut smashed = std::mem::take(fwd_out);
    // encode-once: quantize at the producer, keep the decoded values
    // locally (FSL-SAGE's last_upload below must also see the
    // post-roundtrip batch, so this happens before the clone)
    let enc = match ctx.cfg.codec {
        crate::net::codec::Codec::F32 => None,
        codec => Some(crate::net::codec::transcode(codec, &mut smashed)),
    };
    // the upload forward is part of the protocol but NOT an extra
    // training cost in Table I (the paper's accounting charges the ZO /
    // FO step); we still charge its flops to the client sim for latency
    lane.compute(
        (ctx.book.flops_per_step / (ctx.cfg.n_pert as u64 + 1)).max(1),
    );
    *comm_bytes += ctx.book.comm_per_step(true);
    lane.upload(ctx.book.smashed_bytes);
    let targets = y_slice(ctx.task, &cs.loader).to_vec();
    // only the FSL-SAGE alignment ever reads last_upload — don't pay a
    // full smashed-batch copy per upload on the other algorithms
    if ctx.cfg.algorithm == Algorithm::FslSage {
        let x_i32 = match ctx.task {
            Task::Lm => cs.loader.xs_i32.clone(),
            Task::Vision => Vec::new(),
        };
        cs.last_upload =
            Some((smashed.clone(), targets.clone(), x_i32));
    }
    let accepted = sink.push_smashed(
        SmashedBatch {
            client: ci,
            round: ctx.round_idx,
            step,
            smashed,
            targets,
        },
        UploadTag {
            seq: step / ctx.cfg.upload_every,
            sent_at: lane.time,
        },
        enc,
    );
    // only accepted uploads become server-side work: a dropped batch
    // must not enter the arrival-driven occupancy schedule
    if accepted {
        lane.mark_arrival();
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// locked exchange (SFLV1/V2) — client half
// ---------------------------------------------------------------------------

/// Client forward to the cut layer on the loader's current batch.
/// Returns the smashed activations (cold path — the locked exchange is
/// the baselines' bottleneck by design, not ours).
pub fn locked_client_fwd(
    session: &Session,
    variant: &str,
    base: Option<&[f32]>,
    theta_c: &[f32],
    x: &TensorValue,
) -> Result<Vec<f32>> {
    let rt = session.client_runtime(variant)?;
    let mut out = Vec::new();
    rt.client_fwd(base, theta_c, x.view(), &mut out)?;
    Ok(out)
}

/// Client backprop step from the relayed cut gradient. Returns the
/// updated θ_c. The native manifests are stateless (`opt_state == 0`),
/// so a live Adam state here means a foreign manifest the typed runtime
/// cannot thread — fail loudly instead of silently dropping it.
#[allow(clippy::too_many_arguments)]
pub fn locked_client_bp(
    session: &Session,
    variant: &str,
    base: Option<&[f32]>,
    theta_c: &[f32],
    opt_c: &mut OptState,
    x: TensorValue,
    g_smashed: Vec<f32>,
    lr: f32,
) -> Result<Vec<f32>> {
    if !matches!(opt_c, OptState::None) {
        bail!(
            "locked client bp: stateful optimizers are not wired through \
             the typed runtime (manifest opt_state must be 0)"
        );
    }
    let rt = session.client_runtime(variant)?;
    let mut out = Vec::new();
    rt.client_bp_step(base, theta_c, x.view(), &g_smashed, lr, &mut out)?;
    Ok(out)
}

/// FSL-SAGE: realign the aux head of `theta` against the server's cut
/// gradient for the client's last uploaded batch. Runs on whichever
/// process holds `last_upload` (the driver in-process, the remote client
/// over the wire) — same model method, same inputs, same bits.
#[allow(clippy::too_many_arguments)]
pub fn aux_align_apply(
    session: &Session,
    variant: &str,
    base: Option<&[f32]>,
    theta: Vec<f32>,
    smashed: Vec<f32>,
    y: Vec<i32>,
    g_smashed: Vec<f32>,
    lr: f32,
) -> Result<Vec<f32>> {
    let rt = session.client_runtime(variant)?;
    let mut out = Vec::new();
    rt.aux_align(base, &theta, &smashed, &y, &g_smashed, lr, &mut out)?;
    Ok(out)
}
