//! Pluggable server drain policy (substrate S23): *when* the Main-Server
//! consumes queued smashed uploads, and in *what order*.
//!
//! The paper's Eq. (7) server phase is a barrier drain: every upload of
//! the round is held until all participants finish their local phase,
//! then consumed in deterministic `(round, client, step)` order. That is
//! bit-reproducible but leaves the (compute-rich, FO) server idle while
//! slow ZO clients finish — exactly the straggler regime AdaptSFL
//! (arXiv:2403.13101) targets. The `stream` policy trades the
//! bit-identity contract for latency: uploads are consumed in **arrival
//! order, mid-round**, overlapping the client phase with the server's FO
//! steps (SFLV2-style pipelining).
//!
//! Both execution modes go through the same two hooks:
//!
//! * [`DrainPolicy::take_ready`] — the mid-round probe. Called whenever
//!   new uploads may have arrived (after each wire event on the
//!   networked dispatcher; continuously by the in-process consumer
//!   loop). `barrier` releases nothing; `stream` releases everything
//!   currently queued, FIFO.
//! * [`DrainPolicy::take_at_barrier`] — the round barrier. `barrier`
//!   performs the full Eq. (7) sorted drain; `stream` hands over
//!   whatever stragglers remain, still in arrival order.
//!
//! What each mode guarantees:
//!
//! | | `barrier` (default) | `stream` |
//! |---|---|---|
//! | θ_s update order | Eq. (7): `(round, client, step)` | arrival order |
//! | trajectory | bit-identical for any worker/connection count | θ_l + per-step losses still bit-identical for HERON/CSE-FSL (the client phase is θ_s-independent); θ_s, eval metrics — and FSL-SAGE's aligned θ_l, which feeds on mid-round cut gradients — depend on the arrival order |
//! | server idle | waits for the slowest client | consumes mid-round |
//! | algorithms | all | decoupled only (HERON, CSE-FSL, FSL-SAGE) |
//!
//! `--zo_wire seeds` composes with `stream`: the server-side ZO replay
//! reconstructs each client's θ_l from the *round broadcast* θ and the
//! client's own `(seed, gscales)` record — it never reads the smashed
//! queue, so replay ordering does not require the barrier (enforced
//! decision of `RunConfig::validate`; pinned in
//! `rust/tests/drain_stream.rs`). The locked baselines (SFLV1/V2) have
//! no decoupled queue to stream from — `stream` is rejected for them
//! with a typed [`DrainConfigError`].
//!
//! ## Straggler cutoff (`--round_deadline_ms`)
//!
//! A round deadline extends the barrier hook with a *cut set*: the
//! clients the deadline (or a mid-round disconnect) excluded from the
//! round. [`DrainPolicy::take_at_barrier_cut`] consumes the barrier
//! batches minus anything a cut-off client queued — the cutoff is
//! **client-granular**: a client either contributes its whole round
//! (uploads + θ) or nothing, so the surviving drain stays deterministic
//! under `barrier`. With an empty cut set the hook IS
//! `take_at_barrier`, which is how bit-identity with deadline-free runs
//! is preserved. Under `stream`, batches a mid-round probe already
//! consumed before the cut stand — arrival-order consumption is already
//! outside the bit-identity contract.

use crate::coordinator::server_queue::{ServerQueue, SmashedBatch};
use std::fmt;

/// Which drain policy a run executes (`--drain`, config key `drain`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DrainMode {
    /// Hold every upload until the round barrier; consume in Eq. (7)
    /// `(round, client, step)` order. Bit-identical to the sequential
    /// reference for any worker/connection count.
    #[default]
    Barrier,
    /// Consume uploads in arrival order, mid-round, overlapping the
    /// client phase with the server FO steps.
    Stream,
}

impl DrainMode {
    pub fn name(&self) -> &'static str {
        match self {
            DrainMode::Barrier => "barrier",
            DrainMode::Stream => "stream",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "barrier" | "eq7" | "sorted" => Some(DrainMode::Barrier),
            "stream" | "streaming" | "arrival" => Some(DrainMode::Stream),
            _ => None,
        }
    }

    /// The policy object for this mode (stateless, so `'static`).
    pub fn policy(&self) -> &'static dyn DrainPolicy {
        match self {
            DrainMode::Barrier => &BarrierDrain,
            DrainMode::Stream => &StreamDrain,
        }
    }
}

/// Typed rejection for a `--drain` / algorithm / wire-mode combination
/// the engine cannot honor. Carried inside `anyhow::Error` by
/// `RunConfig::validate` so callers can `downcast_ref` it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DrainConfigError {
    pub drain: DrainMode,
    /// `Algorithm::name()` of the offending algorithm
    pub algorithm: &'static str,
    pub reason: &'static str,
}

impl fmt::Display for DrainConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "--drain {} is incompatible with {}: {}",
            self.drain.name(),
            self.algorithm,
            self.reason
        )
    }
}

impl std::error::Error for DrainConfigError {}

/// The consumption schedule over the Main-Server queue. Implementations
/// are stateless; all queue state lives in [`ServerQueue`].
pub trait DrainPolicy: Sync {
    fn name(&self) -> &'static str;

    /// Does consumption overlap the client phase? (Drives the
    /// in-process round engine's consumer-thread setup and the
    /// networked client's choice of upload message.)
    fn streams(&self) -> bool;

    /// Mid-round probe: the batches the server may consume *now*, in
    /// this policy's consumption order.
    fn take_ready(&self, queue: &ServerQueue) -> Vec<SmashedBatch>;

    /// Round barrier: the remaining batches, in this policy's
    /// consumption order. Everything, for `barrier`; stragglers the
    /// mid-round probes missed, for `stream`.
    fn take_at_barrier(&self, queue: &ServerQueue) -> Vec<SmashedBatch>;

    /// Round barrier under a straggler cutoff: [`Self::take_at_barrier`]
    /// minus every batch a cut-off client queued (discarded, and still
    /// counted as `processed` by the queue's own drain accounting —
    /// cut-off is a consumption decision, not a queue drop). With an
    /// empty cut set this is *exactly* `take_at_barrier`, byte for byte
    /// — the bit-identity hinge for deadline-free rounds.
    fn take_at_barrier_cut(
        &self,
        queue: &ServerQueue,
        cut: &std::collections::BTreeSet<usize>,
    ) -> Vec<SmashedBatch> {
        let batches = self.take_at_barrier(queue);
        if cut.is_empty() {
            return batches;
        }
        batches
            .into_iter()
            .filter(|b| !cut.contains(&b.client))
            .collect()
    }
}

/// Eq. (7): nothing mid-round, everything sorted at the barrier.
pub struct BarrierDrain;

impl DrainPolicy for BarrierDrain {
    fn name(&self) -> &'static str {
        "barrier"
    }

    fn streams(&self) -> bool {
        false
    }

    fn take_ready(&self, _queue: &ServerQueue) -> Vec<SmashedBatch> {
        Vec::new()
    }

    fn take_at_barrier(&self, queue: &ServerQueue) -> Vec<SmashedBatch> {
        queue.drain_sorted()
    }
}

/// Arrival order, mid-round (SFLV2-style pipelining).
pub struct StreamDrain;

impl DrainPolicy for StreamDrain {
    fn name(&self) -> &'static str {
        "stream"
    }

    fn streams(&self) -> bool {
        true
    }

    fn take_ready(&self, queue: &ServerQueue) -> Vec<SmashedBatch> {
        queue.drain_fifo()
    }

    fn take_at_barrier(&self, queue: &ServerQueue) -> Vec<SmashedBatch> {
        queue.drain_fifo()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(client: usize, round: usize, step: usize) -> SmashedBatch {
        SmashedBatch {
            client,
            round,
            step,
            smashed: vec![0.0; 2],
            targets: vec![1],
        }
    }

    fn fill(q: &ServerQueue) {
        // deliberately out of (round, client, step) order
        q.push(batch(2, 0, 1));
        q.push(batch(0, 0, 2));
        q.push(batch(1, 0, 1));
        q.push(batch(0, 0, 1));
    }

    fn keys(batches: &[SmashedBatch]) -> Vec<(usize, usize, usize)> {
        batches.iter().map(|b| (b.round, b.client, b.step)).collect()
    }

    #[test]
    fn barrier_releases_nothing_mid_round_and_sorts_at_barrier() {
        let q = ServerQueue::new(16);
        fill(&q);
        let p = DrainMode::Barrier.policy();
        assert!(!p.streams());
        assert!(p.take_ready(&q).is_empty());
        assert_eq!(q.len(), 4, "mid-round probe must not consume");
        assert_eq!(
            keys(&p.take_at_barrier(&q)),
            vec![(0, 0, 1), (0, 0, 2), (0, 1, 1), (0, 2, 1)]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn stream_releases_arrival_order_mid_round() {
        let q = ServerQueue::new(16);
        fill(&q);
        let p = DrainMode::Stream.policy();
        assert!(p.streams());
        assert_eq!(
            keys(&p.take_ready(&q)),
            vec![(0, 2, 1), (0, 0, 2), (0, 1, 1), (0, 0, 1)],
            "stream consumes in arrival (FIFO) order"
        );
        assert!(q.is_empty());
        // stragglers after the probe still come out in arrival order
        q.push(batch(3, 0, 1));
        q.push(batch(1, 0, 2));
        assert_eq!(
            keys(&p.take_at_barrier(&q)),
            vec![(0, 3, 1), (0, 1, 2)]
        );
    }

    #[test]
    fn barrier_cut_with_empty_set_is_take_at_barrier() {
        let q = ServerQueue::new(16);
        fill(&q);
        let p = DrainMode::Barrier.policy();
        let cut = std::collections::BTreeSet::new();
        assert_eq!(
            keys(&p.take_at_barrier_cut(&q, &cut)),
            vec![(0, 0, 1), (0, 0, 2), (0, 1, 1), (0, 2, 1)],
            "empty cut set must be exactly take_at_barrier"
        );
    }

    #[test]
    fn cut_clients_batches_are_discarded_in_both_policies() {
        for mode in [DrainMode::Barrier, DrainMode::Stream] {
            let q = ServerQueue::new(16);
            fill(&q);
            let cut: std::collections::BTreeSet<usize> =
                [0usize].into_iter().collect();
            let out = mode.policy().take_at_barrier_cut(&q, &cut);
            assert!(
                out.iter().all(|b| b.client != 0),
                "{}: client 0 was cut off",
                mode.name()
            );
            assert_eq!(out.len(), 2, "{}: two surviving batches", mode.name());
            assert!(q.is_empty(), "cut batches leave the queue too");
        }
    }

    #[test]
    fn mode_parse_and_names() {
        assert_eq!(DrainMode::parse("barrier"), Some(DrainMode::Barrier));
        assert_eq!(DrainMode::parse("STREAM"), Some(DrainMode::Stream));
        assert_eq!(DrainMode::parse("arrival"), Some(DrainMode::Stream));
        assert_eq!(DrainMode::parse("nope"), None);
        assert_eq!(DrainMode::default(), DrainMode::Barrier);
        assert_eq!(DrainMode::Stream.policy().name(), "stream");
        assert_eq!(DrainMode::Barrier.policy().name(), "barrier");
    }

    #[test]
    fn typed_error_formats() {
        let e = DrainConfigError {
            drain: DrainMode::Stream,
            algorithm: "SFLV2",
            reason: "locked baselines have no decoupled upload queue",
        };
        let s = e.to_string();
        assert!(s.contains("stream") && s.contains("SFLV2"), "{s}");
    }
}
