//! L3 coordinator: the HERON-SFL protocol and its baselines.
//!
//! * [`algorithms`] — the algorithm family (HERON, CSE-FSL, FSL-SAGE,
//!   SFLV1/V2-SplitLoRA)
//! * [`local`] — the client-side local phase, shared by the in-process
//!   driver and the networked client endpoint (`net::client`)
//! * [`round`] — the four-stage round driver over the AOT runtime
//! * [`aggregator`] — Fed-Server FedAvg (Eq. 8)
//! * [`server_queue`] — Main-Server sequential smashed-data queue (Eq. 7)
//! * [`drain`] — pluggable server drain policy (`--drain barrier|stream`)
//! * [`accounting`] — Table I/II/III resource cost models
//! * [`eventsim`] — virtual-time latency / training-lock simulator
//! * [`config`] — experiment configuration
//! * [`checkpoint`] — checksummed checkpoint/restore of the round driver

pub mod accounting;
pub mod aggregator;
pub mod algorithms;
pub mod checkpoint;
pub mod config;
pub mod drain;
pub mod eventsim;
pub mod local;
pub mod round;
pub mod server_queue;
