//! Experiment configuration (substrate S5): typed config with JSON presets
//! and dotted CLI overrides.

use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::drain::{DrainConfigError, DrainMode};
use crate::data::partition::Scheme;
use crate::net::codec::{Codec, GradCodec};
use crate::util::cli::Args;
use crate::util::json::Value;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// What HERON puts on the wire around the local phase (`--zo_wire`).
/// The θ trajectory is bit-identical in every mode (pinned in
/// `rust/tests/net_loopback.rs`); only the wire payloads and the comm
/// accounting change.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ZoWireMode {
    /// Upload the updated θ_l (the general protocol; every algorithm).
    #[default]
    Theta,
    /// HERON only: upload per-step `(seed, per-probe gradient scalars)`
    /// and let the server *replay* the ZO update through
    /// `zo::stream::replay_update` (paper §IV / Remark 4) — O(h·n_p)
    /// floats up instead of |θ_c|+|θ_a|.
    Seeds,
    /// HERON only: lean in *both* directions (wire v7). Uploads are the
    /// `Seeds` record; the downlink `ModelSync` broadcast is replaced by
    /// a `SeedSync` carrying every participant's `(seeds, gscales)`
    /// record plus its FedAvg weight, and each client reconstructs the
    /// aggregate locally via `zo::aggregate_trajectories` from its
    /// cached round-start θ_l (HO-SFL's dimension-free aggregation).
    /// Only the first round (and any restore/rejoin bootstrap) ships a
    /// dense θ_l.
    SeedAgg,
}

impl ZoWireMode {
    pub fn name(&self) -> &'static str {
        match self {
            ZoWireMode::Theta => "theta",
            ZoWireMode::Seeds => "seeds",
            ZoWireMode::SeedAgg => "seed_agg",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "theta" => Some(ZoWireMode::Theta),
            "seeds" | "seed" | "lean" => Some(ZoWireMode::Seeds),
            "seed_agg" | "seedagg" | "agg" => Some(ZoWireMode::SeedAgg),
            _ => None,
        }
    }

    /// Uploads are the lean `(seeds, gscales)` record (no θ_l up).
    pub fn lean_uplink(&self) -> bool {
        matches!(self, ZoWireMode::Seeds | ZoWireMode::SeedAgg)
    }

    /// Steady-state downlink is the lean `SeedSync` broadcast (no dense
    /// θ_l down past the bootstrap round).
    pub fn lean_downlink(&self) -> bool {
        matches!(self, ZoWireMode::SeedAgg)
    }
}

/// Typed rejection for `--zo_wire` modes that need a capability only
/// one algorithm has (mirrors [`DrainConfigError`]): callers match on
/// this to distinguish a config-gate refusal from an I/O failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ZoWireConfigError {
    pub zo_wire: ZoWireMode,
    pub algorithm: &'static str,
    pub reason: &'static str,
}

impl std::fmt::Display for ZoWireConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "--zo_wire {} is not valid for algorithm {}: {}",
            self.zo_wire.name(),
            self.algorithm,
            self.reason
        )
    }
}

impl std::error::Error for ZoWireConfigError {}

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// artifact variant (e.g. "cnn_c1", "gpt2micro_c2_a1")
    pub variant: String,
    pub algorithm: Algorithm,
    pub n_clients: usize,
    /// fraction of clients participating per round (paper Fig 3c)
    pub participation: f64,
    pub rounds: usize,
    /// local steps per round (h in the paper)
    pub local_steps: usize,
    /// upload smashed data every k local steps
    pub upload_every: usize,
    /// FSL-SAGE: run aux alignment every this many uploads
    pub align_every: usize,
    pub lr_client: f32,
    pub lr_server: f32,
    /// ZO perturbation step size μ
    pub mu: f32,
    /// ZO probes per step (n_p); total forwards = n_pert + 1
    pub n_pert: usize,
    pub scheme: Scheme,
    /// virtual dataset size assigned across clients
    pub dataset_size: u64,
    pub data_seed: u64,
    pub run_seed: u64,
    pub eval_every: usize,
    /// held-out eval sample start (beyond dataset_size)
    pub eval_holdout: u64,
    /// host worker threads for the parallel client phase (0 = all cores)
    pub workers: usize,
    /// Main-Server queue capacity override (0 = auto: N·(h/k + 1), which
    /// never drops; nonzero bounds the queue so backpressure drops — and,
    /// on the networked path, typed NACKs — become observable)
    pub queue_capacity: usize,
    /// HERON wire mode: `theta` (full θ_l up), `seeds` (seed +
    /// per-probe scalars up, server replays the update), or `seed_agg`
    /// (lean both ways: seeds up AND the round sync down as a
    /// `SeedSync` seeds+scalars broadcast clients replay locally)
    pub zo_wire: ZoWireMode,
    /// Server drain policy: `barrier` (Eq. 7 order at the round barrier,
    /// bit-identical — the default) or `stream` (arrival-order
    /// consumption mid-round, decoupled algorithms only)
    pub drain: DrainMode,
    /// Straggler cutoff: per-round deadline in milliseconds after which
    /// the round finalizes with the contributions it has (0 = wait
    /// forever, the pre-deadline behavior — bit-identical to runs built
    /// before the flag existed). Wall-clock on the wire path,
    /// virtual-time against the event-sim lane clocks in-process. A
    /// cut-off client is excluded whole: its queued uploads are
    /// discarded at the barrier and its θ never enters FedAvg, so the
    /// cutoff is client-granular and deterministic (see
    /// `coordinator::drain`).
    pub round_deadline_ms: u64,
    /// Payload codec for smashed-activation uploads (`--codec
    /// {f32,int8,int4}`). `f32` (the default) is the identity envelope
    /// and is pinned bit-identical to pre-codec behavior; the lossy
    /// codecs trade accuracy for bytes (see `net::codec`). A negotiated
    /// capability: clients advertise supported ids in `Hello.codecs`
    /// and the dispatcher validates this pick against them.
    pub codec: Codec,
    /// Payload codec for the server→client `CutGradient` in the locked
    /// baselines (`--grad_codec topk:<ratio>`). `f32` (default) is the
    /// identity; `topk` ships only the k=⌈ratio·n⌉ largest-|g| entries
    /// as (index, value) pairs. Gated to SFLV1/V2 — the decoupled
    /// algorithms never ship a per-step cut gradient.
    pub grad_codec: GradCodec,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            variant: "cnn_c1".into(),
            algorithm: Algorithm::Heron,
            n_clients: 5,
            participation: 1.0,
            rounds: 30,
            local_steps: 2,
            upload_every: 1,
            align_every: 4,
            lr_client: 1e-3,
            lr_server: 1e-3,
            mu: 1e-2,
            n_pert: 1,
            scheme: Scheme::Iid,
            dataset_size: 4096,
            data_seed: 42,
            run_seed: 7,
            eval_every: 1,
            eval_holdout: 1 << 20,
            workers: 0,
            queue_capacity: 0,
            zo_wire: ZoWireMode::Theta,
            drain: DrainMode::Barrier,
            round_deadline_ms: 0,
            codec: Codec::F32,
            grad_codec: GradCodec::F32,
        }
    }
}

impl RunConfig {
    pub fn validate(&self) -> Result<()> {
        if self.n_clients == 0 {
            bail!("n_clients must be positive");
        }
        if !(0.0..=1.0).contains(&self.participation) || self.participation <= 0.0
        {
            bail!("participation must be in (0, 1]");
        }
        if self.local_steps == 0 || self.upload_every == 0 {
            bail!("local_steps and upload_every must be positive");
        }
        if self.mu <= 0.0 {
            bail!("mu must be positive");
        }
        if self.dataset_size < self.n_clients as u64 {
            bail!("dataset smaller than client count");
        }
        if self.zo_wire == ZoWireMode::Seeds
            && self.algorithm != Algorithm::Heron
        {
            bail!(
                "--zo_wire seeds replays a ZO update record and therefore \
                 requires the HERON algorithm (got {})",
                self.algorithm.name()
            );
        }
        // seed_agg carries the typed rejection: the four non-HERON
        // algorithms have no seed-addressed ZO record to aggregate, so
        // the gate is a capability mismatch, not a parse error.
        if self.zo_wire == ZoWireMode::SeedAgg
            && self.algorithm != Algorithm::Heron
        {
            return Err(anyhow::Error::new(ZoWireConfigError {
                zo_wire: self.zo_wire,
                algorithm: self.algorithm.name(),
                reason: "seed-space aggregation replays every \
                         participant's (seed, gscales) record from the \
                         cached round-start θ_l, which only the HERON \
                         ZO local phase produces",
            }));
        }
        // `--drain stream` needs the decoupled upload queue: the locked
        // baselines (SFLV1/V2) answer every smashed upload synchronously
        // inside the training lock, so there is nothing to stream.
        //
        // `--drain stream` + `--zo_wire seeds` is deliberately ALLOWED:
        // the seeds replay reconstructs each client's θ_l from the
        // round's *broadcast* θ and the client's own (seed, gscales)
        // record — it never reads the smashed queue, so replay ordering
        // does not require the barrier (pinned bit-identical across
        // drain modes in `rust/tests/drain_stream.rs`).
        if self.drain == DrainMode::Stream && !self.algorithm.is_decoupled()
        {
            return Err(anyhow::Error::new(DrainConfigError {
                drain: self.drain,
                algorithm: self.algorithm.name(),
                reason: "the locked baselines have no decoupled upload \
                         queue to consume mid-round (every smashed batch \
                         is answered inside the per-step training lock)",
            }));
        }
        if let GradCodec::TopK(ratio) = self.grad_codec {
            if !(ratio > 0.0 && ratio <= 1.0) {
                bail!(
                    "--grad_codec topk ratio must be in (0, 1], got {ratio}"
                );
            }
            // Only the locked baselines ship a per-step CutGradient;
            // the decoupled algorithms (HERON/CSE/SAGE) compute the
            // client backward from locally-held state, so a gradient
            // codec would silently do nothing there.
            if !matches!(self.algorithm, Algorithm::SflV1 | Algorithm::SflV2)
            {
                bail!(
                    "--grad_codec topk compresses the per-step CutGradient \
                     and therefore requires a locked baseline (sfl_v1 or \
                     sfl_v2, got {})",
                    self.algorithm.name()
                );
            }
        }
        Ok(())
    }

    pub fn participants_per_round(&self) -> usize {
        ((self.n_clients as f64 * self.participation).round() as usize)
            .clamp(1, self.n_clients)
    }

    /// The straggler deadline as *virtual* seconds (the in-process
    /// interpretation: event-sim lane clocks). `None` when unset.
    pub fn virtual_deadline(&self) -> Option<f64> {
        (self.round_deadline_ms > 0)
            .then(|| self.round_deadline_ms as f64 / 1e3)
    }

    /// The straggler deadline as a *wall-clock* duration (the wire-path
    /// interpretation). `None` when unset.
    pub fn wall_deadline(&self) -> Option<std::time::Duration> {
        (self.round_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(self.round_deadline_ms))
    }

    /// Apply `--key value` overrides (dotted keys accepted for
    /// discoverability; the last path segment decides).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for (k, v) in &args.flags {
            let key = k.rsplit('.').next().unwrap_or(k);
            self.apply_kv(key, v)
                .with_context(|| format!("applying --{k} {v}"))?;
        }
        Ok(())
    }

    fn apply_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "variant" => self.variant = v.to_string(),
            "algorithm" | "algo" => {
                self.algorithm = Algorithm::parse(v)
                    .with_context(|| format!("unknown algorithm {v}"))?
            }
            // "population" is the cohort-scheduling view of the same
            // field: N registered devices, of which `participation`
            // samples a per-round cohort (storm presets use this name)
            "clients" | "n_clients" | "population" => {
                self.n_clients = v.parse()?
            }
            "participation" => self.participation = v.parse()?,
            "rounds" => self.rounds = v.parse()?,
            "local_steps" | "h" => self.local_steps = v.parse()?,
            "upload_every" | "k" => self.upload_every = v.parse()?,
            "align_every" => self.align_every = v.parse()?,
            "lr_client" => self.lr_client = v.parse()?,
            "lr_server" => self.lr_server = v.parse()?,
            "mu" => self.mu = v.parse()?,
            "n_pert" => self.n_pert = v.parse()?,
            "alpha" | "dirichlet" => {
                self.scheme = Scheme::Dirichlet { alpha: v.parse()? }
            }
            "iid" => {
                if v == "true" {
                    self.scheme = Scheme::Iid
                }
            }
            "workers" => self.workers = v.parse()?,
            "dataset_size" => self.dataset_size = v.parse()?,
            "data_seed" => self.data_seed = v.parse()?,
            "run_seed" | "seed" => self.run_seed = v.parse()?,
            "eval_every" => self.eval_every = v.parse()?,
            "eval_holdout" => self.eval_holdout = v.parse()?,
            "queue_capacity" => self.queue_capacity = v.parse()?,
            "zo_wire" => {
                self.zo_wire = ZoWireMode::parse(v)
                    .with_context(|| format!("unknown zo_wire mode {v}"))?
            }
            "drain" => {
                self.drain = DrainMode::parse(v)
                    .with_context(|| format!("unknown drain mode {v}"))?
            }
            "round_deadline_ms" | "deadline_ms" => {
                self.round_deadline_ms = v.parse()?
            }
            "codec" => {
                self.codec = Codec::parse(v)
                    .with_context(|| format!("unknown codec {v}"))?
            }
            "grad_codec" => {
                self.grad_codec = GradCodec::parse(v)
                    .with_context(|| format!("unknown grad_codec {v}"))?
            }
            // non-config CLI flags pass through silently
            _ => {}
        }
        Ok(())
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(obj) = v.as_obj() {
            for (k, val) in obj {
                let s = match val {
                    Value::Str(s) => s.clone(),
                    Value::Num(n) => {
                        if *n == n.trunc() {
                            format!("{}", *n as i64)
                        } else {
                            format!("{n}")
                        }
                    }
                    Value::Bool(b) => b.to_string(),
                    _ => continue,
                };
                cfg.apply_kv(k, &s)?;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize as a JSON object whose values are the *exact* strings
    /// [`Self::apply_kv`] parses back, so `from_json(to_json(cfg))`
    /// reproduces every field bit-for-bit (Rust's `{}` float formatting is
    /// shortest-roundtrip, and integer fields go through `to_string`).
    /// The networked `Assign` handshake ships configs this way — a remote
    /// client must reconstruct the server's run parameters exactly or the
    /// bit-identity contract of the wire protocol breaks.
    pub fn to_json(&self) -> Value {
        let mut pairs: Vec<(&str, Value)> = vec![
            ("variant", Value::str(&self.variant)),
            ("algorithm", Value::str(self.algorithm.name())),
            ("n_clients", Value::str(&self.n_clients.to_string())),
            ("participation", Value::str(&self.participation.to_string())),
            ("rounds", Value::str(&self.rounds.to_string())),
            ("local_steps", Value::str(&self.local_steps.to_string())),
            ("upload_every", Value::str(&self.upload_every.to_string())),
            ("align_every", Value::str(&self.align_every.to_string())),
            ("lr_client", Value::str(&self.lr_client.to_string())),
            ("lr_server", Value::str(&self.lr_server.to_string())),
            ("mu", Value::str(&self.mu.to_string())),
            ("n_pert", Value::str(&self.n_pert.to_string())),
            ("dataset_size", Value::str(&self.dataset_size.to_string())),
            ("data_seed", Value::str(&self.data_seed.to_string())),
            ("run_seed", Value::str(&self.run_seed.to_string())),
            ("eval_every", Value::str(&self.eval_every.to_string())),
            ("eval_holdout", Value::str(&self.eval_holdout.to_string())),
            ("workers", Value::str(&self.workers.to_string())),
            ("queue_capacity", Value::str(&self.queue_capacity.to_string())),
            ("zo_wire", Value::str(self.zo_wire.name())),
            ("drain", Value::str(self.drain.name())),
            (
                "round_deadline_ms",
                Value::str(&self.round_deadline_ms.to_string()),
            ),
            ("codec", Value::str(self.codec.name())),
            ("grad_codec", Value::str(&self.grad_codec.spec())),
        ];
        match self.scheme {
            Scheme::Iid => pairs.push(("iid", Value::str("true"))),
            Scheme::Dirichlet { alpha } => {
                pairs.push(("alpha", Value::str(&alpha.to_string())))
            }
        }
        Value::obj(pairs)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = crate::util::json::parse(&text)?;
        Self::from_json(&v)
    }

    pub fn describe(&self) -> String {
        let w = if self.workers == 0 {
            "auto".to_string()
        } else {
            self.workers.to_string()
        };
        format!(
            "{} on {} | N={} part={:.0}% rounds={} h={} k={} | lr_c={} lr_s={} mu={} np={} | wire={} drain={} workers={w} | {:?}",
            self.algorithm.name(),
            self.variant,
            self.n_clients,
            self.participation * 100.0,
            self.rounds,
            self.local_steps,
            self.upload_every,
            self.lr_client,
            self.lr_server,
            self.mu,
            self.n_pert,
            self.zo_wire.name(),
            self.drain.name(),
            self.scheme,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn args_override() {
        let mut cfg = RunConfig::default();
        let args = Args::parse_from(
            ["--algo", "sage", "--rounds", "5", "--alpha", "0.3",
             "--run.mu", "0.05"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.algorithm, Algorithm::FslSage);
        assert_eq!(cfg.rounds, 5);
        assert!(matches!(cfg.scheme, Scheme::Dirichlet { alpha } if (alpha - 0.3).abs() < 1e-12));
        assert!((cfg.mu - 0.05).abs() < 1e-9);
    }

    #[test]
    fn population_aliases_n_clients() {
        let mut cfg = RunConfig::default();
        let args = Args::parse_from(
            ["--population", "1024", "--participation", "0.0625"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.n_clients, 1024);
        assert_eq!(cfg.participants_per_round(), 64);
        let v = crate::util::json::parse(r#"{"population": 200}"#).unwrap();
        assert_eq!(RunConfig::from_json(&v).unwrap().n_clients, 200);
    }

    #[test]
    fn json_config() {
        let v = crate::util::json::parse(
            r#"{"variant": "cnn_c2", "algorithm": "heron", "clients": 10,
                "mu": 0.001, "rounds": 3}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.variant, "cnn_c2");
        assert_eq!(cfg.n_clients, 10);
        assert!((cfg.mu - 0.001).abs() < 1e-9);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = RunConfig::default();
        c.n_clients = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.participation = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.mu = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn to_json_roundtrips_exactly() {
        let mut cfg = RunConfig {
            variant: "gpt2micro_c2_a1".into(),
            algorithm: Algorithm::FslSage,
            n_clients: 7,
            participation: 0.37,
            lr_client: 1.7e-3,
            lr_server: 3.3e-4,
            mu: 0.012345,
            n_pert: 3,
            scheme: Scheme::Dirichlet { alpha: 0.31 },
            dataset_size: 2048,
            data_seed: 123456789,
            run_seed: 987654321,
            eval_holdout: (1 << 21) + 17,
            queue_capacity: 5,
            zo_wire: ZoWireMode::Theta,
            round_deadline_ms: 1500,
            codec: Codec::Int8,
            ..Default::default()
        };
        for _ in 0..2 {
            let json = cfg.to_json().to_string();
            let back =
                RunConfig::from_json(&crate::util::json::parse(&json).unwrap())
                    .unwrap();
            assert_eq!(back.variant, cfg.variant);
            assert_eq!(back.algorithm, cfg.algorithm);
            assert_eq!(back.n_clients, cfg.n_clients);
            assert_eq!(back.participation.to_bits(), cfg.participation.to_bits());
            assert_eq!(back.lr_client.to_bits(), cfg.lr_client.to_bits());
            assert_eq!(back.lr_server.to_bits(), cfg.lr_server.to_bits());
            assert_eq!(back.mu.to_bits(), cfg.mu.to_bits());
            assert_eq!(back.n_pert, cfg.n_pert);
            match (back.scheme, cfg.scheme) {
                (
                    Scheme::Dirichlet { alpha: a },
                    Scheme::Dirichlet { alpha: b },
                ) => assert_eq!(a.to_bits(), b.to_bits()),
                (Scheme::Iid, Scheme::Iid) => {}
                other => panic!("scheme mismatch: {other:?}"),
            }
            assert_eq!(back.dataset_size, cfg.dataset_size);
            assert_eq!(back.data_seed, cfg.data_seed);
            assert_eq!(back.run_seed, cfg.run_seed);
            assert_eq!(back.eval_holdout, cfg.eval_holdout);
            assert_eq!(back.queue_capacity, cfg.queue_capacity);
            assert_eq!(back.zo_wire, cfg.zo_wire);
            assert_eq!(back.drain, cfg.drain);
            assert_eq!(back.round_deadline_ms, cfg.round_deadline_ms);
            assert_eq!(back.codec, cfg.codec);
            match (back.grad_codec, cfg.grad_codec) {
                (GradCodec::TopK(a), GradCodec::TopK(b)) => {
                    assert_eq!(a.to_bits(), b.to_bits())
                }
                (GradCodec::F32, GradCodec::F32) => {}
                other => panic!("grad_codec mismatch: {other:?}"),
            }
            // second lap exercises the IID branch + the seeds wire mode
            // + the stream drain policy; codec laps ride on a locked
            // baseline config below instead (seeds gates on HERON)
            cfg.scheme = Scheme::Iid;
            cfg.algorithm = Algorithm::Heron;
            cfg.zo_wire = ZoWireMode::Seeds;
            cfg.drain = DrainMode::Stream;
            cfg.codec = Codec::Int4;
        }
        // a topk ratio with a non-trivial shortest-roundtrip decimal
        // must survive the JSON lap bit-for-bit on a locked baseline
        let cfg = RunConfig {
            algorithm: Algorithm::SflV2,
            grad_codec: GradCodec::TopK(0.1),
            ..Default::default()
        };
        let json = cfg.to_json().to_string();
        let back =
            RunConfig::from_json(&crate::util::json::parse(&json).unwrap())
                .unwrap();
        match back.grad_codec {
            GradCodec::TopK(r) => assert_eq!(r.to_bits(), 0.1f32.to_bits()),
            other => panic!("grad_codec mismatch: {other:?}"),
        }
    }

    #[test]
    fn codec_flags_parse_and_gate() {
        let mut cfg = RunConfig::default();
        let args = Args::parse_from(
            ["--codec", "int8"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.codec, Codec::Int8);
        cfg.validate().unwrap(); // smashed codecs work on any algorithm
        // grad_codec topk requires a locked baseline
        cfg.grad_codec = GradCodec::TopK(0.25);
        assert!(cfg.validate().is_err(), "topk requires sfl_v1/v2");
        cfg.algorithm = Algorithm::SflV1;
        cfg.validate().unwrap();
        cfg.algorithm = Algorithm::SflV2;
        cfg.validate().unwrap();
        // ratio bounds
        cfg.grad_codec = GradCodec::TopK(0.0);
        assert!(cfg.validate().is_err(), "ratio 0 rejected");
        cfg.grad_codec = GradCodec::TopK(1.5);
        assert!(cfg.validate().is_err(), "ratio > 1 rejected");
        cfg.grad_codec = GradCodec::TopK(1.0);
        cfg.validate().unwrap();
        // parse surface
        assert!(Codec::parse("nope").is_none());
        assert!(GradCodec::parse("topk:0").is_some(), "gate, not parse");
        assert!(GradCodec::parse("topk:abc").is_none());
        let args = Args::parse_from(
            ["--grad_codec", "topk:0.25"].iter().map(|s| s.to_string()),
        );
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert!(
            matches!(cfg.grad_codec, GradCodec::TopK(r) if r == 0.25)
        );
    }

    #[test]
    fn zo_wire_parses_and_gates_on_heron() {
        let mut cfg = RunConfig::default();
        let args = Args::parse_from(
            ["--zo_wire", "seeds"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.zo_wire, ZoWireMode::Seeds);
        cfg.validate().unwrap(); // default algorithm is HERON
        cfg.algorithm = Algorithm::CseFsl;
        assert!(cfg.validate().is_err(), "seeds mode requires HERON");
        cfg.zo_wire = ZoWireMode::Theta;
        cfg.validate().unwrap();
        assert!(ZoWireMode::parse("nope").is_none());
        assert_eq!(ZoWireMode::parse("lean"), Some(ZoWireMode::Seeds));
    }

    #[test]
    fn seed_agg_parses_and_rejects_non_heron_with_typed_error() {
        let mut cfg = RunConfig::default();
        let args = Args::parse_from(
            ["--zo_wire", "seed_agg"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.zo_wire, ZoWireMode::SeedAgg);
        assert!(cfg.zo_wire.lean_uplink() && cfg.zo_wire.lean_downlink());
        assert!(!ZoWireMode::Seeds.lean_downlink());
        cfg.validate().unwrap(); // default algorithm is HERON
        // every non-HERON algorithm is refused with the *typed* error
        for alg in [
            Algorithm::SflV1,
            Algorithm::SflV2,
            Algorithm::CseFsl,
            Algorithm::FslSage,
        ] {
            cfg.algorithm = alg;
            let err = cfg.validate().unwrap_err();
            let typed = err
                .downcast_ref::<ZoWireConfigError>()
                .expect("seed_agg + non-HERON must carry ZoWireConfigError");
            assert_eq!(typed.zo_wire, ZoWireMode::SeedAgg);
            assert_eq!(typed.algorithm, alg.name());
            // theta mode stays valid for the same algorithm
            let mut ok = cfg.clone();
            ok.zo_wire = ZoWireMode::Theta;
            ok.validate().unwrap();
        }
        assert_eq!(ZoWireMode::parse("agg"), Some(ZoWireMode::SeedAgg));
        // the JSON lap ships "seed_agg" verbatim (Assign handshake path)
        cfg.algorithm = Algorithm::Heron;
        let json = cfg.to_json().to_string();
        let back =
            RunConfig::from_json(&crate::util::json::parse(&json).unwrap())
                .unwrap();
        assert_eq!(back.zo_wire, ZoWireMode::SeedAgg);
    }

    #[test]
    fn drain_flag_parses_and_gates_on_decoupled() {
        let mut cfg = RunConfig::default();
        let args = Args::parse_from(
            ["--drain", "stream"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.drain, DrainMode::Stream);
        // decoupled algorithms stream fine — HERON (default) included
        cfg.validate().unwrap();
        cfg.algorithm = Algorithm::FslSage;
        cfg.validate().unwrap();
        // the locked baselines are rejected with the *typed* error
        for alg in [Algorithm::SflV1, Algorithm::SflV2] {
            cfg.algorithm = alg;
            let err = cfg.validate().unwrap_err();
            let typed = err
                .downcast_ref::<DrainConfigError>()
                .expect("stream+locked must carry a DrainConfigError");
            assert_eq!(typed.drain, DrainMode::Stream);
            assert_eq!(typed.algorithm, alg.name());
            // barrier mode stays valid for the same algorithm
            let mut ok = cfg.clone();
            ok.drain = DrainMode::Barrier;
            ok.validate().unwrap();
        }
        assert!(DrainMode::parse("nope").is_none());
    }

    #[test]
    fn stream_drain_composes_with_seeds_wire_mode() {
        // The decision of record: seeds replay reads only the round's
        // broadcast θ plus the client's own record — it never touches
        // the smashed queue — so stream drain does NOT invalidate it.
        let mut cfg = RunConfig::default();
        cfg.algorithm = Algorithm::Heron;
        cfg.zo_wire = ZoWireMode::Seeds;
        cfg.drain = DrainMode::Stream;
        cfg.validate().unwrap();
        // seed_agg composes identically: the SeedSync replay reads only
        // the cached round-start θ plus the shipped records — never the
        // smashed queue — so stream drain stays legal
        cfg.zo_wire = ZoWireMode::SeedAgg;
        cfg.validate().unwrap();
        // and the inverse gates still hold independently
        cfg.algorithm = Algorithm::CseFsl;
        assert!(cfg.validate().is_err(), "seed_agg still requires HERON");
        cfg.zo_wire = ZoWireMode::Theta;
        cfg.validate().unwrap(); // cse + stream + theta is fine
    }

    #[test]
    fn round_deadline_parses_and_converts() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.round_deadline_ms, 0, "default is unset");
        assert_eq!(cfg.virtual_deadline(), None);
        assert_eq!(cfg.wall_deadline(), None);
        let args = Args::parse_from(
            ["--round_deadline_ms", "2500"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.round_deadline_ms, 2500);
        cfg.validate().unwrap();
        assert_eq!(cfg.virtual_deadline(), Some(2.5));
        assert_eq!(
            cfg.wall_deadline(),
            Some(std::time::Duration::from_millis(2500))
        );
    }

    #[test]
    fn workers_flag_parses() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.workers, 0, "default is auto");
        let args = Args::parse_from(
            ["--workers", "4"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.workers, 4);
        assert!(cfg.describe().contains("workers=4"));
    }

    #[test]
    fn participants_rounding() {
        let mut c = RunConfig::default();
        c.n_clients = 10;
        c.participation = 0.25;
        assert_eq!(c.participants_per_round(), 3);
        c.participation = 0.01;
        assert_eq!(c.participants_per_round(), 1);
    }
}

#[cfg(test)]
mod preset_tests {
    use super::*;

    #[test]
    fn repo_presets_load_and_validate() {
        let mut dir = std::env::current_dir().unwrap();
        loop {
            if dir.join("configs").exists() {
                break;
            }
            assert!(dir.pop(), "configs/ not found above cwd");
        }
        let configs = dir.join("configs");
        let mut count = 0;
        for entry in std::fs::read_dir(&configs).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) == Some("json") {
                let cfg = RunConfig::load(&path)
                    .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
                cfg.validate().unwrap();
                count += 1;
            }
        }
        assert!(count >= 3, "expected >=3 preset configs, found {count}");
    }
}
