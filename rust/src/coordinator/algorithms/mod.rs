//! The SFL algorithm family (substrate S9).
//!
//! Each algorithm is a strategy over the shared round driver
//! (`coordinator::round`): it decides how a client performs one local step,
//! what it uploads, and what the server does with it.
//!
//! * [`Algorithm::Heron`] — the paper's contribution: client-side ZO
//!   (forward-only) updates through the aux head, server-side FO.
//! * [`Algorithm::CseFsl`] — decoupled FO baseline (aux head trained with
//!   local backprop; paper [10]).
//! * [`Algorithm::FslSage`] — CSE-FSL plus periodic aux-gradient alignment
//!   against the server's cut gradient (paper [11]).
//! * [`Algorithm::SflV2`] — traditional split-fed: per-batch smashed upload,
//!   server FO step, cut-gradient download, client backprop (training
//!   lock). On transformer variants this is the SplitLoRA baseline.
//! * [`Algorithm::SflV1`] — as V2 but with per-client server model copies
//!   aggregated at round end.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Heron,
    CseFsl,
    FslSage,
    SflV1,
    SflV2,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Heron => "HERON-SFL",
            Algorithm::CseFsl => "CSE-FSL",
            Algorithm::FslSage => "FSL-SAGE",
            Algorithm::SflV1 => "SFLV1",
            Algorithm::SflV2 => "SFLV2",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "heron" | "heron-sfl" | "heron_sfl" => Some(Algorithm::Heron),
            "cse" | "cse-fsl" | "cse_fsl" => Some(Algorithm::CseFsl),
            "sage" | "fsl-sage" | "fsl_sage" => Some(Algorithm::FslSage),
            "sflv1" | "sfl-v1" => Some(Algorithm::SflV1),
            "sflv2" | "sfl-v2" | "splitlora" => Some(Algorithm::SflV2),
            _ => None,
        }
    }

    pub fn all() -> [Algorithm; 5] {
        [
            Algorithm::SflV1,
            Algorithm::SflV2,
            Algorithm::CseFsl,
            Algorithm::FslSage,
            Algorithm::Heron,
        ]
    }

    /// Decoupled algorithms update clients without per-step server
    /// round-trips (aux-network based).
    pub fn is_decoupled(&self) -> bool {
        matches!(
            self,
            Algorithm::Heron | Algorithm::CseFsl | Algorithm::FslSage
        )
    }

    /// Does the client-side update need backprop (activation caching)?
    pub fn client_uses_backprop(&self) -> bool {
        !matches!(self, Algorithm::Heron)
    }

    /// HLO entries this algorithm needs (used for warmup + manifest
    /// validation).
    pub fn required_entries(&self) -> &'static [&'static str] {
        match self {
            Algorithm::Heron => {
                &["zo_step", "client_fwd", "server_step", "eval_full"]
            }
            Algorithm::CseFsl => {
                &["fo_step", "client_fwd", "server_step", "eval_full"]
            }
            Algorithm::FslSage => &[
                "fo_step",
                "client_fwd",
                "server_step",
                "server_step_cutgrad",
                "aux_align",
                "eval_full",
            ],
            Algorithm::SflV1 | Algorithm::SflV2 => &[
                "client_fwd",
                "server_step_cutgrad",
                "client_bp_step",
                "eval_full",
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Algorithm::parse("heron"), Some(Algorithm::Heron));
        assert_eq!(Algorithm::parse("HERON-SFL"), Some(Algorithm::Heron));
        assert_eq!(Algorithm::parse("splitlora"), Some(Algorithm::SflV2));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn classification_flags() {
        assert!(Algorithm::Heron.is_decoupled());
        assert!(!Algorithm::SflV2.is_decoupled());
        assert!(!Algorithm::Heron.client_uses_backprop());
        assert!(Algorithm::CseFsl.client_uses_backprop());
    }

    #[test]
    fn required_entries_nonempty() {
        for a in Algorithm::all() {
            assert!(!a.required_entries().is_empty());
            assert!(a.required_entries().contains(&"eval_full"));
        }
    }
}
