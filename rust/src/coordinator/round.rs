//! The round driver (substrate S10): executes the four-stage HERON-SFL
//! protocol (paper §IV) and its baselines over the runtime.
//!
//! Per communication round t:
//! 1. *Model initialization* — participants start from the aggregated
//!    θ_l^t (Fed-Server broadcast).
//! 2. *Local phase* — h local steps per client. HERON uses the in-graph ZO
//!    step (Eq. 6); CSE-FSL/FSL-SAGE use local FO; SFLV1/V2 do the
//!    traditional locked exchange (upload smashed, server FO step, download
//!    cut gradient, client backprop). Decoupled methods enqueue smashed
//!    batches every k steps.
//! 3. *Server phase* — the Main-Server drains the queue with FO updates
//!    (Eq. 7; SFLV2-style single server model).
//! 4. *Aggregation* — Fed-Server FedAvg over participants (Eq. 8).
//!
//! ## Parallel execution model
//!
//! The local phase of the decoupled algorithms (HERON, CSE-FSL, FSL-SAGE)
//! is embarrassingly parallel: each client's steps touch only its own
//! loader/optimizer state and read-only shared state. The driver fans
//! those clients out across a worker-thread pool (`util::pool`, sized by
//! `RunConfig::workers`; 0 = all cores), with clients enqueueing smashed
//! batches into the concurrent bounded [`ServerQueue`] as they go. Results
//! are **bit-identical for any worker count or scheduling order** because:
//!
//! * per-client randomness is a counter-based stream derived via
//!   `mix64(run_seed, round << 24 | client << 12 | step)` — no shared RNG
//!   is touched during the fan-out;
//! * every f32 reduction (loss list, FedAvg, queue drain) happens at the
//!   round barrier in participant order, and the Main-Server drains the
//!   queue in the deterministic `(round, client, step)` order (Eq. 7);
//! * participant sampling uses the driver's sequential RNG *before* the
//!   fan-out begins.
//!
//! SFLV1/V2 keep their sequential path: the per-step training lock against
//! the Main-Server is the defining property of those baselines (every
//! batch waits on a server round-trip), so there is no decoupled client
//! phase to parallelize without changing the algorithm.
//!
//! ## Zero-allocation hot loop
//!
//! The decoupled local phase and the server drain run through
//! [`Session::invoke_into`]: inputs are borrowed [`TensorRef`] views of
//! the loader's reused batch buffers, the client's θ, and the frozen base
//! blob, and outputs land in per-client scratch arenas whose buffers are
//! reused across all h steps (the updated θ is *swapped* out of its slot,
//! not copied). The driver itself allocates nothing parameter-sized per
//! step — the old path cloned θ, base, x, and y into every `Call` — and
//! the models allocate no per-probe vectors (their remaining per-call
//! scratch is a bounded handful of buffers). Results are bit-identical
//! to the allocating `Call` path, which the cold branches (SFLV1/V2
//! locked exchange, alignment, eval) still use.

use crate::coordinator::accounting::CostBook;
use crate::coordinator::aggregator::fedavg_into;
use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::config::RunConfig;
use crate::coordinator::eventsim::{
    ClientLane, DeviceProfile, RoundSim, RoundTiming,
};
use crate::coordinator::server_queue::{ServerQueue, SmashedBatch};
use crate::data::loader::{Loader, Task};
use crate::data::partition::Partition;
use crate::metrics::{RoundRecord, RunRecord};
use crate::runtime::manifest::EntrySpec;
use crate::runtime::tensor::{TensorRef, TensorValue};
use crate::runtime::{Call, Session};
use crate::util::pool;
use crate::util::rng::{mix64, Xoshiro256pp};
use anyhow::{bail, Context, Result};

/// Adam state threading through the step entries ((m, v, t) or stateless).
#[derive(Debug, Clone)]
pub enum OptState {
    None,
    Adam { m: Vec<f32>, v: Vec<f32>, t: f32 },
}

impl OptState {
    pub fn new(opt_state: usize, dim: usize) -> Self {
        if opt_state == 0 {
            OptState::None
        } else {
            OptState::Adam {
                m: vec![0.0; dim],
                v: vec![0.0; dim],
                t: 0.0,
            }
        }
    }
}

struct ClientState {
    loader: Loader,
    opt_local: OptState,
    /// SFLV1/V2: separate optimizer for θ_c-only backprop updates
    opt_client: OptState,
    shard_weight: f64,
    /// last uploaded batch (FSL-SAGE alignment needs it)
    last_upload: Option<(Vec<f32>, Vec<i32>, Vec<i32>)>, // smashed, y, x
}

/// Read-only context shared by all client worker threads during the
/// decoupled fan-out phase.
struct LocalCtx<'a> {
    session: &'a Session,
    cfg: &'a RunConfig,
    book: &'a CostBook,
    base: Option<&'a [f32]>,
    task: Task,
    round_idx: usize,
    profile: DeviceProfile,
    nc: usize,
}

/// What one client's local phase produces, merged at the round barrier in
/// participant order.
struct LocalOutcome {
    ci: usize,
    theta: Vec<f32>,
    losses: Vec<f64>,
    comm_bytes: u64,
    flops: u64,
    lane: ClientLane,
}

pub struct Driver<'s> {
    pub session: &'s Session,
    pub cfg: RunConfig,
    pub book: CostBook,
    task: Task,
    base: Option<Vec<f32>>,
    pub theta_l: Vec<f32>,
    pub theta_s: Vec<f32>,
    opt_server: OptState,
    /// SFLV1: per-client server replicas (θ_s, opt)
    server_replicas: Vec<(Vec<f32>, OptState)>,
    clients: Vec<ClientState>,
    rng: Xoshiro256pp,
    pub comm_bytes: u64,
    pub flops_client: u64,
    profile: DeviceProfile,
    pub timings: Vec<RoundTiming>,
    nc: usize,
    ns: usize,
    round_idx: usize,
    // reusable aggregation buffer
    agg_buf: Vec<f32>,
    // reusable output slots for the server-phase invoke_into calls
    inv_outs: Vec<TensorValue>,
}

impl<'s> Driver<'s> {
    pub fn new(session: &'s Session, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let v = session.variant(&cfg.variant)?.clone();
        for e in cfg.algorithm.required_entries() {
            if !v.entries.contains_key(*e) {
                bail!(
                    "variant {} lacks entry {e} required by {}",
                    cfg.variant,
                    cfg.algorithm.name()
                );
            }
        }
        let task = if v.task == "lm" { Task::Lm } else { Task::Vision };
        let base = if v.size_base > 0 {
            Some(v.blob("frozen_base")?)
        } else {
            None
        };
        let theta_l = v.blob("init_theta_l")?;
        let theta_s = v.blob("init_theta_s")?;
        let (nc, nl, ns) = (v.size_client, v.size_local(), v.size_server);
        if theta_l.len() != nl || theta_s.len() != ns {
            bail!("init blob sizes disagree with manifest");
        }

        let part = match task {
            Task::Vision => Partition::vision(
                cfg.data_seed,
                cfg.dataset_size,
                cfg.n_clients,
                cfg.scheme,
            ),
            Task::Lm => Partition::text(
                cfg.data_seed,
                cfg.dataset_size,
                cfg.n_clients,
                cfg.scheme,
            ),
        };
        let total: usize = part.sizes().iter().sum();
        let clients = part
            .clients
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let shard = if shard.is_empty() {
                    vec![(i as u64) % cfg.dataset_size] // degenerate shard fallback
                } else {
                    shard.clone()
                };
                let w = shard.len() as f64 / total.max(1) as f64;
                ClientState {
                    loader: Loader::new(
                        task,
                        cfg.data_seed,
                        shard,
                        v.batch,
                        mix64(cfg.run_seed, 0x10AD ^ i as u64),
                    ),
                    opt_local: OptState::new(v.opt_state, nl),
                    opt_client: OptState::new(v.opt_state, nc),
                    shard_weight: w,
                    last_upload: None,
                }
            })
            .collect();

        let server_replicas = if cfg.algorithm == Algorithm::SflV1 {
            (0..cfg.n_clients)
                .map(|_| (theta_s.clone(), OptState::new(v.opt_state, ns)))
                .collect()
        } else {
            Vec::new()
        };

        let opt_state = v.opt_state;
        Ok(Driver {
            session,
            book: CostBook::new(&v, cfg.algorithm, cfg.n_pert as u64),
            task,
            base,
            theta_l,
            theta_s,
            opt_server: OptState::new(opt_state, ns),
            server_replicas,
            clients,
            rng: Xoshiro256pp::new(cfg.run_seed),
            comm_bytes: 0,
            flops_client: 0,
            profile: DeviceProfile::edge_default(),
            timings: Vec::new(),
            nc,
            ns,
            round_idx: 0,
            agg_buf: vec![0.0; nl],
            inv_outs: Vec::new(),
            cfg,
        })
    }

    pub fn warmup(&self) -> Result<()> {
        self.session
            .warmup(&self.cfg.variant, self.cfg.algorithm.required_entries())
    }

    fn call<'a>(&'a self, entry: &'a str) -> Call<'a> {
        let mut c = Call::new(self.session, &self.cfg.variant, entry);
        if let Some(b) = &self.base {
            c = c.arg("base", b.clone());
        }
        c
    }

    fn opt_args<'a>(mut c: Call<'a>, opt: &OptState) -> Call<'a> {
        if let OptState::Adam { m, v, t } = opt {
            c = c
                .arg("opt_m", m.clone())
                .arg("opt_v", v.clone())
                .arg("opt_t", *t);
        }
        c
    }

    fn take_opt(
        outs: &mut std::collections::HashMap<String, TensorValue>,
        opt: &mut OptState,
    ) -> Result<()> {
        if let OptState::Adam { m, v, t } = opt {
            *m = outs
                .remove("opt_m")
                .context("opt_m output")?
                .into_f32()?;
            *v = outs
                .remove("opt_v")
                .context("opt_v output")?
                .into_f32()?;
            *t = outs
                .remove("opt_t")
                .context("opt_t output")?
                .scalar_f32()?;
        }
        Ok(())
    }

    fn batch_xy(&self, client: usize) -> (TensorValue, Vec<i32>) {
        loader_batch_xy(self.task, &self.clients[client].loader)
    }

    /// One full communication round. Returns the train-loss mean over all
    /// local steps.
    pub fn run_round(&mut self) -> Result<f64> {
        let participants = self.sample_participants();
        let mut sim = RoundSim::new(&self.profile, self.cfg.n_clients);
        let queue = ServerQueue::new(
            participants.len()
                * (self.cfg.local_steps / self.cfg.upload_every + 1),
        );
        let mut losses: Vec<f64> = Vec::new();
        let mut updated: Vec<(usize, Vec<f32>)> = Vec::new();

        if self.cfg.algorithm.is_decoupled() {
            self.local_fanout(
                &participants,
                &queue,
                &mut sim,
                &mut losses,
                &mut updated,
            )?;
        } else {
            // SFLV1/V2: the per-step training lock serializes each client
            // against the Main-Server — executed sequentially by design.
            sim.set_workers(1);
            for &ci in &participants {
                let theta_start = self.theta_l.clone();
                let theta_end = self
                    .local_phase_locked(ci, theta_start, &mut sim, &mut losses)?;
                self.comm_bytes += self.book.comm_per_round_sync();
                sim.sync(self.book.comm_per_round_sync());
                updated.push((ci, theta_end));
            }
        }

        // ---- server phase: drain queued smashed batches (Eq. 7) ----
        // The concurrent queue is drained at the barrier in deterministic
        // (round, client, step) order, which matches the order a purely
        // sequential client loop would have produced.
        if self.cfg.algorithm.is_decoupled() {
            let mut sage_feedback: Vec<(usize, Vec<f32>)> = Vec::new();
            for b in queue.drain_sorted() {
                let want_cutgrad = self.cfg.algorithm == Algorithm::FslSage
                    && b.step % (self.cfg.upload_every * self.cfg.align_every)
                        == 0;
                let g = self.server_consume(&b, want_cutgrad, &mut sim)?;
                if let Some(g_sm) = g {
                    sage_feedback.push((b.client, g_sm));
                }
            }
            // FSL-SAGE: clients align their aux model against the returned
            // cut gradients (one alignment per feedback message)
            for (ci, g_sm) in sage_feedback {
                self.comm_bytes += self.book.comm_per_alignment();
                sim.client_download(ci, self.book.comm_per_alignment());
                if let Some(pos) =
                    updated.iter().position(|(c, _)| *c == ci)
                {
                    let (sm, y, _x) = self.clients[ci]
                        .last_upload
                        .clone()
                        .context("sage alignment without upload")?;
                    let theta = updated[pos].1.clone();
                    let mut outs = self
                        .call("aux_align")
                        .arg("theta_l", theta)
                        .arg("smashed", sm)
                        .arg("y", TensorValue::I32(y))
                        .arg("g_smashed", g_sm)
                        .arg("lr", self.cfg.lr_client)
                        .run()?;
                    updated[pos].1 = outs
                        .remove("theta_l")
                        .context("aux_align theta_l")?
                        .into_f32()?;
                }
            }
        }
        sim.record_queue(queue.stats());

        // ---- aggregation (Fed-Server, Eq. 8) ----
        if !updated.is_empty() {
            let refs: Vec<&[f32]> =
                updated.iter().map(|(_, t)| t.as_slice()).collect();
            let weights: Vec<f64> = updated
                .iter()
                .map(|(c, _)| self.clients[*c].shard_weight.max(1e-9))
                .collect();
            fedavg_into(&refs, &weights, &mut self.agg_buf);
            if self.cfg.algorithm.is_decoupled() {
                self.theta_l.copy_from_slice(&self.agg_buf);
            } else {
                // SFLV1/V2: only θ_c is client-trained; aux stays at init
                self.theta_l[..self.nc]
                    .copy_from_slice(&self.agg_buf[..self.nc]);
            }
        }

        // SFLV1: aggregate the per-client server replicas into all replicas
        if self.cfg.algorithm == Algorithm::SflV1 {
            let refs: Vec<&[f32]> = participants
                .iter()
                .map(|&c| self.server_replicas[c].0.as_slice())
                .collect();
            let w = vec![1.0; refs.len()];
            let mut mean = vec![0.0f32; self.ns];
            fedavg_into(&refs, &w, &mut mean);
            self.theta_s.copy_from_slice(&mean);
            for (rep, _) in &mut self.server_replicas {
                rep.copy_from_slice(&mean);
            }
        }

        self.timings.push(sim.finish());
        self.round_idx += 1;
        Ok(losses.iter().sum::<f64>() / losses.len().max(1) as f64)
    }

    fn sample_participants(&mut self) -> Vec<usize> {
        let k = self.cfg.participants_per_round();
        let mut idx = self.rng.sample_indices(self.cfg.n_clients, k);
        idx.sort_unstable();
        idx
    }

    // ---- parallel local phase (decoupled algorithms) ---------------------

    /// Fan the participants' local phases out across the worker pool and
    /// merge outcomes at the barrier in participant order.
    fn local_fanout(
        &mut self,
        participants: &[usize],
        queue: &ServerQueue,
        sim: &mut RoundSim,
        losses: &mut Vec<f64>,
        updated: &mut Vec<(usize, Vec<f32>)>,
    ) -> Result<()> {
        let eff = pool::effective_workers(self.cfg.workers, participants.len());
        sim.set_workers(eff);
        let theta0 = self.theta_l.clone();
        let ctx = LocalCtx {
            session: self.session,
            cfg: &self.cfg,
            book: &self.book,
            base: self.base.as_deref(),
            task: self.task,
            round_idx: self.round_idx,
            profile: self.profile,
            nc: self.nc,
        };
        // Disjoint &mut borrows of the participating client states.
        let jobs: Vec<(usize, &mut ClientState)> = self
            .clients
            .iter_mut()
            .enumerate()
            .filter(|(ci, _)| participants.binary_search(ci).is_ok())
            .collect();
        let results = pool::run_jobs(eff, jobs, |(ci, state)| {
            client_local_phase(&ctx, ci, state, theta0.clone(), queue)
        });
        for res in results {
            let out = res?;
            losses.extend(out.losses);
            self.comm_bytes +=
                out.comm_bytes + self.book.comm_per_round_sync();
            self.flops_client += out.flops;
            sim.merge_lane(out.ci, &out.lane);
            sim.sync(self.book.comm_per_round_sync());
            updated.push((out.ci, out.theta));
        }
        Ok(())
    }

    // ---- locked local phase (SFLV1/V2) -----------------------------------

    /// Traditional SFL (V1/V2): every batch runs the locked exchange.
    fn local_phase_locked(
        &mut self,
        ci: usize,
        mut theta: Vec<f32>,
        sim: &mut RoundSim,
        losses: &mut Vec<f64>,
    ) -> Result<Vec<f32>> {
        let mut opt_c = std::mem::replace(
            &mut self.clients[ci].opt_client,
            OptState::None,
        );
        let server_fwd_flops = self.variant_server_flops();
        for _step in 1..=self.cfg.local_steps {
            self.clients[ci].loader.next_batch();
            let (x, y) = self.batch_xy(ci);
            // client forward to the cut layer
            let mut outs = self
                .call("client_fwd")
                .arg("theta_c", theta[..self.nc].to_vec())
                .arg("x", x.clone())
                .run()?;
            let smashed = outs
                .remove("smashed")
                .context("smashed")?
                .into_f32()?;
            let fwd = self.book.flops_per_step / 3; // 1 of 3F_c is the fwd
            self.flops_client += fwd;
            sim.client_compute(ci, fwd);
            self.comm_bytes += self.book.smashed_bytes;
            sim.client_upload(ci, self.book.smashed_bytes);

            // server step on this client's replica (V1) or the shared model
            // (V2); returns the cut gradient
            let (theta_s, opt_s) = match self.cfg.algorithm {
                Algorithm::SflV1 => {
                    let (t, o) = &mut self.server_replicas[ci];
                    (t, o)
                }
                _ => (&mut self.theta_s, &mut self.opt_server),
            };
            let mut souts = {
                let mut c = Call::new(
                    self.session,
                    &self.cfg.variant,
                    "server_step_cutgrad",
                );
                if let Some(b) = &self.base {
                    c = c.arg("base", b.clone());
                }
                c = c.arg("theta_s", theta_s.clone());
                if let OptState::Adam { m, v, t } = &*opt_s {
                    c = c
                        .arg("opt_m", m.clone())
                        .arg("opt_v", v.clone())
                        .arg("opt_t", *t);
                }
                c.arg("smashed", smashed)
                    .arg("y", TensorValue::I32(y.clone()))
                    .arg("lr", self.cfg.lr_server)
                    .run()?
            };
            *theta_s = souts
                .remove("theta_s")
                .context("server theta_s")?
                .into_f32()?;
            Self::take_opt(&mut souts, opt_s)?;
            losses.push(
                souts.remove("loss").context("server loss")?.scalar_f32()?
                    as f64,
            );
            let g_sm = souts
                .remove("g_smashed")
                .context("g_smashed")?
                .into_f32()?;
            // training lock: the client waits for the server's fwd+bwd
            sim.client_blocked_on_server(ci, 3 * server_fwd_flops);
            self.comm_bytes += self.book.cutgrad_bytes;
            sim.client_download(ci, self.book.cutgrad_bytes);

            // client backprop from the relayed cut gradient
            let mut bouts = Self::opt_args(
                self.call("client_bp_step")
                    .arg("theta_c", theta[..self.nc].to_vec()),
                &opt_c,
            )
            .arg("x", x)
            .arg("g_smashed", g_sm)
            .arg("lr", self.cfg.lr_client)
            .run()?;
            let new_c = bouts
                .remove("theta_c")
                .context("bp theta_c")?
                .into_f32()?;
            theta[..self.nc].copy_from_slice(&new_c);
            Self::take_opt(&mut bouts, &mut opt_c)?;
            let bwd = 2 * (self.book.flops_per_step / 3);
            self.flops_client += bwd;
            sim.client_compute(ci, bwd);
        }
        self.clients[ci].opt_client = opt_c;
        Ok(theta)
    }

    /// Consume one queued smashed batch (Eq. 7) through the
    /// zero-allocation invoke path: borrowed inputs, outputs into the
    /// driver's reused slot vector, θ_s swapped (not copied) back.
    fn server_consume(
        &mut self,
        b: &SmashedBatch,
        want_cutgrad: bool,
        sim: &mut RoundSim,
    ) -> Result<Option<Vec<f32>>> {
        if !matches!(self.opt_server, OptState::None) {
            bail!(
                "server drain: stateful optimizers are not wired through \
                 the native entries (manifest opt_state must be 0)"
            );
        }
        let entry = if want_cutgrad {
            "server_step_cutgrad"
        } else {
            "server_step"
        };
        let session = self.session;
        let espec = session.variant(&self.cfg.variant)?.entry(entry)?;
        let ti = espec.output_pos("theta_s")?;
        let mut named: Vec<(&str, TensorRef)> = Vec::with_capacity(5);
        if let Some(base) = self.base.as_deref() {
            named.push(("base", TensorRef::F32(base)));
        }
        named.push(("theta_s", TensorRef::F32(&self.theta_s)));
        named.push(("smashed", TensorRef::F32(&b.smashed)));
        named.push(("y", TensorRef::I32(&b.targets)));
        named.push(("lr", TensorRef::ScalarF32(self.cfg.lr_server)));
        let inputs = bind_entry_inputs(espec, &named)?;
        session.invoke_into(
            &self.cfg.variant,
            entry,
            &inputs,
            &mut self.inv_outs,
        )?;
        match &mut self.inv_outs[ti] {
            TensorValue::F32(v) => std::mem::swap(&mut self.theta_s, v),
            other => bail!(
                "{entry}: theta_s output has wrong dtype {:?}",
                other.dtype()
            ),
        }
        sim.server_compute(3 * self.variant_server_flops());
        Ok(if want_cutgrad {
            let gi = espec.output_pos("g_smashed")?;
            match std::mem::replace(
                &mut self.inv_outs[gi],
                TensorValue::ScalarF32(0.0),
            ) {
                TensorValue::F32(v) => Some(v),
                other => bail!(
                    "{entry}: g_smashed output has wrong dtype {:?}",
                    other.dtype()
                ),
            }
        } else {
            None
        })
    }

    fn variant_server_flops(&self) -> u64 {
        let v = self
            .session
            .variant(&self.cfg.variant)
            .expect("variant exists");
        v.cost.flops_fwd_server as u64 * v.batch as u64
    }

    // ---- evaluation ---------------------------------------------------------

    /// Evaluate the assembled global model on a held-out batch.
    /// Returns (metric, raw_stats): vision accuracy in [0,1], or LM
    /// perplexity.
    pub fn evaluate(&self) -> Result<f64> {
        let v = self.session.variant(&self.cfg.variant)?;
        let eb = v.eval_batch;
        let (x, y): (TensorValue, Vec<i32>) = match self.task {
            Task::Vision => {
                let (xs, ys) = crate::data::loader::eval_batch_vision(
                    self.cfg.data_seed,
                    self.cfg.eval_holdout,
                    eb,
                );
                (TensorValue::F32(xs), ys)
            }
            Task::Lm => {
                let xs = crate::data::loader::eval_batch_text(
                    self.cfg.data_seed,
                    self.cfg.eval_holdout,
                    eb,
                );
                (TensorValue::I32(xs.clone()), xs)
            }
        };
        let outs = self
            .call("eval_full")
            .arg("theta_c", self.theta_l[..self.nc].to_vec())
            .arg("theta_s", self.theta_s.clone())
            .arg("x", x)
            .arg("y", TensorValue::I32(y))
            .run()?;
        let s1 = outs.get("stat1").context("stat1")?.scalar_f32()? as f64;
        let s2 = outs.get("stat2").context("stat2")?.scalar_f32()? as f64;
        Ok(match self.task {
            Task::Vision => s1 / s2.max(1.0), // accuracy
            Task::Lm => (s1 / s2.max(1.0)).exp(), // perplexity
        })
    }

    /// Run the configured number of rounds, recording curves.
    pub fn run(&mut self, record_name: &str) -> Result<RunRecord> {
        self.warmup()?;
        let mut rec = RunRecord::new(record_name);
        let t0 = std::time::Instant::now();
        for round in 0..self.cfg.rounds {
            let loss = self.run_round()?;
            let eval_due = self.cfg.eval_every > 0
                && (round % self.cfg.eval_every == 0
                    || round + 1 == self.cfg.rounds);
            let metric = if eval_due { self.evaluate()? } else { f64::NAN };
            rec.push(RoundRecord {
                round,
                train_loss: loss,
                eval_metric: metric,
                comm_bytes_cum: self.comm_bytes,
                wall_seconds: t0.elapsed().as_secs_f64(),
            });
            if eval_due {
                log::info!(
                    "[{}] round {round}: loss {loss:.4} metric {metric:.4} comm {}",
                    record_name,
                    crate::coordinator::accounting::fmt_bytes(self.comm_bytes)
                );
            }
        }
        rec.set("comm_bytes", self.comm_bytes as f64);
        rec.set("client_flops", self.flops_client as f64);
        rec.set(
            "peak_mem_bytes",
            self.book.peak_mem_bytes as f64,
        );
        rec.set(
            "virtual_seconds",
            self.timings.iter().map(|t| t.total()).sum(),
        );
        rec.set(
            "client_idle_seconds",
            self.timings.iter().map(|t| t.client_idle).sum(),
        );
        rec.set(
            "host_makespan_seconds",
            self.timings.iter().map(|t| t.host_makespan).sum(),
        );
        rec.set(
            "queue_enqueued",
            self.timings.iter().map(|t| t.queue.enqueued as f64).sum(),
        );
        rec.set(
            "queue_dropped",
            self.timings.iter().map(|t| t.queue.dropped as f64).sum(),
        );
        rec.set(
            "queue_max_depth",
            self.timings
                .iter()
                .map(|t| t.queue.max_depth as f64)
                .fold(0.0, f64::max),
        );
        Ok(rec)
    }
}

// ---------------------------------------------------------------------------
// worker-thread client phase (decoupled algorithms)
// ---------------------------------------------------------------------------

fn loader_batch_xy(task: Task, loader: &Loader) -> (TensorValue, Vec<i32>) {
    match task {
        Task::Vision => (
            TensorValue::F32(loader.xs_f32.clone()),
            loader.ys.clone(),
        ),
        Task::Lm => (
            TensorValue::I32(loader.xs_i32.clone()),
            loader.xs_i32.clone(),
        ),
    }
}

fn step_seed(ctx: &LocalCtx, client: usize, step: usize) -> i32 {
    mix64(
        ctx.cfg.run_seed,
        (ctx.round_idx as u64) << 24 | (client as u64) << 12 | step as u64,
    ) as i32
}

/// Borrow the loader's reused batch buffer as the entry's `x` input.
fn x_ref(task: Task, loader: &Loader) -> TensorRef<'_> {
    match task {
        Task::Vision => TensorRef::F32(&loader.xs_f32),
        Task::Lm => TensorRef::I32(&loader.xs_i32),
    }
}

/// Borrow the loader's target buffer (LM entries take the token batch).
fn y_slice(task: Task, loader: &Loader) -> &[i32] {
    match task {
        Task::Vision => &loader.ys,
        Task::Lm => &loader.xs_i32,
    }
}

/// Build the positional input list for `espec` from named borrowed
/// buffers. Scalars travel by value; a spec input with no binding (e.g.
/// optimizer-state tensors the native manifest never emits) is an error.
fn bind_entry_inputs<'a>(
    espec: &EntrySpec,
    named: &[(&str, TensorRef<'a>)],
) -> Result<Vec<TensorRef<'a>>> {
    let mut out = Vec::with_capacity(espec.inputs.len());
    for spec in &espec.inputs {
        let r = named
            .iter()
            .find(|(n, _)| *n == spec.name)
            .map(|(_, r)| *r)
            .with_context(|| {
                format!("{}: no binding for input {}", espec.name, spec.name)
            })?;
        out.push(r);
    }
    Ok(out)
}

/// One client's full local phase (h steps + uploads), self-contained so it
/// can run on any worker thread. Mutates only this client's state; all
/// cross-client effects go through the concurrent queue and the returned
/// outcome.
///
/// The loop is allocation-lean: every input is a borrowed view (θ, the
/// loader's batch buffers, the frozen base), outputs land in the two
/// scratch arenas below, and the updated θ is swapped out of its slot —
/// the same two parameter buffers ping-pong through all h steps.
fn client_local_phase(
    ctx: &LocalCtx,
    ci: usize,
    cs: &mut ClientState,
    mut theta: Vec<f32>,
    queue: &ServerQueue,
) -> Result<LocalOutcome> {
    let mut lane = ClientLane::new(&ctx.profile);
    let mut losses = Vec::with_capacity(ctx.cfg.local_steps);
    let mut comm_bytes = 0u64;
    let mut flops = 0u64;
    let zo = ctx.cfg.algorithm == Algorithm::Heron;
    let entry = if zo { "zo_step" } else { "fo_step" };
    if !matches!(cs.opt_local, OptState::None) {
        bail!(
            "local phase: stateful optimizers are not wired through the \
             native entries (manifest opt_state must be 0)"
        );
    }
    let vspec = ctx.session.variant(&ctx.cfg.variant)?;
    let step_espec = vspec.entry(entry)?;
    let fwd_espec = vspec.entry("client_fwd")?;
    let ti = step_espec.output_pos("theta_l")?;
    let li = step_espec.output_pos("loss")?;
    let si = fwd_espec.output_pos("smashed")?;
    // per-client scratch arenas, reused across all h steps
    let mut outs: Vec<TensorValue> = Vec::new();
    let mut fwd_outs: Vec<TensorValue> = Vec::new();

    for step in 1..=ctx.cfg.local_steps {
        cs.loader.next_batch();
        let seed = step_seed(ctx, ci, step);
        let mut named: Vec<(&str, TensorRef)> = Vec::with_capacity(8);
        if let Some(b) = ctx.base {
            named.push(("base", TensorRef::F32(b)));
        }
        named.push(("theta_l", TensorRef::F32(&theta)));
        named.push(("x", x_ref(ctx.task, &cs.loader)));
        named.push(("y", TensorRef::I32(y_slice(ctx.task, &cs.loader))));
        named.push(("lr", TensorRef::ScalarF32(ctx.cfg.lr_client)));
        if zo {
            named.push(("seed", TensorRef::ScalarI32(seed)));
            named.push(("mu", TensorRef::ScalarF32(ctx.cfg.mu)));
            named.push((
                "n_pert",
                TensorRef::ScalarI32(ctx.cfg.n_pert as i32),
            ));
        }
        let inputs = bind_entry_inputs(step_espec, &named)?;
        ctx.session
            .invoke_into(&ctx.cfg.variant, entry, &inputs, &mut outs)?;
        match &mut outs[ti] {
            TensorValue::F32(v) => std::mem::swap(&mut theta, v),
            other => bail!(
                "{entry}: theta_l output has wrong dtype {:?}",
                other.dtype()
            ),
        }
        losses.push(outs[li].scalar_f32()? as f64);
        flops += ctx.book.flops_per_step;
        lane.compute(ctx.book.flops_per_step);

        if step % ctx.cfg.upload_every == 0 {
            upload_smashed(
                ctx,
                ci,
                cs,
                &theta,
                fwd_espec,
                si,
                step,
                queue,
                &mut lane,
                &mut comm_bytes,
                &mut fwd_outs,
            )?;
        }
    }
    Ok(LocalOutcome {
        ci,
        theta,
        losses,
        comm_bytes,
        flops,
        lane,
    })
}

fn upload_smashed(
    ctx: &LocalCtx,
    ci: usize,
    cs: &mut ClientState,
    theta: &[f32],
    fwd_espec: &EntrySpec,
    smashed_idx: usize,
    step: usize,
    queue: &ServerQueue,
    lane: &mut ClientLane,
    comm_bytes: &mut u64,
    fwd_outs: &mut Vec<TensorValue>,
) -> Result<()> {
    let mut named: Vec<(&str, TensorRef)> = Vec::with_capacity(3);
    if let Some(b) = ctx.base {
        named.push(("base", TensorRef::F32(b)));
    }
    named.push(("theta_c", TensorRef::F32(&theta[..ctx.nc])));
    named.push(("x", x_ref(ctx.task, &cs.loader)));
    let inputs = bind_entry_inputs(fwd_espec, &named)?;
    ctx.session.invoke_into(
        &ctx.cfg.variant,
        "client_fwd",
        &inputs,
        fwd_outs,
    )?;
    // the queue owns the smashed batch, so move it out of its slot (the
    // slot re-grows a buffer on the next upload)
    let smashed = match std::mem::replace(
        &mut fwd_outs[smashed_idx],
        TensorValue::ScalarF32(0.0),
    ) {
        TensorValue::F32(v) => v,
        other => bail!(
            "client_fwd: smashed output has wrong dtype {:?}",
            other.dtype()
        ),
    };
    // the upload forward is part of the protocol but NOT an extra
    // training cost in Table I (the paper's accounting charges the ZO /
    // FO step); we still charge its flops to the client sim for latency
    lane.compute(
        (ctx.book.flops_per_step / (ctx.cfg.n_pert as u64 + 1)).max(1),
    );
    *comm_bytes += ctx.book.comm_per_step(true);
    lane.upload(ctx.book.smashed_bytes);
    let targets = y_slice(ctx.task, &cs.loader).to_vec();
    // only the FSL-SAGE alignment ever reads last_upload — don't pay a
    // full smashed-batch copy per upload on the other algorithms
    if ctx.cfg.algorithm == Algorithm::FslSage {
        let x_i32 = match ctx.task {
            Task::Lm => cs.loader.xs_i32.clone(),
            Task::Vision => Vec::new(),
        };
        cs.last_upload =
            Some((smashed.clone(), targets.clone(), x_i32));
    }
    queue.push(SmashedBatch {
        client: ci,
        round: ctx.round_idx,
        step,
        smashed,
        targets,
    });
    Ok(())
}
