//! The round driver (substrate S10): executes the four-stage HERON-SFL
//! protocol (paper §IV) and its baselines over the runtime.
//!
//! Per communication round t:
//! 1. *Model initialization* — participants start from the aggregated
//!    θ_l^t (Fed-Server broadcast).
//! 2. *Local phase* — h local steps per client. HERON uses the in-graph ZO
//!    step (Eq. 6); CSE-FSL/FSL-SAGE use local FO; SFLV1/V2 do the
//!    traditional locked exchange (upload smashed, server FO step, download
//!    cut gradient, client backprop). Decoupled methods enqueue smashed
//!    batches every k steps.
//! 3. *Server phase* — the Main-Server drains the queue with FO updates
//!    (Eq. 7; SFLV2-style single server model).
//! 4. *Aggregation* — Fed-Server FedAvg over participants (Eq. 8).
//!
//! ## Parallel execution model
//!
//! The local phase of the decoupled algorithms (HERON, CSE-FSL, FSL-SAGE)
//! is embarrassingly parallel: each client's steps touch only its own
//! loader/optimizer state and read-only shared state. The driver fans
//! those clients out across a worker-thread pool (`util::pool`, sized by
//! `RunConfig::workers`; 0 = all cores), with clients enqueueing smashed
//! batches into the concurrent bounded [`ServerQueue`] as they go. Results
//! are **bit-identical for any worker count or scheduling order** because:
//!
//! * per-client randomness is a counter-based stream derived via
//!   `mix64(run_seed, round << 24 | client << 12 | step)` — no shared RNG
//!   is touched during the fan-out;
//! * every f32 reduction (loss list, FedAvg, queue drain) happens at the
//!   round barrier in participant order, and the Main-Server drains the
//!   queue in the deterministic `(round, client, step)` order (Eq. 7);
//! * participant sampling uses the driver's sequential RNG *before* the
//!   fan-out begins.
//!
//! SFLV1/V2 keep their sequential path: the per-step training lock against
//! the Main-Server is the defining property of those baselines (every
//! batch waits on a server round-trip), so there is no decoupled client
//! phase to parallelize without changing the algorithm.
//!
//! ## Drain policies (`--drain barrier|stream`)
//!
//! *When* the Main-Server consumes the queued uploads is pluggable
//! ([`crate::coordinator::drain`]). The default `barrier` policy holds
//! everything to the round barrier and drains in Eq. (7) order —
//! bit-identical for any worker count. The `stream` policy overlaps the
//! phases: the fan-out produces from a spawned thread while the driver
//! thread consumes the queue in arrival order mid-round
//! ([`Driver::server_pump`] is the same mid-round hook for the
//! networked dispatcher). For HERON and CSE-FSL the θ_l trajectory,
//! per-step losses, and all analytic accounting stay bit-identical —
//! the client phase never reads θ_s — while θ_s (and the eval metric)
//! absorbs batches in arrival order. FSL-SAGE's alignment feedback is a
//! cut gradient of the *mid-round* θ_s, so under `stream` its aligned
//! θ_l inherits the arrival order too. Either way, stream trades the
//! bit-identity contract for server-side latency (measured by the
//! event-sim's `server_makespan_{barrier,stream}` comparison).
//!
//! ## Shared phases, two execution modes
//!
//! The client-side step loops live in [`crate::coordinator::local`] and
//! the server-side round phases are the public(-in-crate) methods below
//! ([`Driver::server_drain`], [`Driver::locked_server_exchange`],
//! [`Driver::absorb_outcome`], [`Driver::finish_round`]). `run_round`
//! composes them in-process; the networked dispatcher (`net::server`)
//! composes the *same* methods around wire messages, which is why a
//! TCP-loopback run is bit-identical to the in-process trajectory.
//!
//! ## Typed zero-allocation hot loop
//!
//! Every model call — the decoupled local phase, the server drain, the
//! locked exchange, alignment, eval — goes through the typed
//! [`crate::runtime::api::ClientRuntime`] surface resolved once per
//! phase from the session: no entry-name strings, no per-call argument
//! binding, concrete types end to end (the acceptance contract is that
//! no `invoke`/`invoke_into` with a hard-coded entry name remains in
//! `coordinator/` or `net/`). Inputs are borrowed views of the loader's
//! reused batch buffers, the client's θ, and the frozen base blob;
//! outputs land in per-client scratch arenas reused across all h steps
//! (the updated θ is *swapped* between two ping-pong buffers, never
//! copied). The driver allocates nothing parameter-sized per step, and
//! the models allocate no per-probe vectors. Results are bit-identical
//! to the name-based `Session::invoke` path, which remains for artifact
//! validation and analysis tooling.

use crate::coordinator::accounting::CostBook;
use crate::coordinator::aggregator::fedavg_into;
use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::config::{RunConfig, ZoWireMode};
use crate::coordinator::eventsim::{DeviceProfile, RoundSim, RoundTiming};
use crate::coordinator::local::{
    self, ClientPool, ClientState, LocalCtx, LocalOutcome,
};
use crate::coordinator::server_queue::{ServerQueue, SmashedBatch};
use crate::data::loader::Task;
use crate::metrics::{RoundRecord, RunRecord};
use crate::runtime::api::ClientRuntime;
use crate::runtime::tensor::TensorValue;
use crate::runtime::Session;
use crate::util::pool;
use crate::util::rng::Xoshiro256pp;
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};

/// Adam state threading through the step entries ((m, v, t) or stateless).
#[derive(Debug, Clone)]
pub enum OptState {
    None,
    Adam { m: Vec<f32>, v: Vec<f32>, t: f32 },
}

impl OptState {
    pub fn new(opt_state: usize, dim: usize) -> Self {
        if opt_state == 0 {
            OptState::None
        } else {
            OptState::Adam {
                m: vec![0.0; dim],
                v: vec![0.0; dim],
                t: 0.0,
            }
        }
    }
}

pub struct Driver<'s> {
    pub session: &'s Session,
    pub cfg: RunConfig,
    pub book: CostBook,
    task: Task,
    base: Option<Vec<f32>>,
    pub theta_l: Vec<f32>,
    pub theta_s: Vec<f32>,
    opt_server: OptState,
    /// SFLV1: the value every server replica holds *between* rounds
    /// (`finish_round` copies the participant average into all replicas,
    /// so they are provably equal there). Per-participant replicas are
    /// materialized lazily from this base during a round and dropped at
    /// its end — O(cohort) model-sized state, not O(population).
    replica_base: Vec<f32>,
    /// SFLV1: replicas of this round's touched participants
    server_replicas: std::collections::BTreeMap<usize, (Vec<f32>, OptState)>,
    clients: ClientPool,
    /// manifest optimizer-state flavor (lazy replica construction)
    opt_state: usize,
    rng: Xoshiro256pp,
    /// `--zo_wire seed_agg`: this round's accepted ZO replay records
    /// `(client, seeds, gscales)` in absorb order — the seed-space
    /// aggregation roster. `finish_round` folds them into θ_l without
    /// materializing any per-client θ, and the networked dispatcher
    /// re-broadcasts them verbatim as the next round's `SeedSync`.
    /// Cleared at every round start; never checkpointed (a restored or
    /// rejoining peer gets one dense bootstrap sync instead).
    zo_records: Vec<(usize, Vec<i32>, Vec<f32>)>,
    pub comm_bytes: u64,
    pub flops_client: u64,
    profile: DeviceProfile,
    pub timings: Vec<RoundTiming>,
    nc: usize,
    ns: usize,
    round_idx: usize,
    // reusable aggregation buffer
    agg_buf: Vec<f32>,
    // reusable server-phase arenas: θ_s' ping-pong + cut-gradient buffer
    srv_out: Vec<f32>,
    srv_cut: Vec<f32>,
}

impl<'s> Driver<'s> {
    pub fn new(session: &'s Session, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let v = session.variant(&cfg.variant)?.clone();
        for e in cfg.algorithm.required_entries() {
            if !v.entries.contains_key(*e) {
                bail!(
                    "variant {} lacks entry {e} required by {}",
                    cfg.variant,
                    cfg.algorithm.name()
                );
            }
        }
        let task = if v.task == "lm" { Task::Lm } else { Task::Vision };
        let base = if v.size_base > 0 {
            Some(v.blob("frozen_base")?)
        } else {
            None
        };
        let theta_l = v.blob("init_theta_l")?;
        let theta_s = v.blob("init_theta_s")?;
        let (nc, nl, ns) = (v.size_client, v.size_local(), v.size_server);
        if theta_l.len() != nl || theta_s.len() != ns {
            bail!("init blob sizes disagree with manifest");
        }

        // lazy pool: no client state is model-sized until a client
        // actually participates (the networked orchestrator never
        // materializes any)
        let clients = ClientPool::new(&v, &cfg, task);

        let replica_base = if cfg.algorithm == Algorithm::SflV1 {
            theta_s.clone()
        } else {
            Vec::new()
        };

        let opt_state = v.opt_state;
        Ok(Driver {
            session,
            book: CostBook::new(&v, cfg.algorithm, cfg.n_pert as u64)
                .with_zo_wire(
                    cfg.zo_wire,
                    cfg.local_steps as u64,
                    cfg.participants_per_round() as u64,
                )
                .with_codec(cfg.codec, cfg.grad_codec),
            task,
            base,
            theta_l,
            theta_s,
            opt_server: OptState::new(opt_state, ns),
            replica_base,
            server_replicas: std::collections::BTreeMap::new(),
            clients,
            opt_state,
            rng: Xoshiro256pp::new(cfg.run_seed),
            zo_records: Vec::new(),
            comm_bytes: 0,
            flops_client: 0,
            profile: DeviceProfile::edge_default(),
            timings: Vec::new(),
            nc,
            ns,
            round_idx: 0,
            agg_buf: vec![0.0; nl],
            srv_out: Vec::new(),
            srv_cut: Vec::new(),
            cfg,
        })
    }

    pub fn warmup(&self) -> Result<()> {
        self.session
            .warmup(&self.cfg.variant, self.cfg.algorithm.required_entries())
    }

    pub fn round_index(&self) -> usize {
        self.round_idx
    }

    /// The fresh event-sim accumulator for one round, scoped to the
    /// round's sampled cohort (per-client accounting is O(cohort), with
    /// the population size kept only as the sync-phase divisor).
    pub fn new_sim(&self, participants: &[usize]) -> RoundSim {
        RoundSim::new_cohort(&self.profile, participants, self.cfg.n_clients)
    }

    /// The Main-Server queue for one round: capacity `N·(h/k + 1)` (never
    /// drops under the synchronous protocol) unless the config pins an
    /// explicit bound (`queue_capacity`, used by backpressure/failure
    /// injection — dropped batches surface in `QueueStats` and, on the
    /// networked path, as typed NACKs to the uploading client).
    pub fn round_queue(&self, n_participants: usize) -> ServerQueue {
        let cap = if self.cfg.queue_capacity > 0 {
            self.cfg.queue_capacity
        } else {
            n_participants
                * (self.cfg.local_steps / self.cfg.upload_every + 1)
        };
        ServerQueue::new(cap)
    }

    /// One full communication round. Returns the train-loss mean over all
    /// local steps.
    ///
    /// With `--round_deadline_ms` set, the decoupled path applies the
    /// straggler cutoff in *virtual time*: a participant whose event-sim
    /// lane finishes past the deadline is cut whole — queued uploads
    /// discarded at the barrier, θ excluded from FedAvg, losses and
    /// analytic counters uncharged — and recorded in the round's
    /// [`RoundTiming::cut_clients`]. The locked baselines (SFLV1/V2)
    /// ignore the deadline: their per-step training lock co-executes the
    /// server, so there is no asynchronous wait to cut.
    pub fn run_round(&mut self) -> Result<f64> {
        let _round_span = crate::span!("round", round = self.round_idx);
        self.begin_round_records();
        let participants = self.sample_participants();
        let mut sim = self.new_sim(&participants);
        let queue = self.round_queue(participants.len());
        let mut losses: Vec<f64> = Vec::new();
        let mut updated: Vec<(usize, Vec<f32>)> = Vec::new();
        // FSL-SAGE cut-gradient feedback; the stream drain policy fills
        // it mid-round, the barrier policy entirely at `server_drain`
        let mut feedback: Vec<(usize, Vec<f32>)> = Vec::new();
        // participants the straggler deadline excluded this round
        let mut cut: BTreeSet<usize> = BTreeSet::new();

        if self.cfg.algorithm.is_decoupled() {
            self.local_fanout(
                &participants,
                &queue,
                &mut sim,
                &mut losses,
                &mut updated,
                &mut feedback,
                &mut cut,
            )?;
        } else {
            // SFLV1/V2: the per-step training lock serializes each client
            // against the Main-Server — executed sequentially by design.
            sim.set_workers(1);
            for &ci in &participants {
                let theta_start = self.theta_l.clone();
                let theta_end = self
                    .local_phase_locked(ci, theta_start, &mut sim, &mut losses)?;
                self.comm_bytes +=
                    self.book.comm_per_round_sync_at(self.round_idx as u64);
                sim.sync_split(
                    self.book.downlink_per_round_sync(self.round_idx as u64),
                    self.book.uplink_per_round_sync(),
                );
                updated.push((ci, theta_end));
            }
        }

        feedback.extend(self.server_drain_cut(&queue, &cut, &mut sim)?);
        if !cut.is_empty() {
            // a mid-round (stream) probe may have produced alignment
            // feedback for a client the deadline then cut — a cut client
            // receives nothing
            feedback.retain(|(c, _)| !cut.contains(c));
        }
        self.apply_alignment_local(feedback, &mut updated, &mut sim)?;
        Ok(self.finish_round(&participants, updated, sim, &losses))
    }

    pub fn sample_participants(&mut self) -> Vec<usize> {
        let k = self.cfg.participants_per_round();
        let mut idx = self.rng.sample_indices(self.cfg.n_clients, k);
        idx.sort_unstable();
        idx
    }

    // ---- parallel local phase (decoupled algorithms) ---------------------

    /// Fan the participants' local phases out across the worker pool and
    /// merge outcomes at the barrier in participant order.
    ///
    /// Under the `barrier` drain policy the queue just fills up here;
    /// under `stream` this thread doubles as the Main-Server consumer:
    /// the pool produces from a spawned thread while the driver pops the
    /// queue in arrival order and runs the Eq. (7) FO step on each batch
    /// mid-round — the client phase and the server phase overlap, which
    /// is the whole point of `--drain stream`. Client-side results stay
    /// bit-identical either way (the local phases never read θ_s); only
    /// the order θ_s absorbs batches — and therefore θ_s itself and any
    /// cut-gradient feedback — follows arrival order instead of the
    /// deterministic sorted order.
    fn local_fanout(
        &mut self,
        participants: &[usize],
        queue: &ServerQueue,
        sim: &mut RoundSim,
        losses: &mut Vec<f64>,
        updated: &mut Vec<(usize, Vec<f32>)>,
        feedback: &mut Vec<(usize, Vec<f32>)>,
        cut: &mut BTreeSet<usize>,
    ) -> Result<()> {
        let eff = pool::effective_workers(self.cfg.workers, participants.len());
        sim.set_workers(eff);
        let theta0 = self.theta_l.clone();
        let stream = self.cfg.drain.policy().streams();
        let srv_step_flops = 3 * self.variant_server_flops();
        if stream && !matches!(self.opt_server, OptState::None) {
            bail!(
                "stream drain: stateful optimizers are not wired through \
                 the typed runtime (manifest opt_state must be 0)"
            );
        }
        // Disjoint field borrows: the client jobs take &mut clients, the
        // streaming consumer takes the server-phase arenas, and the
        // shared context borrows the rest immutably.
        let this = &mut *self;
        let ctx = LocalCtx {
            session: this.session,
            cfg: &this.cfg,
            book: &this.book,
            base: this.base.as_deref(),
            task: this.task,
            round_idx: this.round_idx,
            profile: this.profile,
            nc: this.nc,
        };
        let jobs: Vec<(usize, &mut ClientState)> =
            this.clients.states_for(participants);
        let results: Vec<Result<LocalOutcome>> = if !stream {
            pool::run_jobs(eff, jobs, |(ci, state)| {
                local::client_local_phase(&ctx, ci, state, theta0.clone(), queue)
            })
        } else {
            let rt = this.session.client_runtime(&this.cfg.variant)?;
            let base = this.base.as_deref();
            let cfg = &this.cfg;
            let theta_s = &mut this.theta_s;
            let srv_out = &mut this.srv_out;
            let srv_cut = &mut this.srv_cut;
            let producers_done = AtomicBool::new(false);
            std::thread::scope(
                |scope| -> Result<Vec<Result<LocalOutcome>>> {
                    let done = &producers_done;
                    let producer = scope.spawn(move || {
                        let r = pool::run_jobs(eff, jobs, |(ci, state)| {
                            local::client_local_phase(
                                &ctx,
                                ci,
                                state,
                                theta0.clone(),
                                queue,
                            )
                        });
                        done.store(true, Ordering::Release);
                        r
                    });
                    // mid-round consumption through the same DrainPolicy
                    // hook the networked dispatcher uses, until the
                    // fan-out is done AND the queue is dry
                    let policy = cfg.drain.policy();
                    loop {
                        let batches = policy.take_ready(queue);
                        if batches.is_empty() {
                            if producers_done.load(Ordering::Acquire)
                                && queue.is_empty()
                            {
                                break;
                            }
                            // park briefly instead of spinning: the gaps
                            // between uploads span whole local steps, and
                            // burning a core here would steal throughput
                            // from the very fan-out this mode overlaps
                            // with. 50 µs of added wake-up latency is
                            // noise next to a model step.
                            std::thread::sleep(
                                std::time::Duration::from_micros(50),
                            );
                            continue;
                        }
                        for b in batches {
                            let want = wants_cutgrad(cfg, b.step);
                            let g = consume_smashed(
                                rt,
                                base,
                                theta_s,
                                srv_out,
                                srv_cut,
                                cfg.lr_server,
                                &b,
                                want,
                            )?;
                            sim.server_compute(srv_step_flops);
                            if let Some(g_sm) = g {
                                feedback.push((b.client, g_sm));
                            }
                        }
                    }
                    Ok(producer.join().expect("client fan-out panicked"))
                },
            )?
        };
        // straggler cutoff (virtual time): a lane that finishes past the
        // deadline is excluded whole. The comparison is strict (`>`), so
        // a deadline placed exactly at the slowest lane's finish time
        // cuts nobody — and stays bitwise identical to no deadline at
        // all (pinned in `rust/tests/drain_stream.rs`).
        let deadline = self.cfg.virtual_deadline();
        for res in results {
            let out = res?;
            if let Some(d) = deadline {
                if out.lane.time > d {
                    sim.record_cutoff(out.ci);
                    cut.insert(out.ci);
                    continue;
                }
            }
            self.absorb_outcome(out, sim, losses, updated);
        }
        Ok(())
    }

    /// Merge one client's local-phase outcome into the round's driver-side
    /// accounting, in the order outcomes are presented (participant order
    /// at the barrier — both execution modes preserve it).
    pub(crate) fn absorb_outcome(
        &mut self,
        out: LocalOutcome,
        sim: &mut RoundSim,
        losses: &mut Vec<f64>,
        updated: &mut Vec<(usize, Vec<f32>)>,
    ) {
        let LocalOutcome {
            ci,
            theta,
            losses: step_losses,
            // under theta/seeds wire modes the client's θ is what the
            // aggregator consumes, so the seeds + gscales replay record
            // is dropped here (the networked `--zo_wire seeds` path
            // exercises it server-side; pinned equal in net_loopback
            // tests). Under seed_agg the record IS the aggregation
            // input — `finish_round` replays it and the dispatcher
            // re-broadcasts it — so it is retained instead.
            seeds,
            gscales,
            comm_bytes,
            flops,
            lane,
        } = out;
        losses.extend(step_losses);
        self.comm_bytes += comm_bytes
            + self.book.comm_per_round_sync_at(self.round_idx as u64);
        self.flops_client += flops;
        sim.merge_lane(ci, &lane);
        sim.sync_split(
            self.book.downlink_per_round_sync(self.round_idx as u64),
            self.book.uplink_per_round_sync(),
        );
        if self.cfg.zo_wire == ZoWireMode::SeedAgg {
            self.zo_records.push((ci, seeds, gscales));
        }
        updated.push((ci, theta));
    }

    /// Reset the per-round seed-space aggregation roster. Both round
    /// composers (in-process [`Self::run_round`] and the networked
    /// dispatcher) call this before absorbing any outcome; the dispatcher
    /// does so only *after* broadcasting the previous round's
    /// [`Self::seed_sync_record`], which reads the same buffer.
    pub(crate) fn begin_round_records(&mut self) {
        self.zo_records.clear();
    }

    /// The previous round's seed-space aggregation roster, flattened for
    /// the wire (`Msg::SeedSync`): per participant i in server absorb
    /// order, `clients[i]`, its FedAvg weight as the exact f64 the
    /// aggregation used, `seeds[i*h ..]` and `gscales[i*h*np ..]`.
    /// `None` when there is nothing to replay — fresh start, restore, or
    /// a round whose cohort was cut whole — in which case the dispatcher
    /// falls back to a dense `ModelSync` bootstrap.
    pub(crate) fn seed_sync_record(
        &self,
    ) -> Option<(Vec<u32>, Vec<f64>, Vec<i32>, Vec<f32>)> {
        if self.zo_records.is_empty() {
            return None;
        }
        let mut clients = Vec::with_capacity(self.zo_records.len());
        let mut weights = Vec::with_capacity(self.zo_records.len());
        let mut seeds = Vec::new();
        let mut gscales = Vec::new();
        for (ci, s, g) in &self.zo_records {
            clients.push(*ci as u32);
            weights.push(self.clients.shard_weight(*ci).max(1e-9));
            seeds.extend_from_slice(s);
            gscales.extend_from_slice(g);
        }
        Some((clients, weights, seeds, gscales))
    }

    // ---- locked local phase (SFLV1/V2) -----------------------------------

    /// Traditional SFL (V1/V2): every batch runs the locked exchange. The
    /// client half (cut forward, backprop) lives in `coordinator::local`;
    /// the server half is [`Self::locked_server_exchange`] — the same
    /// split the networked path runs over the wire.
    fn local_phase_locked(
        &mut self,
        ci: usize,
        mut theta: Vec<f32>,
        sim: &mut RoundSim,
        losses: &mut Vec<f64>,
    ) -> Result<Vec<f32>> {
        let mut opt_c = std::mem::replace(
            &mut self.clients.state(ci).opt_client,
            OptState::None,
        );
        for _step in 1..=self.cfg.local_steps {
            let cs = self.clients.state(ci);
            cs.loader.next_batch();
            let (x, y) = local::loader_batch_xy(self.task, &cs.loader);
            // client forward to the cut layer
            let mut smashed = local::locked_client_fwd(
                self.session,
                &self.cfg.variant,
                self.base.as_deref(),
                &theta[..self.nc],
                &x,
            )?;
            // encode-once: the server must see the post-roundtrip
            // activations a wire run would decode (net::codec)
            if self.cfg.codec != crate::net::codec::Codec::F32 {
                crate::net::codec::transcode(self.cfg.codec, &mut smashed);
            }
            let (loss, mut g_sm) =
                self.locked_server_exchange(ci, smashed, y, sim)?;
            losses.push(loss);
            // mirror the downlink: the client backprops from the cut
            // gradient as the grad codec reconstructs it
            if self.cfg.grad_codec != crate::net::codec::GradCodec::F32 {
                crate::net::codec::transcode_grad(
                    self.cfg.grad_codec,
                    &mut g_sm,
                );
            }
            // client backprop from the relayed cut gradient
            let new_c = local::locked_client_bp(
                self.session,
                &self.cfg.variant,
                self.base.as_deref(),
                &theta[..self.nc],
                &mut opt_c,
                x,
                g_sm,
                self.cfg.lr_client,
            )?;
            theta[..self.nc].copy_from_slice(&new_c);
        }
        self.clients.state(ci).opt_client = opt_c;
        Ok(theta)
    }

    /// The Main-Server half of one locked exchange step: charges the
    /// client's forward, the two-way smashed/cut-gradient transfer, the
    /// training-lock wait, and the client's backward to the driver
    /// counters, and runs the server FO step on this client's replica
    /// (V1) or the shared model (V2). Returns `(loss, g_smashed)`.
    pub(crate) fn locked_server_exchange(
        &mut self,
        ci: usize,
        smashed: Vec<f32>,
        y: Vec<i32>,
        sim: &mut RoundSim,
    ) -> Result<(f64, Vec<f32>)> {
        let fwd = self.book.flops_per_step / 3; // 1 of 3F_c is the fwd
        self.flops_client += fwd;
        sim.client_compute(ci, fwd);
        self.comm_bytes += self.book.smashed_bytes;
        sim.client_upload(ci, self.book.smashed_bytes);

        // server step on this client's replica (V1) or the shared model
        // (V2); returns the cut gradient
        let rt = self.session.client_runtime(&self.cfg.variant)?;
        let (theta_s, opt_s) = match self.cfg.algorithm {
            Algorithm::SflV1 => {
                // lazy replica: between rounds every replica equals the
                // averaged base, so cloning it on first touch is
                // bit-identical to keeping N live replicas
                let base = &self.replica_base;
                let (os, ns) = (self.opt_state, self.ns);
                let e = self.server_replicas.entry(ci).or_insert_with(|| {
                    (base.clone(), OptState::new(os, ns))
                });
                (&mut e.0, &mut e.1)
            }
            _ => (&mut self.theta_s, &mut self.opt_server),
        };
        if !matches!(opt_s, OptState::None) {
            bail!(
                "locked server exchange: stateful optimizers are not wired \
                 through the typed runtime (manifest opt_state must be 0)"
            );
        }
        let mut new_s = Vec::new();
        let mut g_sm = Vec::new();
        let loss = rt.server_step(
            self.base.as_deref(),
            theta_s,
            &smashed,
            &y,
            self.cfg.lr_server,
            Some(&mut g_sm),
            &mut new_s,
        )? as f64;
        *theta_s = new_s;
        // training lock: the client waits for the server's fwd+bwd
        sim.client_blocked_on_server(ci, 3 * self.variant_server_flops());
        self.comm_bytes += self.book.cutgrad_bytes;
        sim.client_download(ci, self.book.cutgrad_bytes);
        let bwd = 2 * (self.book.flops_per_step / 3);
        self.flops_client += bwd;
        sim.client_compute(ci, bwd);
        Ok((loss, g_sm))
    }

    // ---- server phase ------------------------------------------------------

    /// Barrier-time consumption through the configured
    /// [`crate::coordinator::drain::DrainPolicy`]:
    /// `barrier` drains everything in deterministic `(round, client,
    /// step)` Eq. (7) order; `stream` consumes only the stragglers the
    /// mid-round probes missed (usually none), in arrival order. Also
    /// records the queue's occupancy stats into the sim. Returns
    /// FSL-SAGE cut-gradient feedback `(client, g_smashed)` in
    /// consumption order; empty for every other algorithm (and for the
    /// locked baselines, whose queue is empty by construction).
    pub(crate) fn server_drain(
        &mut self,
        queue: &ServerQueue,
        sim: &mut RoundSim,
    ) -> Result<Vec<(usize, Vec<f32>)>> {
        self.server_drain_cut(queue, &BTreeSet::new(), sim)
    }

    /// [`Self::server_drain`] under a straggler cutoff: batches queued by
    /// cut-off clients are discarded at the barrier
    /// ([`crate::coordinator::drain::DrainPolicy::take_at_barrier_cut`]).
    /// An empty cut set is exactly the plain barrier drain.
    pub(crate) fn server_drain_cut(
        &mut self,
        queue: &ServerQueue,
        cut: &BTreeSet<usize>,
        sim: &mut RoundSim,
    ) -> Result<Vec<(usize, Vec<f32>)>> {
        let mut sage_feedback: Vec<(usize, Vec<f32>)> = Vec::new();
        if self.cfg.algorithm.is_decoupled() {
            let _s = crate::span!("server_drain", round = self.round_idx);
            let batches =
                self.cfg.drain.policy().take_at_barrier_cut(queue, cut);
            self.consume_batches(batches, sim, &mut sage_feedback)?;
        }
        sim.record_queue(queue.stats());
        Ok(sage_feedback)
    }

    /// Mid-round consumption tick (the networked dispatcher calls this
    /// between wire events): hand whatever the drain policy releases —
    /// everything currently queued under `stream`, nothing under
    /// `barrier` — to the Eq. (7) server step. Returns the number of
    /// batches consumed.
    pub(crate) fn server_pump(
        &mut self,
        queue: &ServerQueue,
        sim: &mut RoundSim,
        feedback: &mut Vec<(usize, Vec<f32>)>,
    ) -> Result<usize> {
        let batches = self.cfg.drain.policy().take_ready(queue);
        let n = batches.len();
        self.consume_batches(batches, sim, feedback)?;
        Ok(n)
    }

    fn consume_batches(
        &mut self,
        batches: Vec<SmashedBatch>,
        sim: &mut RoundSim,
        feedback: &mut Vec<(usize, Vec<f32>)>,
    ) -> Result<()> {
        for b in batches {
            let want_cutgrad = wants_cutgrad(&self.cfg, b.step);
            if let Some(g_sm) = self.server_consume(&b, want_cutgrad, sim)? {
                feedback.push((b.client, g_sm));
            }
        }
        Ok(())
    }

    /// Charge the per-alignment communication for one FSL-SAGE feedback
    /// message (shared by the in-process and networked paths).
    pub(crate) fn note_alignment_accounting(
        &mut self,
        ci: usize,
        sim: &mut RoundSim,
    ) {
        self.comm_bytes += self.book.comm_per_alignment();
        sim.client_download(ci, self.book.comm_per_alignment());
    }

    /// FSL-SAGE, in-process: clients align their aux model against the
    /// returned cut gradients (one alignment per feedback message). The
    /// networked dispatcher performs the same loop by relaying each
    /// gradient to the owning client process instead.
    pub(crate) fn apply_alignment_local(
        &mut self,
        feedback: Vec<(usize, Vec<f32>)>,
        updated: &mut [(usize, Vec<f32>)],
        sim: &mut RoundSim,
    ) -> Result<()> {
        for (ci, g_sm) in feedback {
            self.note_alignment_accounting(ci, sim);
            if let Some(pos) = updated.iter().position(|(c, _)| *c == ci) {
                let (sm, y, _x) = self
                    .clients
                    .state(ci)
                    .last_upload
                    .clone()
                    .context("sage alignment without upload")?;
                let theta = updated[pos].1.clone();
                updated[pos].1 = local::aux_align_apply(
                    self.session,
                    &self.cfg.variant,
                    self.base.as_deref(),
                    theta,
                    sm,
                    y,
                    g_sm,
                    self.cfg.lr_client,
                )?;
            }
        }
        Ok(())
    }

    /// Consume one queued smashed batch (Eq. 7) through the typed
    /// runtime: borrowed inputs, θ_s' into the driver's reused arena and
    /// swapped (not copied) back, the cut gradient moved out of its
    /// reused buffer only on cut-grad steps.
    fn server_consume(
        &mut self,
        b: &SmashedBatch,
        want_cutgrad: bool,
        sim: &mut RoundSim,
    ) -> Result<Option<Vec<f32>>> {
        if !matches!(self.opt_server, OptState::None) {
            bail!(
                "server drain: stateful optimizers are not wired through \
                 the typed runtime (manifest opt_state must be 0)"
            );
        }
        let rt = self.session.client_runtime(&self.cfg.variant)?;
        let g = consume_smashed(
            rt,
            self.base.as_deref(),
            &mut self.theta_s,
            &mut self.srv_out,
            &mut self.srv_cut,
            self.cfg.lr_server,
            b,
            want_cutgrad,
        )?;
        sim.server_compute(3 * self.variant_server_flops());
        Ok(g)
    }

    /// Aggregation (Fed-Server, Eq. 8) + SFLV1 replica averaging + round
    /// bookkeeping. Consumes the sim; returns the round's train-loss mean.
    pub(crate) fn finish_round(
        &mut self,
        participants: &[usize],
        updated: Vec<(usize, Vec<f32>)>,
        sim: RoundSim,
        losses: &[f64],
    ) -> f64 {
        if self.cfg.zo_wire == ZoWireMode::SeedAgg
            && !self.zo_records.is_empty()
        {
            // Seed-space aggregation (HERON only): replay each record
            // from the round-start θ_l and accumulate the FedAvg sum
            // one trajectory at a time — same per-element op order as
            // `fedavg_into` over materialized θs, so bit-identical to
            // the dense path (pinned in `zo::tests`) without ever
            // holding a per-client parameter vector. The networked
            // dispatcher feeds empty θs through `absorb_outcome` in
            // this mode, so `updated` must not be consumed here.
            let records: Vec<(&[i32], &[f32])> = self
                .zo_records
                .iter()
                .map(|(_, s, g)| (s.as_slice(), g.as_slice()))
                .collect();
            let weights: Vec<f64> = self
                .zo_records
                .iter()
                .map(|(c, _, _)| self.clients.shard_weight(*c).max(1e-9))
                .collect();
            let agg = crate::zo::aggregate_trajectories(
                &self.theta_l,
                &records,
                &weights,
                self.cfg.n_pert,
            )
            .expect("validated seed_agg records cannot fail aggregation");
            self.theta_l.copy_from_slice(&agg);
        } else if !updated.is_empty() {
            let refs: Vec<&[f32]> =
                updated.iter().map(|(_, t)| t.as_slice()).collect();
            let weights: Vec<f64> = updated
                .iter()
                .map(|(c, _)| self.clients.shard_weight(*c).max(1e-9))
                .collect();
            fedavg_into(&refs, &weights, &mut self.agg_buf);
            if self.cfg.algorithm.is_decoupled() {
                self.theta_l.copy_from_slice(&self.agg_buf);
            } else {
                // SFLV1/V2: only θ_c is client-trained; aux stays at init
                self.theta_l[..self.nc]
                    .copy_from_slice(&self.agg_buf[..self.nc]);
            }
        }

        // SFLV1: aggregate the participants' server replicas, then fold
        // the mean into the single between-round base. Copying the mean
        // into every replica (the eager formulation) makes all replicas
        // equal — so dropping the cohort's replicas and keeping one base
        // is the same state in O(1) model-sized copies instead of O(N).
        if self.cfg.algorithm == Algorithm::SflV1 {
            let refs: Vec<&[f32]> = participants
                .iter()
                .map(|&c| {
                    self.server_replicas
                        .get(&c)
                        .map(|(t, _)| t.as_slice())
                        // a participant that never touched its replica
                        // (impossible with local_steps >= 1, but harmless)
                        // still holds the between-round base
                        .unwrap_or(self.replica_base.as_slice())
                })
                .collect();
            let w = vec![1.0; refs.len()];
            let mut mean = vec![0.0f32; self.ns];
            fedavg_into(&refs, &w, &mut mean);
            self.theta_s.copy_from_slice(&mean);
            self.replica_base.copy_from_slice(&mean);
            self.server_replicas.clear();
        }

        self.timings.push(sim.finish());
        self.round_idx += 1;
        losses.iter().sum::<f64>() / losses.len().max(1) as f64
    }

    // ---- checkpoint/restore ------------------------------------------------

    /// Snapshot everything needed to continue this run from the next
    /// round boundary (see [`crate::coordinator::checkpoint`]). Taken
    /// *between* rounds, where the SFLV1 per-participant replicas are
    /// provably folded into `replica_base` (cleared by
    /// [`Self::finish_round`]), so the cohort replicas never need to be
    /// captured.
    pub fn export_state(&self) -> crate::coordinator::checkpoint::DriverState {
        crate::coordinator::checkpoint::DriverState {
            round_idx: self.round_idx as u64,
            rng: self.rng.state(),
            theta_l: self.theta_l.clone(),
            theta_s: self.theta_s.clone(),
            replica_base: self.replica_base.clone(),
            opt_server: self.opt_server.clone(),
            comm_bytes: self.comm_bytes,
            flops_client: self.flops_client,
            timings: self.timings.clone(),
        }
    }

    /// Adopt a [`Self::export_state`] snapshot: the driver continues at
    /// `state.round_idx` with the exact RNG stream, parameters,
    /// optimizer state, and accumulated accounting the saved run had —
    /// bit-identical continuation for the stateless-optimizer variants
    /// (client-side Adam state is outside the checkpoint's scope).
    /// Rejects a snapshot whose parameter shapes disagree with this
    /// driver's manifest — restoring across configs is a config error,
    /// not a truncation waiting to happen.
    pub fn import_state(
        &mut self,
        state: crate::coordinator::checkpoint::DriverState,
    ) -> Result<()> {
        if state.theta_l.len() != self.theta_l.len() {
            bail!(
                "checkpoint theta_l has {} params, manifest wants {}",
                state.theta_l.len(),
                self.theta_l.len()
            );
        }
        if state.theta_s.len() != self.theta_s.len() {
            bail!(
                "checkpoint theta_s has {} params, manifest wants {}",
                state.theta_s.len(),
                self.theta_s.len()
            );
        }
        if state.replica_base.len() != self.replica_base.len() {
            bail!(
                "checkpoint replica base has {} params, manifest wants {}",
                state.replica_base.len(),
                self.replica_base.len()
            );
        }
        self.round_idx = state.round_idx as usize;
        self.rng = Xoshiro256pp::from_state(state.rng);
        self.theta_l = state.theta_l;
        self.theta_s = state.theta_s;
        self.replica_base = state.replica_base;
        self.opt_server = state.opt_server;
        self.comm_bytes = state.comm_bytes;
        self.flops_client = state.flops_client;
        self.timings = state.timings;
        self.server_replicas.clear();
        // never checkpointed: a restored run re-bootstraps its clients
        // with one dense sync instead of replaying a stale roster
        self.zo_records.clear();
        Ok(())
    }

    fn variant_server_flops(&self) -> u64 {
        let v = self
            .session
            .variant(&self.cfg.variant)
            .expect("variant exists");
        v.cost.flops_fwd_server as u64 * v.batch as u64
    }

    // ---- evaluation ---------------------------------------------------------

    /// Evaluate the assembled global model on a held-out batch.
    /// Returns (metric, raw_stats): vision accuracy in [0,1], or LM
    /// perplexity.
    pub fn evaluate(&self) -> Result<f64> {
        let v = self.session.variant(&self.cfg.variant)?;
        let eb = v.eval_batch;
        let (x, y): (TensorValue, Vec<i32>) = match self.task {
            Task::Vision => {
                let (xs, ys) = crate::data::loader::eval_batch_vision(
                    self.cfg.data_seed,
                    self.cfg.eval_holdout,
                    eb,
                );
                (TensorValue::F32(xs), ys)
            }
            Task::Lm => {
                let xs = crate::data::loader::eval_batch_text(
                    self.cfg.data_seed,
                    self.cfg.eval_holdout,
                    eb,
                );
                (TensorValue::I32(xs.clone()), xs)
            }
        };
        let rt = self.session.client_runtime(&self.cfg.variant)?;
        let (s1, s2) = rt.eval_full(
            self.base.as_deref(),
            &self.theta_l[..self.nc],
            &self.theta_s,
            x.view(),
            &y,
        )?;
        let (s1, s2) = (s1 as f64, s2 as f64);
        Ok(match self.task {
            Task::Vision => s1 / s2.max(1.0), // accuracy
            Task::Lm => (s1 / s2.max(1.0)).exp(), // perplexity
        })
    }

    /// Record one finished round into `rec` (eval cadence, curve point,
    /// progress log) — shared verbatim by the in-process and networked
    /// run loops so their records can only differ in wall-clock.
    pub fn record_round(
        &self,
        rec: &mut RunRecord,
        round: usize,
        loss: f64,
        t0: std::time::Instant,
    ) -> Result<()> {
        let eval_due = self.cfg.eval_every > 0
            && (round % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds);
        let metric = if eval_due { self.evaluate()? } else { f64::NAN };
        rec.push(RoundRecord {
            round,
            train_loss: loss,
            eval_metric: metric,
            comm_bytes_cum: self.comm_bytes,
            wall_seconds: t0.elapsed().as_secs_f64(),
        });
        if eval_due {
            // per-round queue high watermark (occupancy gauge): what
            // `queue_capacity` must cover. Barrier mode peaks at the
            // full round's upload count; stream mode stays lower
            // because consumption overlaps the fan-out.
            let q_hwm = self
                .timings
                .last()
                .map(|t| t.queue.max_depth)
                .unwrap_or(0);
            log::info!(
                "[{}] round {round}: loss {loss:.4} metric {metric:.4} comm {} q\u{2191}{q_hwm}",
                rec.name,
                crate::coordinator::accounting::fmt_bytes(self.comm_bytes)
            );
        }
        Ok(())
    }

    /// Write the end-of-run summary counters (analytic cost book, event
    /// sim, queue, measured wire traffic) into the record.
    pub fn finalize_record(&self, rec: &mut RunRecord) {
        rec.set("comm_bytes", self.comm_bytes as f64);
        rec.set("client_flops", self.flops_client as f64);
        // the O(cohort) memory claim, observable: model-sized client
        // states this driver ever materialized (0 for a networked
        // orchestrator; #distinct participants for an in-process run)
        rec.set("client_states_built", self.clients.built() as f64);
        rec.set("peak_mem_bytes", self.book.peak_mem_bytes as f64);
        rec.set(
            "virtual_seconds",
            self.timings.iter().map(|t| t.total()).sum(),
        );
        rec.set(
            "client_idle_seconds",
            self.timings.iter().map(|t| t.client_idle).sum(),
        );
        rec.set(
            "host_makespan_seconds",
            self.timings.iter().map(|t| t.host_makespan).sum(),
        );
        rec.set(
            "queue_enqueued",
            self.timings.iter().map(|t| t.queue.enqueued as f64).sum(),
        );
        rec.set(
            "queue_dropped",
            self.timings.iter().map(|t| t.queue.dropped as f64).sum(),
        );
        rec.set(
            "queue_max_depth",
            self.timings
                .iter()
                .map(|t| t.queue.max_depth as f64)
                .fold(0.0, f64::max),
        );
        // per-round occupancy high watermark, averaged over the run —
        // the gauge to size `queue_capacity` with (especially in stream
        // mode, where mid-round consumption keeps the depth low)
        rec.set(
            "queue_hwm_mean",
            self.timings
                .iter()
                .map(|t| t.queue.max_depth as f64)
                .sum::<f64>()
                / self.timings.len().max(1) as f64,
        );
        // the drain-policy comparison: virtual server completion under
        // the barrier schedule vs arrival-order mid-round consumption
        rec.set(
            "server_makespan_barrier_seconds",
            self.timings.iter().map(|t| t.server_makespan_barrier).sum(),
        );
        rec.set(
            "server_makespan_stream_seconds",
            self.timings.iter().map(|t| t.server_makespan_stream).sum(),
        );
        rec.set(
            "queue_wait_barrier_seconds",
            self.timings.iter().map(|t| t.queue_wait_barrier).sum(),
        );
        rec.set(
            "queue_wait_stream_seconds",
            self.timings.iter().map(|t| t.queue_wait_stream).sum(),
        );
        rec.set(
            "wire_bytes_sent",
            self.timings.iter().map(|t| t.wire.bytes_sent as f64).sum(),
        );
        rec.set(
            "wire_bytes_recv",
            self.timings.iter().map(|t| t.wire.bytes_recv as f64).sum(),
        );
        rec.set(
            "wire_frames",
            self.timings
                .iter()
                .map(|t| (t.wire.frames_sent + t.wire.frames_recv) as f64)
                .sum(),
        );
        // Telemetry dump: mirror the stats structs into the registry and
        // fold the whole registry into the summary. Gated so a run with
        // no telemetry flags emits byte-identical records to builds that
        // predate the flight recorder.
        if crate::telemetry::metrics_enabled() {
            self.session.stats().publish_registry();
            crate::coordinator::eventsim::publish_timings_registry(
                &self.timings,
            );
            crate::telemetry::registry::export_into(&mut rec.summary);
        }
    }

    /// Run the configured number of rounds, recording curves.
    pub fn run(&mut self, record_name: &str) -> Result<RunRecord> {
        self.warmup()?;
        let mut rec = RunRecord::new(record_name);
        let t0 = std::time::Instant::now();
        for round in 0..self.cfg.rounds {
            let loss = self.run_round()?;
            self.record_round(&mut rec, round, loss, t0)?;
        }
        self.finalize_record(&mut rec);
        Ok(rec)
    }
}

/// Does this upload step owe FSL-SAGE a cut gradient? (Alignment fires
/// every `align_every`-th upload.)
fn wants_cutgrad(cfg: &RunConfig, step: usize) -> bool {
    cfg.algorithm == Algorithm::FslSage
        && step % (cfg.upload_every * cfg.align_every) == 0
}

/// One Eq. (7) server FO step on a queued batch. Free-standing (no
/// `&mut Driver`) so the streaming fan-out can run it on the driver
/// thread while `LocalCtx` and the client jobs hold borrows of the
/// driver's other fields. θ_s' lands in the reused `srv_out` arena and
/// is swapped — never copied — back; the cut gradient is moved out of
/// its reused buffer only when requested.
#[allow(clippy::too_many_arguments)]
fn consume_smashed(
    rt: &dyn ClientRuntime,
    base: Option<&[f32]>,
    theta_s: &mut Vec<f32>,
    srv_out: &mut Vec<f32>,
    srv_cut: &mut Vec<f32>,
    lr_server: f32,
    b: &SmashedBatch,
    want_cutgrad: bool,
) -> Result<Option<Vec<f32>>> {
    let _s = crate::span!("server_consume", client = b.client, step = b.step);
    let cut = if want_cutgrad {
        Some(&mut *srv_cut)
    } else {
        None
    };
    rt.server_step(
        base,
        theta_s.as_slice(),
        &b.smashed,
        &b.targets,
        lr_server,
        cut,
        srv_out,
    )?;
    std::mem::swap(theta_s, srv_out);
    Ok(if want_cutgrad {
        // the caller owns the gradient; the buffer re-grows next time
        Some(std::mem::take(srv_cut))
    } else {
        None
    })
}
