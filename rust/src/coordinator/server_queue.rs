//! Main-Server smashed-data queue (substrate S11).
//!
//! Clients enqueue (smashed, targets) batches during their local phase; the
//! Main-Server drains the queue sequentially (SFLV2-style, paper Eq. (7))
//! with first-order updates. The queue tracks occupancy statistics and
//! enforces a capacity bound so backpressure behaviour is observable in the
//! event simulator.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct SmashedBatch {
    pub client: usize,
    pub round: usize,
    pub step: usize,
    pub smashed: Vec<f32>,
    /// vision: labels; lm: full token batch (targets derived in-graph)
    pub targets: Vec<i32>,
}

#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    pub enqueued: u64,
    pub processed: u64,
    pub dropped: u64,
    pub max_depth: usize,
}

pub struct ServerQueue {
    queue: VecDeque<SmashedBatch>,
    capacity: usize,
    stats: QueueStats,
}

impl ServerQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity: capacity.max(1),
            stats: QueueStats::default(),
        }
    }

    /// Enqueue; returns false (and counts a drop) when at capacity.
    /// The synchronous protocol never drops — capacity is sized to
    /// N·(h/k) — but failure-injection tests exercise this path.
    pub fn push(&mut self, batch: SmashedBatch) -> bool {
        if self.queue.len() >= self.capacity {
            self.stats.dropped += 1;
            return false;
        }
        self.queue.push_back(batch);
        self.stats.enqueued += 1;
        self.stats.max_depth = self.stats.max_depth.max(self.queue.len());
        true
    }

    pub fn pop(&mut self) -> Option<SmashedBatch> {
        let b = self.queue.pop_front();
        if b.is_some() {
            self.stats.processed += 1;
        }
        b
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn stats(&self) -> &QueueStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(client: usize) -> SmashedBatch {
        SmashedBatch {
            client,
            round: 0,
            step: 0,
            smashed: vec![0.0; 4],
            targets: vec![1],
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = ServerQueue::new(10);
        for c in 0..5 {
            assert!(q.push(batch(c)));
        }
        for c in 0..5 {
            assert_eq!(q.pop().unwrap().client, c);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn capacity_enforced_and_drops_counted() {
        let mut q = ServerQueue::new(2);
        assert!(q.push(batch(0)));
        assert!(q.push(batch(1)));
        assert!(!q.push(batch(2)));
        assert_eq!(q.stats().dropped, 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn stats_track_depth() {
        let mut q = ServerQueue::new(8);
        for c in 0..6 {
            q.push(batch(c));
        }
        q.pop();
        q.push(batch(9));
        assert_eq!(q.stats().max_depth, 6);
        assert_eq!(q.stats().enqueued, 7);
        assert_eq!(q.stats().processed, 1);
    }
}
