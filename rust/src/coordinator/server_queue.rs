//! Main-Server smashed-data queue (substrate S11) — bounded MPSC.
//!
//! Clients enqueue (smashed, targets) batches **concurrently** during the
//! parallel local phase; the Main-Server drains at the round barrier with
//! first-order updates (SFLV2-style, paper Eq. (7)). The paper's Eq. (7)
//! semantics are deterministic regardless of thread scheduling because the
//! drain happens via [`ServerQueue::drain_sorted`], which orders batches by
//! `(round, client, step)` — exactly the order the old single-threaded
//! driver produced them in.
//!
//! The queue tracks occupancy statistics and enforces a capacity bound so
//! backpressure behaviour is observable in the event simulator. The
//! synchronous protocol never drops — capacity is sized to N·(h/k) — but
//! failure-injection tests exercise the drop path.

use std::collections::VecDeque;
use std::sync::Mutex;

#[derive(Debug, Clone)]
pub struct SmashedBatch {
    pub client: usize,
    pub round: usize,
    pub step: usize,
    pub smashed: Vec<f32>,
    /// vision: labels; lm: full token batch (targets derived in-graph)
    pub targets: Vec<i32>,
}

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub enqueued: u64,
    pub processed: u64,
    pub dropped: u64,
    pub max_depth: usize,
}

impl QueueStats {
    /// Mirror the counters into the telemetry registry (`queue.*`
    /// gauges). Absolute sets, so re-publishing is idempotent.
    pub fn publish_registry(&self) {
        use crate::telemetry::registry::gauge;
        gauge("queue.enqueued").set(self.enqueued as f64);
        gauge("queue.processed").set(self.processed as f64);
        gauge("queue.dropped").set(self.dropped as f64);
        gauge("queue.max_depth").set(self.max_depth as f64);
    }
}

struct Inner {
    queue: VecDeque<SmashedBatch>,
    /// Enqueue timestamps (µs since the telemetry epoch), parallel to
    /// `queue`. Only populated while telemetry metrics are enabled;
    /// consumers pop defensively so a mid-run enable cannot misalign
    /// waits by more than the already-queued prefix.
    enq_us: VecDeque<u64>,
    stats: QueueStats,
}

impl Inner {
    /// Observe queue-wait for `n` just-removed batches against the
    /// `queue.wait_us` histogram (+ a trace instant per batch).
    fn observe_waits(&mut self, n: usize) {
        if n == 0 || self.enq_us.is_empty() {
            return;
        }
        let now = crate::telemetry::now_us();
        let hist = crate::telemetry::registry::histogram("queue.wait_us");
        for _ in 0..n.min(self.enq_us.len()) {
            let t = self.enq_us.pop_front().unwrap();
            let wait = now.saturating_sub(t);
            hist.observe(wait);
            crate::telemetry::instant("queue_wait", "us", wait);
        }
    }
}

/// Bounded multi-producer queue. All methods take `&self`, so worker
/// threads can share one queue by reference during the fan-out phase.
pub struct ServerQueue {
    inner: Mutex<Inner>,
    capacity: usize,
}

impl ServerQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                enq_us: VecDeque::new(),
                stats: QueueStats::default(),
            }),
            capacity: capacity.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enqueue; returns false (and counts a drop) when at capacity.
    pub fn push(&self, batch: SmashedBatch) -> bool {
        let mut g = self.lock();
        if g.queue.len() >= self.capacity {
            g.stats.dropped += 1;
            return false;
        }
        g.queue.push_back(batch);
        if crate::telemetry::metrics_enabled() {
            g.enq_us.push_back(crate::telemetry::now_us());
        }
        g.stats.enqueued += 1;
        let depth = g.queue.len();
        g.stats.max_depth = g.stats.max_depth.max(depth);
        true
    }

    /// FIFO pop (streaming consumers; the round driver uses
    /// [`Self::drain_sorted`] instead).
    pub fn pop(&self) -> Option<SmashedBatch> {
        let mut g = self.lock();
        let b = g.queue.pop_front();
        if b.is_some() {
            g.stats.processed += 1;
            g.observe_waits(1);
        }
        b
    }

    /// Barrier drain: remove everything, ordered by `(round, client, step)`.
    /// This is the deterministic Eq. (7) consumption order — identical no
    /// matter how concurrent producers interleaved their pushes.
    pub fn drain_sorted(&self) -> Vec<SmashedBatch> {
        let mut g = self.lock();
        let mut out: Vec<SmashedBatch> = g.queue.drain(..).collect();
        out.sort_by_key(|b| (b.round, b.client, b.step));
        g.stats.processed += out.len() as u64;
        g.observe_waits(out.len());
        out
    }

    /// Arrival-order drain: remove everything currently queued, FIFO —
    /// the mid-round consumption step of the `stream` drain policy. One
    /// lock acquisition for the whole snapshot, so a concurrent producer
    /// cannot interleave *into* the returned prefix.
    pub fn drain_fifo(&self) -> Vec<SmashedBatch> {
        let mut g = self.lock();
        let out: Vec<SmashedBatch> = g.queue.drain(..).collect();
        g.stats.processed += out.len() as u64;
        g.observe_waits(out.len());
        out
    }

    pub fn len(&self) -> usize {
        self.lock().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> QueueStats {
        self.lock().stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(client: usize) -> SmashedBatch {
        batch_at(client, 0, 0)
    }

    fn batch_at(client: usize, round: usize, step: usize) -> SmashedBatch {
        SmashedBatch {
            client,
            round,
            step,
            smashed: vec![0.0; 4],
            targets: vec![1],
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let q = ServerQueue::new(10);
        for c in 0..5 {
            assert!(q.push(batch(c)));
        }
        for c in 0..5 {
            assert_eq!(q.pop().unwrap().client, c);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn capacity_enforced_and_drops_counted() {
        let q = ServerQueue::new(2);
        assert!(q.push(batch(0)));
        assert!(q.push(batch(1)));
        assert!(!q.push(batch(2)));
        assert_eq!(q.stats().dropped, 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn stats_track_depth() {
        let q = ServerQueue::new(8);
        for c in 0..6 {
            q.push(batch(c));
        }
        q.pop();
        q.push(batch(9));
        assert_eq!(q.stats().max_depth, 6);
        assert_eq!(q.stats().enqueued, 7);
        assert_eq!(q.stats().processed, 1);
    }

    #[test]
    fn drain_sorted_orders_by_round_client_step() {
        let q = ServerQueue::new(16);
        q.push(batch_at(2, 0, 1));
        q.push(batch_at(0, 1, 1));
        q.push(batch_at(0, 0, 2));
        q.push(batch_at(1, 0, 1));
        q.push(batch_at(0, 0, 1));
        let order: Vec<(usize, usize, usize)> = q
            .drain_sorted()
            .iter()
            .map(|b| (b.round, b.client, b.step))
            .collect();
        assert_eq!(
            order,
            vec![(0, 0, 1), (0, 0, 2), (0, 1, 1), (0, 2, 1), (1, 0, 1)]
        );
        assert!(q.is_empty());
        assert_eq!(q.stats().processed, 5);
    }

    #[test]
    fn drain_fifo_preserves_arrival_order_and_counts() {
        let q = ServerQueue::new(16);
        q.push(batch_at(2, 0, 1));
        q.push(batch_at(0, 0, 2));
        q.push(batch_at(1, 0, 1));
        let order: Vec<(usize, usize, usize)> = q
            .drain_fifo()
            .iter()
            .map(|b| (b.round, b.client, b.step))
            .collect();
        assert_eq!(order, vec![(0, 2, 1), (0, 0, 2), (0, 1, 1)]);
        assert!(q.is_empty());
        assert_eq!(q.stats().processed, 3);
        assert!(q.drain_fifo().is_empty());
    }

    #[test]
    fn concurrent_enqueue_conserves_counts() {
        let q = ServerQueue::new(64);
        std::thread::scope(|s| {
            for t in 0..8 {
                let q = &q;
                s.spawn(move || {
                    for i in 0..32 {
                        q.push(batch_at(t, 0, i));
                    }
                });
            }
        });
        let st = q.stats();
        assert_eq!(st.enqueued + st.dropped, 8 * 32);
        assert_eq!(st.enqueued, 64);
        assert_eq!(st.max_depth, 64);
        assert_eq!(q.len(), 64);
    }
}
