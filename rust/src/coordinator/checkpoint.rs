//! Checksummed checkpoint/restore (substrate S27): the server's full
//! round-trajectory state, serialized every `--checkpoint_every` rounds
//! to a versioned on-disk format in the `net::wire` idiom — magic,
//! version byte, length-prefixed payload, trailing CRC-32 — with no
//! serde (not in the offline vendor set).
//!
//! ## What a checkpoint holds
//!
//! Everything `Driver` needs to continue the run as if it had never
//! stopped, **bit-identically** for the stateless-optimizer variants:
//!
//! * the exact-string config JSON (`RunConfig::to_json`) — restore
//!   refuses a checkpoint whose config differs from the one the server
//!   was launched with, byte for byte, the same equality contract the
//!   networked `Assign` handshake uses;
//! * the driver state: round index, the participant-sampling RNG's raw
//!   256-bit state, θ_l / θ_s / the SFLV1 replica base, the server
//!   optimizer state, the cumulative analytic counters, and every
//!   finished round's [`RoundTiming`] (the end-of-run summary sums over
//!   all of them);
//! * the rounds recorded so far (`RoundRecord` curve points) so the
//!   restored run's output file carries the full curve;
//! * per-client completed-phase counts, which the restored server hands
//!   to freshly connecting clients via `Assign.phases` so they
//!   fast-forward their data streams to the exact batch an
//!   uninterrupted client would read next.
//!
//! Client-side optimizer state (Adam `m`/`v`) is **not** captured — the
//! bit-identical-restore contract covers the stateless-optimizer
//! variants (`opt_state == 0`, which includes every HERON
//! configuration; the networked path already requires it).
//!
//! ## File layout (all integers little-endian)
//!
//! | offset | size | field                                    |
//! |-------:|-----:|------------------------------------------|
//! | 0      | 2    | magic `b"HC"`                             |
//! | 2      | 1    | format version ([`CKPT_VERSION`])         |
//! | 3      | 1    | reserved (0)                              |
//! | 4      | 8    | payload length `n` (u64)                  |
//! | 12     | n    | payload (field layout below)              |
//! | 12+n   | 4    | CRC-32 (poly 0xEDB88320) of bytes 0..12+n |
//!
//! Writes are atomic: the frame goes to `<path>.tmp` and is renamed
//! into place, so a crash mid-write (the chaos harness kill-9s the
//! server on purpose) can never leave a truncated file at the
//! checkpoint path — the previous checkpoint survives intact, and the
//! CRC catches any corruption that slips through anyway.

use crate::coordinator::eventsim::{RoundTiming, WireRoundStats};
use crate::coordinator::round::OptState;
use crate::coordinator::server_queue::QueueStats;
use crate::metrics::RoundRecord;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// First two bytes of every checkpoint file.
pub const CKPT_MAGIC: [u8; 2] = *b"HC";
/// Format version; bumped on any layout change. A restore refuses a
/// version it does not speak — silently misreading state would be far
/// worse than failing loudly.
pub const CKPT_VERSION: u8 = 1;
/// Header bytes before the payload: magic + version + reserved + u64 len.
const CKPT_HEADER: usize = 12;
/// Upper bound on a payload (the decoder rejects larger length fields
/// before allocating — a corrupt length must not OOM the restore path).
pub const MAX_CKPT_PAYLOAD: u64 = 1 << 32;

/// The `Driver`'s complete resumable state (see
/// `Driver::export_state` / `Driver::import_state`).
#[derive(Debug, Clone, PartialEq)]
pub struct DriverState {
    /// next round index to run
    pub round_idx: u64,
    /// raw xoshiro256++ state of the participant-sampling RNG
    pub rng: [u64; 4],
    pub theta_l: Vec<f32>,
    pub theta_s: Vec<f32>,
    /// SFLV1 between-round replica base (empty for other algorithms)
    pub replica_base: Vec<f32>,
    pub opt_server: OptState,
    pub comm_bytes: u64,
    pub flops_client: u64,
    /// every finished round's event-sim timing (the run summary sums
    /// over all of them, so a restored run's summary is bit-identical)
    pub timings: Vec<RoundTiming>,
}

/// One on-disk checkpoint: config identity + driver state + the curve
/// recorded so far + per-client completed-phase counts.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// exact-string `RunConfig::to_json` of the run this state belongs to
    pub cfg_json: String,
    pub state: DriverState,
    /// curve points of the rounds already finished
    pub rounds: Vec<RoundRecord>,
    /// client id → completed local phases (only nonzero entries are
    /// stored; feeds `Assign.phases` after a restore)
    pub phases: BTreeMap<usize, u64>,
}

// ---------------------------------------------------------------------------
// payload writer / reader (the wire idiom, with u64 lengths for blobs)
// ---------------------------------------------------------------------------

struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_f32(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.pos < n {
            bail!("checkpoint payload truncated");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// Validated element count: the declared count must fit in the
    /// remaining bytes *before* anything allocates.
    fn len(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.u64()?;
        let remaining = (self.b.len() - self.pos) as u64;
        match n.checked_mul(elem_size as u64) {
            Some(bytes) if bytes <= remaining => Ok(n as usize),
            _ => bail!("checkpoint vector length exceeds payload"),
        }
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len(1)?;
        String::from_utf8(self.take(n)?.to_vec())
            .context("checkpoint string is not utf-8")
    }
    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_le_bytes(self.take(4)?.try_into().unwrap()));
        }
        Ok(v)
    }
    fn finish(self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!("checkpoint has trailing payload bytes");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// field encoders
// ---------------------------------------------------------------------------

fn put_opt_state(w: &mut Wr, o: &OptState) {
    match o {
        OptState::None => w.u8(0),
        OptState::Adam { m, v, t } => {
            w.u8(1);
            w.vec_f32(m);
            w.vec_f32(v);
            w.f64(*t as f64);
        }
    }
}

fn get_opt_state(r: &mut Rd) -> Result<OptState> {
    match r.u8()? {
        0 => Ok(OptState::None),
        1 => Ok(OptState::Adam {
            m: r.vec_f32()?,
            v: r.vec_f32()?,
            t: r.f64()? as f32,
        }),
        t => bail!("unknown optimizer-state tag {t}"),
    }
}

fn put_timing(w: &mut Wr, t: &RoundTiming) {
    w.f64(t.client_phase);
    w.f64(t.server_phase);
    w.f64(t.sync_phase);
    w.f64(t.client_idle);
    w.u64(t.workers as u64);
    w.f64(t.host_makespan);
    w.u64(t.queue.enqueued);
    w.u64(t.queue.processed);
    w.u64(t.queue.dropped);
    w.u64(t.queue.max_depth as u64);
    w.u64(t.wire.bytes_sent);
    w.u64(t.wire.bytes_recv);
    w.u64(t.wire.frames_sent);
    w.u64(t.wire.frames_recv);
    w.f64(t.server_makespan_barrier);
    w.f64(t.server_makespan_stream);
    w.f64(t.queue_wait_barrier);
    w.f64(t.queue_wait_stream);
    w.u64(t.cut_clients.len() as u64);
    for &c in &t.cut_clients {
        w.u64(c as u64);
    }
}

fn get_timing(r: &mut Rd) -> Result<RoundTiming> {
    let mut t = RoundTiming {
        client_phase: r.f64()?,
        server_phase: r.f64()?,
        sync_phase: r.f64()?,
        client_idle: r.f64()?,
        workers: r.u64()? as usize,
        host_makespan: r.f64()?,
        queue: QueueStats::default(),
        wire: WireRoundStats::default(),
        server_makespan_barrier: 0.0,
        server_makespan_stream: 0.0,
        queue_wait_barrier: 0.0,
        queue_wait_stream: 0.0,
        cut_clients: Vec::new(),
    };
    t.queue = QueueStats {
        enqueued: r.u64()?,
        processed: r.u64()?,
        dropped: r.u64()?,
        max_depth: r.u64()? as usize,
    };
    t.wire = WireRoundStats {
        bytes_sent: r.u64()?,
        bytes_recv: r.u64()?,
        frames_sent: r.u64()?,
        frames_recv: r.u64()?,
    };
    t.server_makespan_barrier = r.f64()?;
    t.server_makespan_stream = r.f64()?;
    t.queue_wait_barrier = r.f64()?;
    t.queue_wait_stream = r.f64()?;
    let n = r.len(8)?;
    t.cut_clients = (0..n)
        .map(|_| r.u64().map(|c| c as usize))
        .collect::<Result<_>>()?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------------

/// Serialize a checkpoint to its complete on-disk byte image (header +
/// payload + CRC).
pub fn encode(ck: &Checkpoint) -> Vec<u8> {
    let mut w = Wr { buf: Vec::with_capacity(256) };
    w.buf.extend_from_slice(&CKPT_MAGIC);
    w.u8(CKPT_VERSION);
    w.u8(0); // reserved
    w.u64(0); // length backfilled below
    w.str(&ck.cfg_json);
    let s = &ck.state;
    w.u64(s.round_idx);
    for &x in &s.rng {
        w.u64(x);
    }
    w.vec_f32(&s.theta_l);
    w.vec_f32(&s.theta_s);
    w.vec_f32(&s.replica_base);
    put_opt_state(&mut w, &s.opt_server);
    w.u64(s.comm_bytes);
    w.u64(s.flops_client);
    w.u64(s.timings.len() as u64);
    for t in &s.timings {
        put_timing(&mut w, t);
    }
    w.u64(ck.rounds.len() as u64);
    for rr in &ck.rounds {
        w.u64(rr.round as u64);
        w.f64(rr.train_loss);
        w.f64(rr.eval_metric);
        w.u64(rr.comm_bytes_cum);
        w.f64(rr.wall_seconds);
    }
    w.u64(ck.phases.len() as u64);
    for (&ci, &n) in &ck.phases {
        w.u64(ci as u64);
        w.u64(n);
    }
    let plen = (w.buf.len() - CKPT_HEADER) as u64;
    w.buf[4..12].copy_from_slice(&plen.to_le_bytes());
    let crc = crate::net::wire::crc32(&w.buf);
    w.buf.extend_from_slice(&crc.to_le_bytes());
    w.buf
}

/// Decode a checkpoint from its on-disk byte image, validating magic,
/// version, length, and CRC before touching any payload field.
pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
    if bytes.len() < CKPT_HEADER + 4 {
        bail!("checkpoint file truncated ({} bytes)", bytes.len());
    }
    if bytes[0..2] != CKPT_MAGIC {
        bail!("not a checkpoint file (bad magic {:02x?})", &bytes[0..2]);
    }
    if bytes[2] != CKPT_VERSION {
        bail!(
            "checkpoint format version {} (this build speaks {})",
            bytes[2],
            CKPT_VERSION
        );
    }
    let plen = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    if plen > MAX_CKPT_PAYLOAD {
        bail!("checkpoint payload length {plen} exceeds cap");
    }
    let total = CKPT_HEADER + plen as usize + 4;
    if bytes.len() != total {
        bail!(
            "checkpoint length mismatch: header says {total} bytes, file has {}",
            bytes.len()
        );
    }
    let body = &bytes[..CKPT_HEADER + plen as usize];
    let want = u32::from_le_bytes(bytes[total - 4..].try_into().unwrap());
    let got = crate::net::wire::crc32(body);
    if want != got {
        bail!("checkpoint checksum mismatch: file says {want:08x}, computed {got:08x}");
    }
    let mut r = Rd { b: &body[CKPT_HEADER..], pos: 0 };
    let cfg_json = r.str()?;
    let round_idx = r.u64()?;
    let mut rng = [0u64; 4];
    for slot in &mut rng {
        *slot = r.u64()?;
    }
    let theta_l = r.vec_f32()?;
    let theta_s = r.vec_f32()?;
    let replica_base = r.vec_f32()?;
    let opt_server = get_opt_state(&mut r)?;
    let comm_bytes = r.u64()?;
    let flops_client = r.u64()?;
    // element-size lower bounds keep a corrupt count from allocating
    // beyond the payload it claims to describe
    let n_t = r.len(8)?;
    let mut timings = Vec::with_capacity(n_t);
    for _ in 0..n_t {
        timings.push(get_timing(&mut r)?);
    }
    let n_r = r.len(40)?;
    let mut rounds = Vec::with_capacity(n_r);
    for _ in 0..n_r {
        rounds.push(RoundRecord {
            round: r.u64()? as usize,
            train_loss: r.f64()?,
            eval_metric: r.f64()?,
            comm_bytes_cum: r.u64()?,
            wall_seconds: r.f64()?,
        });
    }
    let n_p = r.len(16)?;
    let mut phases = BTreeMap::new();
    for _ in 0..n_p {
        let ci = r.u64()? as usize;
        let n = r.u64()?;
        phases.insert(ci, n);
    }
    r.finish()?;
    Ok(Checkpoint {
        cfg_json,
        state: DriverState {
            round_idx,
            rng,
            theta_l,
            theta_s,
            replica_base,
            opt_server,
            comm_bytes,
            flops_client,
            timings,
        },
        rounds,
        phases,
    })
}

/// Write a checkpoint to `path` **atomically**: the frame goes to
/// `<path>.tmp` first and is renamed into place, so a crash at any
/// point leaves either the previous checkpoint or the new one — never
/// a partial file.
pub fn save(ck: &Checkpoint, path: &Path) -> Result<()> {
    let bytes = encode(ck);
    let _s = crate::span!("checkpoint_write", bytes = bytes.len());
    let tmp = path.with_extension("tmp");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| {
                format!("creating checkpoint dir {}", dir.display())
            })?;
        }
    }
    {
        let mut f = std::fs::File::create(&tmp).with_context(|| {
            format!("creating checkpoint temp file {}", tmp.display())
        })?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| {
        format!("renaming checkpoint into place at {}", path.display())
    })?;
    Ok(())
}

/// Read and validate a checkpoint from `path`.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding checkpoint {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut timing = RoundTiming {
            client_phase: 1.5,
            server_phase: 0.25,
            sync_phase: 0.125,
            client_idle: 0.0625,
            workers: 4,
            host_makespan: 2.0,
            ..RoundTiming::default()
        };
        timing.queue = QueueStats {
            enqueued: 12,
            processed: 12,
            dropped: 0,
            max_depth: 7,
        };
        timing.wire = WireRoundStats {
            bytes_sent: 1 << 20,
            bytes_recv: 1 << 18,
            frames_sent: 99,
            frames_recv: 42,
        };
        timing.server_makespan_barrier = 3.5;
        timing.server_makespan_stream = 2.75;
        timing.queue_wait_barrier = 10.0;
        timing.queue_wait_stream = 0.5;
        timing.cut_clients = vec![3, 11];
        Checkpoint {
            cfg_json: "{\"variant\": \"cnn_c1\"}".into(),
            state: DriverState {
                round_idx: 2,
                rng: [1, u64::MAX, 0, 0xDEAD_BEEF],
                theta_l: vec![1.0, -0.5, f32::MIN_POSITIVE],
                theta_s: vec![0.25; 5],
                replica_base: Vec::new(),
                opt_server: OptState::None,
                comm_bytes: 1 << 33,
                flops_client: 123_456_789,
                timings: vec![timing],
            },
            rounds: vec![
                RoundRecord {
                    round: 0,
                    train_loss: 2.25,
                    eval_metric: 0.5,
                    comm_bytes_cum: 4096,
                    wall_seconds: 0.75,
                },
                RoundRecord {
                    round: 1,
                    train_loss: 2.0,
                    eval_metric: f64::NAN, // off-cadence round
                    comm_bytes_cum: 8192,
                    wall_seconds: 1.5,
                },
            ],
            phases: [(0usize, 2u64), (3, 1)].into_iter().collect(),
        }
    }

    /// NaN-tolerant equality (eval_metric is NaN off the eval cadence;
    /// f64s travel as bit patterns so NaN payloads roundtrip exactly).
    fn assert_roundtrip(ck: &Checkpoint) {
        let back = decode(&encode(ck)).unwrap();
        assert_eq!(back.cfg_json, ck.cfg_json);
        assert_eq!(back.state.round_idx, ck.state.round_idx);
        assert_eq!(back.state.rng, ck.state.rng);
        assert_eq!(back.state.theta_l, ck.state.theta_l);
        assert_eq!(back.state.theta_s, ck.state.theta_s);
        assert_eq!(back.state.replica_base, ck.state.replica_base);
        assert_eq!(back.state.opt_server, ck.state.opt_server);
        assert_eq!(back.state.comm_bytes, ck.state.comm_bytes);
        assert_eq!(back.state.flops_client, ck.state.flops_client);
        assert_eq!(back.state.timings.len(), ck.state.timings.len());
        for (a, b) in back.state.timings.iter().zip(&ck.state.timings) {
            assert_eq!(a.client_phase.to_bits(), b.client_phase.to_bits());
            assert_eq!(a.queue, b.queue);
            assert_eq!(a.wire, b.wire);
            assert_eq!(a.cut_clients, b.cut_clients);
            assert_eq!(
                a.queue_wait_stream.to_bits(),
                b.queue_wait_stream.to_bits()
            );
        }
        assert_eq!(back.rounds.len(), ck.rounds.len());
        for (a, b) in back.rounds.iter().zip(&ck.rounds) {
            assert_eq!(a.round, b.round);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            assert_eq!(a.wall_seconds.to_bits(), b.wall_seconds.to_bits());
        }
        assert_eq!(back.phases, ck.phases);
    }

    #[test]
    fn roundtrips_bit_exactly_including_nan_metrics() {
        assert_roundtrip(&sample());
    }

    #[test]
    fn adam_opt_state_roundtrips() {
        let mut ck = sample();
        ck.state.opt_server = OptState::Adam {
            m: vec![0.5, -0.25],
            v: vec![0.125, 0.0625],
            t: 17.0,
        };
        assert_roundtrip(&ck);
    }

    #[test]
    fn corruption_is_detected() {
        let bytes = encode(&sample());
        // flip a payload bit → checksum
        let mut f = bytes.clone();
        f[CKPT_HEADER + 3] ^= 0x10;
        let e = decode(&f).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");
        // truncation → length mismatch (never a partial parse)
        let e = decode(&bytes[..bytes.len() - 5]).unwrap_err().to_string();
        assert!(e.contains("length mismatch"), "{e}");
        // bad magic
        let mut f = bytes.clone();
        f[0] = b'X';
        let e = decode(&f).unwrap_err().to_string();
        assert!(e.contains("magic"), "{e}");
        // future version
        let mut f = bytes;
        f[2] = 99;
        let e = decode(&f).unwrap_err().to_string();
        assert!(e.contains("version 99"), "{e}");
    }

    #[test]
    fn save_is_atomic_and_load_validates() {
        let dir = std::env::temp_dir().join(format!(
            "heron_ckpt_test_{}_{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").len()
        ));
        let path = dir.join("state.ckpt");
        let ck = sample();
        save(&ck, &path).unwrap();
        // the temp file must be gone — only the renamed target remains
        assert!(!path.with_extension("tmp").exists());
        let back = load(&path).unwrap();
        assert_eq!(back.cfg_json, ck.cfg_json);
        assert_eq!(back.state.rng, ck.state.rng);
        // overwrite with a later checkpoint; load sees the new one
        let mut later = ck.clone();
        later.state.round_idx = 5;
        save(&later, &path).unwrap();
        assert_eq!(load(&path).unwrap().state.round_idx, 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
