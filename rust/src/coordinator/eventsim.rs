//! Discrete-event latency simulator (substrate S14).
//!
//! The paper's protocol is synchronous; actual client parallelism is modeled
//! over *virtual time* while compute executes sequentially on the single
//! PJRT client. Each device profile has a compute rate (FLOP/s) and an
//! uplink/downlink bandwidth; the simulator derives per-round wall-clock:
//!
//!   round_time = max_i(client_compute_i + uplink_i) + server_queue_time
//!              + aggregation broadcast
//!
//! which is what the paper's idle-time / training-lock discussion is about:
//! SFLV1/V2 serialize every local step against a server round-trip, while
//! decoupled methods overlap.

#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    /// sustained client compute, FLOP/s (edge device)
    pub client_flops: f64,
    /// sustained server compute, FLOP/s
    pub server_flops: f64,
    /// uplink bandwidth, bytes/s
    pub uplink_bps: f64,
    /// downlink bandwidth, bytes/s
    pub downlink_bps: f64,
    /// per-message latency floor, seconds
    pub rtt: f64,
}

impl DeviceProfile {
    /// A Raspberry-Pi-class edge device on home broadband against a
    /// datacenter server — the regime the paper's intro motivates.
    pub fn edge_default() -> Self {
        Self {
            client_flops: 8e9,
            server_flops: 2e12,
            uplink_bps: 2.5e6,
            downlink_bps: 10e6,
            rtt: 0.02,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// virtual seconds of the parallel client phase (max over clients)
    pub client_phase: f64,
    /// virtual seconds the server spends draining the queue
    pub server_phase: f64,
    /// model sync (broadcast + collect)
    pub sync_phase: f64,
    /// total idle time clients spend blocked on the server (training lock)
    pub client_idle: f64,
}

impl RoundTiming {
    pub fn total(&self) -> f64 {
        self.client_phase + self.server_phase + self.sync_phase
    }
}

/// Accumulates per-client virtual time within one round, then folds into a
/// `RoundTiming`. Owns a copy of the (small, Copy) device profile so the
/// round driver can mutate itself while the sim is live.
pub struct RoundSim {
    profile: DeviceProfile,
    client_times: Vec<f64>,
    client_idle: Vec<f64>,
    server_time: f64,
    sync_bytes: u64,
}

impl RoundSim {
    pub fn new(profile: &DeviceProfile, n_clients: usize) -> Self {
        Self {
            profile: *profile,
            client_times: vec![0.0; n_clients],
            client_idle: vec![0.0; n_clients],
            server_time: 0.0,
            sync_bytes: 0,
        }
    }

    pub fn client_compute(&mut self, client: usize, flops: u64) {
        self.client_times[client] += flops as f64 / self.profile.client_flops;
    }

    pub fn client_upload(&mut self, client: usize, bytes: u64) {
        self.client_times[client] +=
            bytes as f64 / self.profile.uplink_bps + self.profile.rtt;
    }

    pub fn client_download(&mut self, client: usize, bytes: u64) {
        self.client_times[client] +=
            bytes as f64 / self.profile.downlink_bps + self.profile.rtt;
    }

    pub fn server_compute(&mut self, flops: u64) {
        self.server_time += flops as f64 / self.profile.server_flops;
    }

    /// Synchronous round-trip: the client blocks while the server computes
    /// (SFLV1/V2's training lock). Charges the client the wait as idle time.
    pub fn client_blocked_on_server(&mut self, client: usize, server_flops: u64) {
        let wait = server_flops as f64 / self.profile.server_flops
            + 2.0 * self.profile.rtt;
        self.client_times[client] += wait;
        self.client_idle[client] += wait;
    }

    pub fn sync(&mut self, bytes_per_client: u64) {
        self.sync_bytes += bytes_per_client;
    }

    pub fn finish(self) -> RoundTiming {
        let client_phase = self
            .client_times
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        let n = self.client_times.len().max(1) as f64;
        let sync_phase = self.sync_bytes as f64
            / self.profile.downlink_bps.min(self.profile.uplink_bps)
            / n
            + self.profile.rtt;
        RoundTiming {
            client_phase,
            server_phase: self.server_time,
            sync_phase,
            client_idle: self.client_idle.iter().sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> DeviceProfile {
        DeviceProfile {
            client_flops: 1e9,
            server_flops: 1e12,
            uplink_bps: 1e6,
            downlink_bps: 1e7,
            rtt: 0.01,
        }
    }

    #[test]
    fn client_phase_is_max_not_sum() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 3);
        sim.client_compute(0, 1_000_000_000); // 1 s
        sim.client_compute(1, 2_000_000_000); // 2 s
        sim.client_compute(2, 500_000_000); // 0.5 s
        let t = sim.finish();
        assert!((t.client_phase - 2.0).abs() < 1e-9);
    }

    #[test]
    fn upload_time_includes_rtt() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.client_upload(0, 1_000_000); // 1 s + 0.01 rtt
        let t = sim.finish();
        assert!((t.client_phase - 1.01).abs() < 1e-9);
    }

    #[test]
    fn training_lock_accumulates_idle() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 2);
        for _ in 0..10 {
            sim.client_blocked_on_server(0, 1_000_000_000); // 1 ms + 20 ms rtt
        }
        let t = sim.finish();
        assert!(t.client_idle > 0.2, "idle {}", t.client_idle);
    }

    #[test]
    fn server_phase_independent_of_client_phase() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.server_compute(5_000_000_000_000); // 5 s of server work
        let t = sim.finish();
        assert!((t.server_phase - 5.0).abs() < 1e-9);
        assert_eq!(t.client_phase, 0.0);
    }

    #[test]
    fn total_is_sum_of_phases() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.client_compute(0, 1_000_000_000);
        sim.server_compute(1_000_000_000_000);
        sim.sync(1_000_000);
        let t = sim.finish();
        assert!(
            (t.total() - (t.client_phase + t.server_phase + t.sync_phase))
                .abs()
                < 1e-12
        );
    }
}
