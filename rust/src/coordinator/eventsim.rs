//! Discrete-event latency simulator (substrate S14).
//!
//! The paper's protocol is synchronous; the simulator accounts the client
//! fleet's parallelism over *virtual time* regardless of how many host
//! worker threads executed the round. Each device profile has a compute
//! rate (FLOP/s) and an uplink/downlink bandwidth; the simulator derives
//! per-round wall-clock:
//!
//!   round_time = max_i(client_compute_i + uplink_i) + server_queue_time
//!              + aggregation broadcast
//!
//! which is what the paper's idle-time / training-lock discussion is about:
//! SFLV1/V2 serialize every local step against a server round-trip, while
//! decoupled methods overlap.
//!
//! Since the round driver now fans clients out across a host worker pool,
//! the simulator additionally records the pool width and the Main-Server
//! queue's occupancy/backpressure statistics, and exposes a host-side
//! makespan estimate (`host_makespan`) — the greedy least-loaded schedule
//! of the per-client virtual compute over `workers` lanes — so virtual-time
//! accounting can be compared against observed wall-clock parallelism.
//!
//! ## Arrival-time-driven server occupancy (`--drain` comparison)
//!
//! Every queued smashed upload is stamped with the uploading client's
//! virtual lane time ([`ClientLane::upload_queued`] in-process; the
//! `SmashedSeq` wire message's `sent_at` on the networked path). From
//! those arrivals and the round's total server busy time the simulator
//! derives, for *every* round regardless of which policy actually ran:
//!
//! * `server_makespan_barrier` — server completion when consumption
//!   waits for the round barrier: `client_phase + Σ per-batch cost`;
//! * `server_makespan_stream` — completion when consuming in arrival
//!   order mid-round: `t ← max(t, arrival) + cost` over the
//!   arrival-sorted events (the server starts as soon as the first
//!   upload lands, so stream ≤ barrier, strictly under skewed or
//!   mid-round arrivals);
//! * `queue_wait_{barrier,stream}` — summed virtual time batches sit in
//!   the queue before service begins, under each schedule.
//!
//! Per-client latency skew (slow stragglers vs fast devices) is modeled
//! with [`RoundSim::set_client_speed`], which scales a client's whole
//! lane (compute and link) when it merges.

use crate::coordinator::server_queue::QueueStats;

/// Measured wire traffic for one round of a *networked* run (frame bytes
/// actually serialized onto the transport, server-side view). All-zero
/// for in-process runs — the run summary prints these next to the
/// analytic `CostBook` bytes so the two accountings can be compared.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireRoundStats {
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub frames_sent: u64,
    pub frames_recv: u64,
}

impl WireRoundStats {
    /// Per-field difference `self - earlier` (cumulative counters →
    /// per-round deltas).
    pub fn since(&self, earlier: &WireRoundStats) -> WireRoundStats {
        WireRoundStats {
            bytes_sent: self.bytes_sent - earlier.bytes_sent,
            bytes_recv: self.bytes_recv - earlier.bytes_recv,
            frames_sent: self.frames_sent - earlier.frames_sent,
            frames_recv: self.frames_recv - earlier.frames_recv,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    /// sustained client compute, FLOP/s (edge device)
    pub client_flops: f64,
    /// sustained server compute, FLOP/s
    pub server_flops: f64,
    /// uplink bandwidth, bytes/s
    pub uplink_bps: f64,
    /// downlink bandwidth, bytes/s
    pub downlink_bps: f64,
    /// per-message latency floor, seconds
    pub rtt: f64,
}

impl DeviceProfile {
    /// A Raspberry-Pi-class edge device on home broadband against a
    /// datacenter server — the regime the paper's intro motivates.
    pub fn edge_default() -> Self {
        Self {
            client_flops: 8e9,
            server_flops: 2e12,
            uplink_bps: 2.5e6,
            downlink_bps: 10e6,
            rtt: 0.02,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// virtual seconds of the parallel client phase (max over clients —
    /// every simulated client is its own device)
    pub client_phase: f64,
    /// virtual seconds the server spends draining the queue
    pub server_phase: f64,
    /// model sync (broadcast + collect)
    pub sync_phase: f64,
    /// total idle time clients spend blocked on the server (training lock)
    pub client_idle: f64,
    /// host worker-pool width used to execute this round
    pub workers: usize,
    /// greedy makespan of the per-client virtual compute over `workers`
    /// host lanes — what the wall clock should scale like
    pub host_makespan: f64,
    /// Main-Server queue occupancy/backpressure for this round
    pub queue: QueueStats,
    /// measured wire traffic for this round (networked runs only)
    pub wire: WireRoundStats,
    /// server completion time (virtual s) if consumption waits for the
    /// round barrier: `client_phase + server busy time`
    pub server_makespan_barrier: f64,
    /// server completion time (virtual s) when consuming queued uploads
    /// in arrival order mid-round; equals the barrier makespan when no
    /// arrival events were recorded (locked algorithms, or a networked
    /// barrier run where `sent_at` never crosses the wire)
    pub server_makespan_stream: f64,
    /// summed virtual time batches wait in the queue before service
    /// begins, under the barrier schedule (arrival-sorted service from
    /// the barrier onward)
    pub queue_wait_barrier: f64,
    /// same, under the arrival-order mid-round schedule
    pub queue_wait_stream: f64,
    /// participants cut off this round — by the straggler deadline
    /// (`--round_deadline_ms`) or a mid-round disconnect. A cut client
    /// contributed nothing: its queued uploads were discarded at the
    /// barrier and its θ never entered FedAvg. Empty for every round of
    /// a deadline-free, churn-free run.
    pub cut_clients: Vec<usize>,
}

impl RoundTiming {
    pub fn total(&self) -> f64 {
        self.client_phase + self.server_phase + self.sync_phase
    }
}

/// Mirror run-level aggregates of the per-round timings into the
/// telemetry registry (`eventsim.*` / `net.*` gauges). Absolute sets —
/// idempotent, called at finalize time by the round driver and the
/// networked dispatcher.
pub fn publish_timings_registry(timings: &[RoundTiming]) {
    use crate::telemetry::registry::gauge;
    gauge("eventsim.rounds").set(timings.len() as f64);
    gauge("eventsim.virtual_seconds")
        .set(timings.iter().map(|t| t.total()).sum());
    gauge("eventsim.client_idle_seconds")
        .set(timings.iter().map(|t| t.client_idle).sum());
    gauge("eventsim.host_makespan_seconds")
        .set(timings.iter().map(|t| t.host_makespan).sum());
    gauge("eventsim.server_makespan_barrier_seconds")
        .set(timings.iter().map(|t| t.server_makespan_barrier).sum());
    gauge("eventsim.server_makespan_stream_seconds")
        .set(timings.iter().map(|t| t.server_makespan_stream).sum());
    gauge("eventsim.cut_clients")
        .set(timings.iter().map(|t| t.cut_clients.len() as f64).sum());
    let q = |f: fn(&QueueStats) -> f64| -> f64 {
        timings.iter().map(|t| f(&t.queue)).sum()
    };
    gauge("queue.enqueued").set(q(|s| s.enqueued as f64));
    gauge("queue.processed").set(q(|s| s.processed as f64));
    gauge("queue.dropped").set(q(|s| s.dropped as f64));
    gauge("queue.max_depth").set(
        timings
            .iter()
            .map(|t| t.queue.max_depth as f64)
            .fold(0.0, f64::max),
    );
    gauge("net.bytes_sent")
        .set(timings.iter().map(|t| t.wire.bytes_sent as f64).sum());
    gauge("net.bytes_recv")
        .set(timings.iter().map(|t| t.wire.bytes_recv as f64).sum());
    gauge("net.frames_sent")
        .set(timings.iter().map(|t| t.wire.frames_sent as f64).sum());
    gauge("net.frames_recv")
        .set(timings.iter().map(|t| t.wire.frames_recv as f64).sum());
}

/// Per-client virtual-time accumulator usable from a worker thread: owns a
/// copy of the (small, Copy) device profile and accumulates one client's
/// lane locally, to be merged into the round sim at the barrier.
#[derive(Debug, Clone)]
pub struct ClientLane {
    profile: DeviceProfile,
    pub time: f64,
    pub idle: f64,
    /// virtual times at which this lane's *queued* uploads reach the
    /// server (stamped by [`Self::upload_queued`]; drives the drain
    /// policy makespan comparison)
    pub arrivals: Vec<f64>,
}

impl ClientLane {
    pub fn new(profile: &DeviceProfile) -> Self {
        Self {
            profile: *profile,
            time: 0.0,
            idle: 0.0,
            arrivals: Vec::new(),
        }
    }

    pub fn compute(&mut self, flops: u64) {
        self.time += flops as f64 / self.profile.client_flops;
    }

    pub fn upload(&mut self, bytes: u64) {
        self.time += bytes as f64 / self.profile.uplink_bps + self.profile.rtt;
    }

    /// An upload that lands in the Main-Server queue: charges the
    /// transfer like [`Self::upload`] and records the completion time as
    /// the batch's server-side arrival event.
    pub fn upload_queued(&mut self, bytes: u64) {
        self.upload(bytes);
        self.mark_arrival();
    }

    /// Record the lane's current time as a server-side arrival event.
    /// Callers that must first learn whether the queue *accepted* the
    /// upload (a dropped batch is never serviced, so it must not enter
    /// the server-occupancy schedule) charge [`Self::upload`] and then
    /// call this on success.
    pub fn mark_arrival(&mut self) {
        self.arrivals.push(self.time);
    }

    pub fn download(&mut self, bytes: u64) {
        self.time +=
            bytes as f64 / self.profile.downlink_bps + self.profile.rtt;
    }

    /// Synchronous round-trip: the client blocks while the server computes
    /// (SFLV1/V2's training lock). Charges the wait as idle time.
    pub fn blocked_on_server(&mut self, server_flops: u64) {
        let wait = server_flops as f64 / self.profile.server_flops
            + 2.0 * self.profile.rtt;
        self.time += wait;
        self.idle += wait;
    }
}

/// Accumulates per-client virtual time within one round, then folds into a
/// `RoundTiming`. Owns a copy of the (small, Copy) device profile so the
/// round driver can mutate itself while the sim is live.
///
/// The sim is **cohort-scoped**: per-client accumulators exist only for
/// the round's sampled participants ([`Self::new_cohort`]), so one round
/// of bookkeeping costs O(cohort) regardless of the registered
/// population. The population size is still recorded separately because
/// the sync-phase formula divides by it — cohort scoping is a memory
/// bound, never a timing change.
pub struct RoundSim {
    profile: DeviceProfile,
    /// registered population size (the sync-phase divisor; kept apart
    /// from the cohort so memory scoping cannot shift any timing)
    population: usize,
    /// cohort member → dense slot into the per-client vectors
    slots: std::collections::BTreeMap<usize, usize>,
    client_times: Vec<f64>,
    client_idle: Vec<f64>,
    /// per-client device speed factor (1.0 = the profile as-is; 0.5 = a
    /// straggler running at half speed). Applied when a lane merges.
    client_speed: Vec<f64>,
    server_time: f64,
    /// virtual arrival times of queued uploads at the server
    arrivals: Vec<f64>,
    sync_down_bytes: u64,
    sync_up_bytes: u64,
    workers: usize,
    queue_stats: QueueStats,
    wire: WireRoundStats,
    /// participants cut off this round (deadline or disconnect)
    cut: Vec<usize>,
}

impl RoundSim {
    /// Whole-population sim: every client id in `0..n_clients` is a
    /// cohort member. Kept for full-participation rounds and tests; the
    /// round drivers use [`Self::new_cohort`] with the sampled
    /// participants.
    pub fn new(profile: &DeviceProfile, n_clients: usize) -> Self {
        let cohort: Vec<usize> = (0..n_clients).collect();
        Self::new_cohort(profile, &cohort, n_clients)
    }

    /// Cohort-scoped sim: per-client state is allocated only for the
    /// listed participants (any client ids out of `0..population`), so
    /// one round costs O(cohort) memory. Accounting calls for a client
    /// outside the cohort panic — they would mean the round engine is
    /// doing work for a client it never sampled.
    pub fn new_cohort(
        profile: &DeviceProfile,
        cohort: &[usize],
        population: usize,
    ) -> Self {
        let slots: std::collections::BTreeMap<usize, usize> = cohort
            .iter()
            .enumerate()
            .map(|(slot, &ci)| (ci, slot))
            .collect();
        let n = slots.len();
        Self {
            profile: *profile,
            population,
            slots,
            client_times: vec![0.0; n],
            client_idle: vec![0.0; n],
            client_speed: vec![1.0; n],
            server_time: 0.0,
            arrivals: Vec::new(),
            sync_down_bytes: 0,
            sync_up_bytes: 0,
            workers: n.max(1),
            queue_stats: QueueStats::default(),
            wire: WireRoundStats::default(),
            cut: Vec::new(),
        }
    }

    fn slot(&self, client: usize) -> usize {
        *self
            .slots
            .get(&client)
            .unwrap_or_else(|| panic!("client {client} is not in this round's cohort"))
    }

    /// Skew one client's device speed: its whole lane (compute and
    /// link) is divided by `factor` at merge time, so `0.5` makes the
    /// client a 2× straggler. Whole-lane scaling means locked-phase
    /// server waits are scaled too — fine for the decoupled regime this
    /// knob models.
    pub fn set_client_speed(&mut self, client: usize, factor: f64) {
        let s = self.slot(client);
        self.client_speed[s] = factor.max(1e-9);
    }

    /// Record a queued upload's server-side arrival at an externally
    /// measured virtual time (networked path: the `SmashedSeq` frame's
    /// `sent_at`). The in-process path records arrivals through
    /// [`ClientLane::upload_queued`] + [`Self::merge_lane`] instead.
    pub fn upload_arrival(&mut self, at: f64) {
        self.arrivals.push(at);
    }

    /// Record the host worker-pool width used for this round.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    /// Record the Main-Server queue statistics observed this round.
    pub fn record_queue(&mut self, stats: QueueStats) {
        self.queue_stats = stats;
    }

    /// Record the measured wire traffic for this round (networked runs).
    pub fn record_wire(&mut self, wire: WireRoundStats) {
        self.wire = wire;
    }

    /// Record a participant the straggler deadline (or a mid-round
    /// disconnect) cut from this round. The client must be a cohort
    /// member — cutting a client the round never sampled would mean the
    /// round engine lost track of its own cohort.
    pub fn record_cutoff(&mut self, client: usize) {
        let _ = self.slot(client);
        self.cut.push(client);
    }

    pub fn lane(&self) -> ClientLane {
        ClientLane::new(&self.profile)
    }

    /// Merge a worker-thread lane into this client's virtual-time
    /// account, applying the client's speed factor to every duration
    /// (and therefore to its upload arrival events).
    pub fn merge_lane(&mut self, client: usize, lane: &ClientLane) {
        let slot = self.slot(client);
        let s = self.client_speed[slot];
        self.client_times[slot] += lane.time / s;
        self.client_idle[slot] += lane.idle / s;
        self.arrivals.extend(lane.arrivals.iter().map(|a| a / s));
    }

    // The per-event formulas live once, in ClientLane; the sequential
    // accessors below delegate through a scratch lane so the parallel
    // (lane-merge) and sequential paths can never diverge.

    pub fn client_compute(&mut self, client: usize, flops: u64) {
        let mut lane = self.lane();
        lane.compute(flops);
        self.merge_lane(client, &lane);
    }

    pub fn client_upload(&mut self, client: usize, bytes: u64) {
        let mut lane = self.lane();
        lane.upload(bytes);
        self.merge_lane(client, &lane);
    }

    pub fn client_download(&mut self, client: usize, bytes: u64) {
        let mut lane = self.lane();
        lane.download(bytes);
        self.merge_lane(client, &lane);
    }

    pub fn server_compute(&mut self, flops: u64) {
        self.server_time += flops as f64 / self.profile.server_flops;
    }

    /// Synchronous round-trip: the client blocks while the server computes
    /// (SFLV1/V2's training lock). Charges the client the wait as idle time.
    pub fn client_blocked_on_server(&mut self, client: usize, server_flops: u64) {
        let mut lane = self.lane();
        lane.blocked_on_server(server_flops);
        self.merge_lane(client, &lane);
    }

    /// Charge one participant's share of the round sync, split by
    /// direction: `down` is what the Fed-Server broadcasts to the client
    /// (dense θ_l, or a seeds+scalars `SeedSync` under
    /// `--zo_wire seed_agg`), `up` what the client returns. The split
    /// matters because the directions ride different links — the old
    /// lumped `sync(bytes)` priced both at the slower of the two, which
    /// charged a dense download against the (slower) uplink and
    /// couldn't credit a lean downlink at all.
    pub fn sync_split(&mut self, down: u64, up: u64) {
        self.sync_down_bytes += down;
        self.sync_up_bytes += up;
    }

    pub fn finish(mut self) -> RoundTiming {
        let client_phase = self
            .client_times
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        self.cut.sort_unstable();
        self.cut.dedup();
        // the sync broadcast amortizes over the whole registered
        // population (pre-cohort behavior, preserved exactly); each
        // direction is priced on its own link
        let n = self.population.max(1) as f64;
        let sync_phase = self.sync_down_bytes as f64
            / self.profile.downlink_bps
            / n
            + self.sync_up_bytes as f64 / self.profile.uplink_bps / n
            + self.profile.rtt;
        let host_makespan = makespan(&self.client_times, self.workers);
        let (server_makespan_barrier, server_makespan_stream, wb, ws) =
            server_schedules(client_phase, self.server_time, self.arrivals);
        RoundTiming {
            client_phase,
            server_phase: self.server_time,
            sync_phase,
            client_idle: self.client_idle.iter().sum(),
            workers: self.workers,
            host_makespan,
            queue: self.queue_stats,
            wire: self.wire,
            server_makespan_barrier,
            server_makespan_stream,
            queue_wait_barrier: wb,
            queue_wait_stream: ws,
            cut_clients: self.cut,
        }
    }
}

/// The barrier-vs-stream server schedules over one round's upload
/// arrival events. Both assume a uniform per-batch service cost
/// (`server_time / n_events` — true for the Eq. (7) FO step, which costs
/// the same forward+backward for every batch) and arrival-sorted service
/// order. Returns `(barrier_makespan, stream_makespan, barrier_wait,
/// stream_wait)`; with no recorded arrivals the stream schedule
/// degenerates to the barrier one.
fn server_schedules(
    client_phase: f64,
    server_time: f64,
    mut arrivals: Vec<f64>,
) -> (f64, f64, f64, f64) {
    let barrier = client_phase + server_time;
    if arrivals.is_empty() {
        return (barrier, barrier, 0.0, 0.0);
    }
    // total_cmp: never panics — non-finite garbage (rejected at the wire
    // ingress, but belt-and-braces here) sorts to an end instead of
    // crashing the round accounting
    arrivals.sort_by(f64::total_cmp);
    let per = server_time / arrivals.len() as f64;
    // barrier: service starts at the round barrier (client_phase; every
    // arrival precedes it by construction), one batch after another
    let mut wait_barrier = 0.0;
    for (i, &a) in arrivals.iter().enumerate() {
        wait_barrier += client_phase + i as f64 * per - a;
    }
    // stream: the server takes each batch as soon as it is free and the
    // batch has arrived
    let mut t = 0.0f64;
    let mut wait_stream = 0.0;
    for &a in &arrivals {
        let start = t.max(a);
        wait_stream += start - a;
        t = start + per;
    }
    (barrier, t, wait_barrier, wait_stream)
}

/// Greedy least-loaded schedule of `times` over `lanes` workers, assigning
/// in index order (the order the pool hands jobs out). Returns the maximum
/// lane load. With `lanes >= times.len()` this equals `max(times)`.
pub fn makespan(times: &[f64], lanes: usize) -> f64 {
    let lanes = lanes.max(1).min(times.len().max(1));
    let mut loads = vec![0.0f64; lanes];
    for &t in times {
        let min_idx = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        loads[min_idx] += t;
    }
    loads.iter().cloned().fold(0.0f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> DeviceProfile {
        DeviceProfile {
            client_flops: 1e9,
            server_flops: 1e12,
            uplink_bps: 1e6,
            downlink_bps: 1e7,
            rtt: 0.01,
        }
    }

    #[test]
    fn client_phase_is_max_not_sum() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 3);
        sim.client_compute(0, 1_000_000_000); // 1 s
        sim.client_compute(1, 2_000_000_000); // 2 s
        sim.client_compute(2, 500_000_000); // 0.5 s
        let t = sim.finish();
        assert!((t.client_phase - 2.0).abs() < 1e-9);
    }

    #[test]
    fn upload_time_includes_rtt() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.client_upload(0, 1_000_000); // 1 s + 0.01 rtt
        let t = sim.finish();
        assert!((t.client_phase - 1.01).abs() < 1e-9);
    }

    #[test]
    fn training_lock_accumulates_idle() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 2);
        for _ in 0..10 {
            sim.client_blocked_on_server(0, 1_000_000_000); // 1 ms + 20 ms rtt
        }
        let t = sim.finish();
        assert!(t.client_idle > 0.2, "idle {}", t.client_idle);
    }

    #[test]
    fn server_phase_independent_of_client_phase() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.server_compute(5_000_000_000_000); // 5 s of server work
        let t = sim.finish();
        assert!((t.server_phase - 5.0).abs() < 1e-9);
        assert_eq!(t.client_phase, 0.0);
    }

    #[test]
    fn total_is_sum_of_phases() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.client_compute(0, 1_000_000_000);
        sim.server_compute(1_000_000_000_000);
        sim.sync_split(600_000, 400_000);
        let t = sim.finish();
        assert!(
            (t.total() - (t.client_phase + t.server_phase + t.sync_phase))
                .abs()
                < 1e-12
        );
    }

    /// Each sync direction rides its own link: with the profile above
    /// (downlink 1e7 B/s, uplink 1e6 B/s) a 1 MB download + 0.5 MB
    /// upload over a population of 1 costs 0.1 + 0.5 + rtt — not the
    /// 1.5 MB / min-bandwidth lump the pre-split accounting charged.
    /// A zero-byte direction costs nothing, so a lean seed_agg
    /// broadcast's sync phase collapses toward the uplink term.
    #[test]
    fn sync_split_prices_each_direction_on_its_own_link() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        sim.sync_split(1_000_000, 500_000);
        let t = sim.finish();
        assert!((t.sync_phase - (0.1 + 0.5 + 0.01)).abs() < 1e-9);

        let mut lean = RoundSim::new(&p, 1);
        lean.sync_split(0, 500_000);
        let tl = lean.finish();
        assert!((tl.sync_phase - (0.5 + 0.01)).abs() < 1e-9);
        assert!(tl.sync_phase < t.sync_phase);
    }

    #[test]
    fn lane_merge_equals_direct_accounting() {
        let p = profile();
        let mut direct = RoundSim::new(&p, 2);
        direct.client_compute(0, 3_000_000_000);
        direct.client_upload(0, 2_000_000);
        direct.client_compute(1, 1_000_000_000);

        let mut merged = RoundSim::new(&p, 2);
        let mut lane0 = merged.lane();
        lane0.compute(3_000_000_000);
        lane0.upload(2_000_000);
        let mut lane1 = merged.lane();
        lane1.compute(1_000_000_000);
        merged.merge_lane(0, &lane0);
        merged.merge_lane(1, &lane1);

        let (a, b) = (direct.finish(), merged.finish());
        assert_eq!(a.client_phase, b.client_phase);
        assert_eq!(a.client_idle, b.client_idle);
    }

    #[test]
    fn makespan_limits() {
        let times = [1.0, 1.0, 1.0, 1.0];
        assert!((makespan(&times, 4) - 1.0).abs() < 1e-12); // fully parallel
        assert!((makespan(&times, 1) - 4.0).abs() < 1e-12); // sequential
        assert!((makespan(&times, 2) - 2.0).abs() < 1e-12);
        // skewed loads balance greedily
        assert!((makespan(&[3.0, 1.0, 1.0, 1.0], 2) - 3.0).abs() < 1e-12);
    }

    /// The drain-policy comparison on a hand-computed 2-client / 3-step
    /// schedule with skewed per-client latencies (the stream-drain test
    /// fixture): client 0 at full speed, client 1 a 2× straggler.
    ///
    /// Per client (before skew), with the profile above: each step costs
    /// 1 s of compute (1e9 FLOPs at 1e9 FLOP/s) and is followed by a
    /// queued upload of 1e6 B (1 s at 1e6 B/s + 0.01 rtt). So one lane is
    ///   step1 → 1.00, up1 done 2.01   (arrival 2.01)
    ///   step2 → 3.01, up2 done 4.02   (arrival 4.02)
    ///   step3 → 5.02, up3 done 6.03   (arrival 6.03)
    /// Client 1 at speed 0.5 doubles everything: arrivals 4.02, 8.04,
    /// 12.06; lane total 12.06 = client_phase.
    ///
    /// Server: 6 batches × 1e12 FLOPs at 1e12 FLOP/s = 1 s each
    /// (server_time 6 s).
    ///
    /// barrier: starts at the barrier (12.06), runs 6 s → makespan 18.06.
    /// stream (arrival-sorted 2.01, 4.02, 4.02, 6.03, 8.04, 12.06):
    ///   t = 3.01, 5.02, 6.02, 7.03, 9.04, 13.06 → makespan 13.06,
    /// strictly below the barrier schedule — the pipelining win.
    #[test]
    fn skewed_two_client_three_step_schedule_hand_computed() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 2);
        sim.set_client_speed(1, 0.5); // the straggler fixture
        for ci in 0..2usize {
            let mut lane = sim.lane();
            for _ in 0..3 {
                lane.compute(1_000_000_000);
                lane.upload_queued(1_000_000);
            }
            sim.merge_lane(ci, &lane);
        }
        for _ in 0..6 {
            sim.server_compute(1_000_000_000_000);
        }
        let t = sim.finish();
        let eps = 1e-9;
        assert!((t.client_phase - 12.06).abs() < eps, "{}", t.client_phase);
        assert!((t.server_phase - 6.0).abs() < eps);
        assert!(
            (t.server_makespan_barrier - 18.06).abs() < eps,
            "barrier {}",
            t.server_makespan_barrier
        );
        assert!(
            (t.server_makespan_stream - 13.06).abs() < eps,
            "stream {}",
            t.server_makespan_stream
        );
        assert!(
            t.server_makespan_stream < t.server_makespan_barrier,
            "pipelined consumption must strictly beat the barrier"
        );
        // queue waits, hand-computed over the same schedules: barrier
        // service starts 12.06, 13.06, …, 17.06 (sum 87.36) minus the
        // arrivals (2.01+4.02+4.02+6.03+8.04+12.06 = 36.18) → 51.18.
        assert!((t.queue_wait_barrier - 51.18).abs() < 1e-6,
            "barrier wait {}", t.queue_wait_barrier);
        // stream starts: 2.01, 4.02, 5.02, 6.03, 8.04, 12.06 → waits
        // 0 + 0 + 1.00 + 0 + 0 + 0 = 1.00.
        assert!((t.queue_wait_stream - 1.0).abs() < 1e-6,
            "stream wait {}", t.queue_wait_stream);
    }

    /// Both drain schedules without skew and with uniform mid-round
    /// arrivals: stream still strictly wins because the server starts
    /// before the barrier; with NO recorded arrivals (locked algorithms)
    /// the two schedules coincide.
    #[test]
    fn stream_schedule_degenerates_without_arrivals() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 2);
        sim.client_compute(0, 2_000_000_000);
        sim.server_compute(3_000_000_000_000);
        let t = sim.finish();
        assert_eq!(t.server_makespan_barrier, t.server_makespan_stream);
        assert!((t.server_makespan_barrier - 5.0).abs() < 1e-9);
        assert_eq!(t.queue_wait_barrier, 0.0);
        assert_eq!(t.queue_wait_stream, 0.0);
    }

    #[test]
    fn upload_arrival_feeds_the_stream_schedule() {
        // the networked path records arrivals directly (SmashedSeq
        // sent_at) — equivalent to lane-merged arrivals
        let p = profile();
        let mut sim = RoundSim::new(&p, 1);
        let mut lane = sim.lane();
        lane.compute(4_000_000_000); // client busy 4 s
        sim.merge_lane(0, &lane);
        sim.upload_arrival(1.0);
        sim.upload_arrival(2.0);
        sim.server_compute(2_000_000_000_000); // 2 batches x 1 s
        let t = sim.finish();
        assert!((t.server_makespan_barrier - 6.0).abs() < 1e-9);
        // stream: start 1.0 → done 2.0; start 2.0 → done 3.0
        assert!((t.server_makespan_stream - 3.0).abs() < 1e-9);
    }

    /// A cohort-scoped sim over the sampled participants produces the
    /// exact `RoundTiming` a whole-population sim does: zeros for
    /// non-participants never move a max, a sum, or the greedy makespan,
    /// and the sync divisor is pinned to the population either way.
    #[test]
    fn cohort_sim_matches_whole_population_sim() {
        let p = profile();
        let mut full = RoundSim::new(&p, 100);
        let mut cohort = RoundSim::new_cohort(&p, &[7, 42, 99], 100);
        for sim in [&mut full, &mut cohort] {
            sim.set_workers(2);
            sim.set_client_speed(42, 0.5);
            for &ci in &[7usize, 42, 99] {
                let mut lane = sim.lane();
                lane.compute(1_000_000_000);
                lane.upload_queued(1_000_000);
                sim.merge_lane(ci, &lane);
            }
            sim.server_compute(3_000_000_000_000);
            sim.sync_split(1_000_000, 500_000);
        }
        let (a, b) = (full.finish(), cohort.finish());
        assert_eq!(a.client_phase.to_bits(), b.client_phase.to_bits());
        assert_eq!(a.client_idle.to_bits(), b.client_idle.to_bits());
        assert_eq!(a.sync_phase.to_bits(), b.sync_phase.to_bits());
        assert_eq!(a.host_makespan.to_bits(), b.host_makespan.to_bits());
        assert_eq!(
            a.server_makespan_stream.to_bits(),
            b.server_makespan_stream.to_bits()
        );
        assert_eq!(
            a.queue_wait_barrier.to_bits(),
            b.queue_wait_barrier.to_bits()
        );
    }

    #[test]
    #[should_panic(expected = "not in this round's cohort")]
    fn accounting_outside_the_cohort_panics() {
        let p = profile();
        let mut sim = RoundSim::new_cohort(&p, &[1, 3], 8);
        sim.client_compute(2, 1);
    }

    #[test]
    fn cutoffs_recorded_sorted_and_deduped() {
        let p = profile();
        let mut sim = RoundSim::new_cohort(&p, &[3, 7, 11], 20);
        sim.record_cutoff(11);
        sim.record_cutoff(3);
        sim.record_cutoff(11); // deadline + disconnect can both cut
        let t = sim.finish();
        assert_eq!(t.cut_clients, vec![3, 11]);
    }

    #[test]
    #[should_panic(expected = "not in this round's cohort")]
    fn cutoff_outside_the_cohort_panics() {
        let p = profile();
        let mut sim = RoundSim::new_cohort(&p, &[1], 4);
        sim.record_cutoff(2);
    }

    #[test]
    fn workers_and_queue_recorded() {
        let p = profile();
        let mut sim = RoundSim::new(&p, 4);
        sim.set_workers(2);
        sim.client_compute(0, 1_000_000_000);
        sim.client_compute(1, 1_000_000_000);
        let stats = crate::coordinator::server_queue::QueueStats {
            enqueued: 8,
            processed: 8,
            dropped: 0,
            max_depth: 5,
        };
        sim.record_queue(stats.clone());
        let t = sim.finish();
        assert_eq!(t.workers, 2);
        assert_eq!(t.queue, stats);
        // two 1s clients on 2 lanes -> makespan 1s; on the fleet also 1s
        assert!((t.host_makespan - 1.0).abs() < 1e-9);
    }
}
