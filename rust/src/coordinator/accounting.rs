//! Client-side resource accounting (substrate S13): the paper's Table I
//! formulas instantiated with the manifest's per-variant cost model, used to
//! regenerate Tables II and III.
//!
//! Notation from the paper (§V-B): p = batch size, q = smashed-layer size,
//! |θc|, |θa| = client/aux parameter counts, F_c, F_a = forward-pass FLOPs.
//! A backward pass is charged 2× a forward (the standard 1:2 rule, paper
//! [47]); the two-point ZO step costs n_p+1 forward evaluations (n_p probes
//! + the shared base evaluation; the paper's n_p(F_c+F_a) counts the same
//! two forwards for n_p = 2).

use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::config::ZoWireMode;
use crate::net::codec::{self, Codec, GradCodec};
use crate::runtime::manifest::VariantSpec;

pub const BYTES_F32: u64 = 4;
pub const BYTES_F64: u64 = 8;
pub const BYTES_U32: u64 = 4;

/// Per-step / per-round client resource deltas for one algorithm on one
/// model variant.
#[derive(Debug, Clone)]
pub struct CostBook {
    /// bytes uploaded per smashed-data upload (p·q in the paper)
    pub smashed_bytes: u64,
    /// bytes of one cut-gradient download (same shape as smashed)
    pub cutgrad_bytes: u64,
    /// client+aux parameter bytes (one direction of a model sync)
    pub local_param_bytes: u64,
    /// client-only parameter bytes (SFLV1/V2 sync without aux)
    pub client_param_bytes: u64,
    /// FLOPs of one client local update step (per batch)
    pub flops_per_step: u64,
    /// client peak memory bytes during a local update (per batch)
    pub peak_mem_bytes: u64,
    pub algorithm: Algorithm,
    /// ZO probes per step (n_p) the book was built for
    pub n_pert: u64,
    /// wire mode the sync formula models (`theta` unless rebound via
    /// [`Self::with_zo_wire`])
    pub zo_wire: ZoWireMode,
    /// local steps per round (h) — sizes the seeds-mode upload record
    pub local_steps: u64,
    /// participants per round — sizes the `seed_agg` SeedSync downlink,
    /// which ships every cohort member's record to every client
    pub cohort: u64,
    /// smashed payload codec the byte formulas model (`f32` unless
    /// rebound via [`Self::with_codec`])
    pub codec: Codec,
    /// cut-gradient payload codec (`f32` unless rebound)
    pub grad_codec: GradCodec,
}

impl CostBook {
    pub fn new(v: &VariantSpec, alg: Algorithm, n_pert: u64) -> Self {
        let c = &v.cost;
        let b = v.batch as u64;
        let smashed_bytes = v.smashed_elems_per_batch() as u64 * BYTES_F32;
        let f_c = c.flops_fwd_client as u64 * b;
        let f_a = c.flops_fwd_aux as u64 * b;
        // trainable params + gradient + optimizer state (Adam: m+v)
        let opt_mult = if v.opt_state > 0 { 2 } else { 0 };
        let params_fo = |p: u64| (2 + opt_mult) * p * BYTES_F32; // θ + grad + opt
        let params_zo = |p: u64| (1 + opt_mult) * p * BYTES_F32; // θ + opt (no grad vector)

        let p_c = c.params_client as u64;
        let p_a = c.params_aux as u64;
        let (flops_per_step, peak_mem_bytes) = match alg {
            // traditional SFL: fwd to cut + bwd from relayed cut gradient
            Algorithm::SflV1 | Algorithm::SflV2 => (
                3 * f_c,
                params_fo(p_c) + b * c.act_cache_client as u64,
            ),
            // decoupled FO: full local fwd+bwd through client+aux
            Algorithm::CseFsl | Algorithm::FslSage => (
                3 * (f_c + f_a),
                params_fo(p_c + p_a)
                    + b * (c.act_cache_client + c.act_cache_aux) as u64,
            ),
            // HERON: (n_p + 1) forward-only evaluations, no activation cache
            Algorithm::Heron => (
                (n_pert + 1) * (f_c + f_a),
                params_zo(p_c + p_a)
                    + b * c.act_peak_client.max(c.act_peak_aux) as u64,
            ),
        };

        CostBook {
            smashed_bytes,
            cutgrad_bytes: smashed_bytes,
            local_param_bytes: (p_c + p_a) * BYTES_F32,
            client_param_bytes: p_c * BYTES_F32,
            flops_per_step,
            peak_mem_bytes,
            algorithm: alg,
            n_pert,
            zo_wire: ZoWireMode::Theta,
            local_steps: 0,
            cohort: 1,
            codec: Codec::F32,
            grad_codec: GradCodec::F32,
        }
    }

    /// Rebind the book to a `--zo_wire` mode. `Seeds` swaps the HERON
    /// upload leg of the round sync for the per-step
    /// seed + per-probe-scalar record the server replays — the lean
    /// numbers Table I's `2(|θc|+|θa|)` sync collapses to. `SeedAgg`
    /// additionally swaps the steady-state downlink for the `SeedSync`
    /// broadcast, whose size scales with the round `cohort` (the
    /// participants whose records it carries), not |θ_l|.
    pub fn with_zo_wire(
        mut self,
        mode: ZoWireMode,
        local_steps: u64,
        cohort: u64,
    ) -> Self {
        self.zo_wire = mode;
        self.local_steps = local_steps;
        self.cohort = cohort.max(1);
        self
    }

    /// Rebind the book to the run's payload codecs (`--codec` /
    /// `--grad_codec`). A lossy smashed codec shrinks `smashed_bytes` to
    /// its information bytes (`net::codec::info_bytes`) and a top-k
    /// gradient codec shrinks `cutgrad_bytes` likewise; codec *headers*
    /// are per-message overhead, accounted next to the frame envelope in
    /// the measured-vs-analytic loopback cross-check
    /// (`rust/tests/net_loopback.rs`). The default f32 pair leaves every
    /// formula untouched, which is what pins pre-v6 byte accounting.
    pub fn with_codec(mut self, codec: Codec, grad_codec: GradCodec) -> Self {
        let n = self.smashed_bytes / BYTES_F32; // elements per payload
        self.codec = codec;
        self.grad_codec = grad_codec;
        if codec != Codec::F32 {
            self.smashed_bytes = codec::info_bytes(codec, n);
        }
        if grad_codec != GradCodec::F32 {
            self.cutgrad_bytes = codec::info_bytes_grad(grad_codec, n);
        }
        self
    }

    /// Bytes of the seeds-mode upload record for one round: per local
    /// step, one i32 seed plus n_p f32 gradient scalars (paper Remark 4).
    pub fn zo_record_bytes(&self) -> u64 {
        self.local_steps * (BYTES_F32 + self.n_pert.max(1) * BYTES_F32)
    }

    /// Communication bytes for one client local step (paper Table I row,
    /// per-batch part): traditional SFL pays the two-way smashed exchange,
    /// decoupled methods pay the upload only (and only on upload steps).
    pub fn comm_per_step(&self, uploads_this_step: bool) -> u64 {
        match self.algorithm {
            Algorithm::SflV1 | Algorithm::SflV2 => {
                self.smashed_bytes + self.cutgrad_bytes
            }
            _ if uploads_this_step => self.smashed_bytes,
            _ => 0,
        }
    }

    /// Bytes of one participant's entry in the wire-v7 `SeedSync`
    /// broadcast: its u32 client id, its f64 FedAvg weight, and its
    /// per-step (seed, n_p scalars) replay record.
    pub fn seed_sync_entry_bytes(&self) -> u64 {
        BYTES_U32 + BYTES_F64 + self.zo_record_bytes()
    }

    /// Analytic *downlink* bytes of the round sync for one client at a
    /// given round. Round 0 (and every restore/rejoin bootstrap) ships
    /// the dense θ_l in every mode; past that, `seed_agg` replaces the
    /// broadcast with the whole cohort's SeedSync entries —
    /// O(cohort·h·n_p), independent of |θ_l|.
    pub fn downlink_per_round_sync(&self, round: u64) -> u64 {
        match self.algorithm {
            Algorithm::SflV1 | Algorithm::SflV2 => self.client_param_bytes,
            Algorithm::Heron
                if self.zo_wire == ZoWireMode::SeedAgg && round > 0 =>
            {
                self.cohort.max(1) * self.seed_sync_entry_bytes()
            }
            _ => self.local_param_bytes,
        }
    }

    /// Analytic *uplink* bytes of the round sync for one client (the
    /// lean wire modes upload the replay record instead of θ_l).
    pub fn uplink_per_round_sync(&self) -> u64 {
        match self.algorithm {
            Algorithm::SflV1 | Algorithm::SflV2 => self.client_param_bytes,
            Algorithm::Heron if self.zo_wire.lean_uplink() => {
                self.zo_record_bytes()
            }
            _ => self.local_param_bytes,
        }
    }

    /// Per-round model synchronization bytes (download + upload) at a
    /// given round index — only `seed_agg` distinguishes the round-0
    /// dense bootstrap from the steady state.
    pub fn comm_per_round_sync_at(&self, round: u64) -> u64 {
        self.downlink_per_round_sync(round) + self.uplink_per_round_sync()
    }

    /// Per-round model synchronization bytes (download init + upload
    /// update), steady state. In the HERON `seeds` wire mode the upload
    /// leg is the replay record instead of θ_l — the measured wire bytes
    /// then drop below the analytic theta-mode sync, which is the
    /// paper's title claim end to end. `seed_agg` makes the downlink
    /// lean too (HO-SFL's dimension-free aggregation).
    pub fn comm_per_round_sync(&self) -> u64 {
        self.comm_per_round_sync_at(1)
    }

    /// Extra per-alignment communication for FSL-SAGE (cut-gradient
    /// download used by aux_align).
    pub fn comm_per_alignment(&self) -> u64 {
        match self.algorithm {
            Algorithm::FslSage => self.cutgrad_bytes,
            _ => 0,
        }
    }
}

/// The symbolic Table I (paper §V-B) rendered with the variant's actual
/// sizes — regenerated by benches/table1_costs.rs.
pub fn table1_row(v: &VariantSpec, alg: Algorithm, n_pert: u64) -> Vec<String> {
    let book = CostBook::new(v, alg, n_pert);
    let comm = match alg {
        Algorithm::SflV1 | Algorithm::SflV2 => format!(
            "2pq + 2|θc| = {}",
            fmt_bytes(2 * book.smashed_bytes + book.comm_per_round_sync())
        ),
        _ => format!(
            "pq + 2(|θc|+|θa|) = {}",
            fmt_bytes(book.smashed_bytes + book.comm_per_round_sync())
        ),
    };
    let mem = match alg {
        Algorithm::Heron => format!("O(1): {}", fmt_bytes(book.peak_mem_bytes)),
        Algorithm::SflV1 | Algorithm::SflV2 => {
            format!("O(|θc|): {}", fmt_bytes(book.peak_mem_bytes))
        }
        _ => format!("O(|θc|+|θa|): {}", fmt_bytes(book.peak_mem_bytes)),
    };
    let flops = match alg {
        Algorithm::SflV1 | Algorithm::SflV2 => "3·Fc",
        Algorithm::CseFsl | Algorithm::FslSage => "3·(Fc+Fa)",
        Algorithm::Heron => "np·(Fc+Fa)",
    };
    vec![
        alg.name().to_string(),
        comm,
        mem,
        format!("{} = {:.2} GFLOPs", flops, book.flops_per_step as f64 / 1e9),
    ]
}

pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if bf < 1e3 {
        format!("{b} B")
    } else if bf < 1e6 {
        format!("{:.1} KB", bf / 1e3)
    } else if bf < 1e9 {
        format!("{:.2} MB", bf / 1e6)
    } else {
        format!("{:.2} GB", bf / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::CostModel;

    fn fake_variant() -> VariantSpec {
        VariantSpec {
            name: "t".into(),
            family: "cnn".into(),
            task: "vision".into(),
            optimizer: "adam".into(),
            opt_state: 3,
            batch: 32,
            eval_batch: 64,
            x_shape: vec![16, 16, 3],
            y_shape: vec![],
            smashed_shape: vec![16, 16, 16],
            size_client: 5000,
            size_aux: 200,
            size_server: 70000,
            size_base: 0,
            cost: CostModel {
                params_client: 5000,
                params_aux: 200,
                params_server: 70000,
                act_cache_client: 50_000,
                act_cache_aux: 100,
                act_cache_server: 80_000,
                act_peak_client: 4_000,
                act_peak_aux: 16_000,
                act_peak_server: 8_000,
                flops_fwd_client: 25_000_000,
                flops_fwd_aux: 1_000,
                flops_fwd_server: 30_000_000,
                smashed_elems: 4096,
                target_elems: 1,
            },
            entries: Default::default(),
            files: Default::default(),
            golden: Default::default(),
            dir: Default::default(),
        }
    }

    #[test]
    fn heron_flops_two_thirds_of_fo() {
        // paper Table II: HERON 2(Fc+Fa) vs CSE 3(Fc+Fa) => ratio 2/3
        let v = fake_variant();
        let fo = CostBook::new(&v, Algorithm::CseFsl, 1);
        let zo = CostBook::new(&v, Algorithm::Heron, 1); // n_p=1 => 2 fwd
        let ratio = zo.flops_per_step as f64 / fo.flops_per_step as f64;
        assert!((ratio - 2.0 / 3.0).abs() < 1e-3, "ratio {ratio}");
    }

    #[test]
    fn heron_peak_memory_is_activation_free() {
        let v = fake_variant();
        let fo = CostBook::new(&v, Algorithm::CseFsl, 1);
        let zo = CostBook::new(&v, Algorithm::Heron, 1);
        // FO is dominated by the activation cache; ZO must not include it
        assert!(zo.peak_mem_bytes * 2 < fo.peak_mem_bytes);
    }

    #[test]
    fn traditional_sfl_pays_two_way_per_step() {
        let v = fake_variant();
        let sfl = CostBook::new(&v, Algorithm::SflV2, 1);
        let heron = CostBook::new(&v, Algorithm::Heron, 1);
        assert_eq!(sfl.comm_per_step(false), 2 * sfl.smashed_bytes);
        assert_eq!(heron.comm_per_step(true), heron.smashed_bytes);
        assert_eq!(heron.comm_per_step(false), 0);
    }

    #[test]
    fn sage_alignment_costs_one_cutgrad() {
        let v = fake_variant();
        let sage = CostBook::new(&v, Algorithm::FslSage, 1);
        assert_eq!(sage.comm_per_alignment(), sage.cutgrad_bytes);
        assert_eq!(
            CostBook::new(&v, Algorithm::Heron, 1).comm_per_alignment(),
            0
        );
    }

    #[test]
    fn sync_bytes_scale_with_param_counts() {
        let v = fake_variant();
        let sfl = CostBook::new(&v, Algorithm::SflV2, 1);
        let cse = CostBook::new(&v, Algorithm::CseFsl, 1);
        assert_eq!(sfl.comm_per_round_sync(), 2 * 5000 * 4);
        assert_eq!(cse.comm_per_round_sync(), 2 * 5200 * 4);
    }

    #[test]
    fn seeds_wire_mode_is_lean_and_exact() {
        let v = fake_variant();
        let h = 4u64;
        let np = 2u64;
        let theta = CostBook::new(&v, Algorithm::Heron, np);
        let seeds = CostBook::new(&v, Algorithm::Heron, np)
            .with_zo_wire(ZoWireMode::Seeds, h, 5);
        // exact lean formula: θ_l down + h·(seed + n_p scalars) up
        assert_eq!(seeds.zo_record_bytes(), h * (4 + np * 4));
        assert_eq!(
            seeds.comm_per_round_sync(),
            seeds.local_param_bytes + h * (4 + np * 4)
        );
        // strictly below the theta-mode 2(|θc|+|θa|) sync — and the
        // upload leg alone beats a full θ_l upload
        assert!(seeds.comm_per_round_sync() < theta.comm_per_round_sync());
        assert!(seeds.zo_record_bytes() < seeds.local_param_bytes);
        // other algorithms ignore the binding (no replay to speak of)
        let cse = CostBook::new(&v, Algorithm::CseFsl, 1)
            .with_zo_wire(ZoWireMode::Seeds, h, 5);
        assert_eq!(
            cse.comm_per_round_sync(),
            CostBook::new(&v, Algorithm::CseFsl, 1).comm_per_round_sync()
        );
    }

    #[test]
    fn seed_agg_downlink_is_dimension_free_and_round_indexed() {
        let v = fake_variant();
        let (h, np, cohort) = (4u64, 2u64, 5u64);
        let seeds = CostBook::new(&v, Algorithm::Heron, np)
            .with_zo_wire(ZoWireMode::Seeds, h, cohort);
        let agg = CostBook::new(&v, Algorithm::Heron, np)
            .with_zo_wire(ZoWireMode::SeedAgg, h, cohort);
        // one SeedSync entry: u32 id + f64 weight + h·(seed + n_p scalars)
        assert_eq!(agg.seed_sync_entry_bytes(), 4 + 8 + h * (4 + np * 4));
        // round 0 bootstraps dense in every mode
        assert_eq!(agg.downlink_per_round_sync(0), agg.local_param_bytes);
        assert_eq!(agg.comm_per_round_sync_at(0), seeds.comm_per_round_sync());
        // steady state: the whole cohort's entries, independent of |θ_l|
        assert_eq!(
            agg.downlink_per_round_sync(1),
            cohort * (12 + h * (4 + np * 4))
        );
        assert_eq!(
            agg.comm_per_round_sync(),
            cohort * (12 + h * (4 + np * 4)) + h * (4 + np * 4)
        );
        // strictly below both the dense broadcast and the seeds-mode
        // downlink (which is the same dense broadcast)
        assert!(
            agg.downlink_per_round_sync(1) < seeds.downlink_per_round_sync(1)
        );
        assert!(agg.comm_per_round_sync() < seeds.comm_per_round_sync());
        // uplink stays the lean record in both modes
        assert_eq!(agg.uplink_per_round_sync(), seeds.uplink_per_round_sync());
        assert_eq!(agg.uplink_per_round_sync(), agg.zo_record_bytes());
        // split sums to the combined figure at every round index
        for r in 0..3 {
            assert_eq!(
                agg.comm_per_round_sync_at(r),
                agg.downlink_per_round_sync(r) + agg.uplink_per_round_sync()
            );
        }
    }

    #[test]
    fn more_probes_cost_more_flops() {
        let v = fake_variant();
        let z1 = CostBook::new(&v, Algorithm::Heron, 1);
        let z4 = CostBook::new(&v, Algorithm::Heron, 4);
        assert!(z4.flops_per_step > z1.flops_per_step * 2);
    }

    #[test]
    fn codec_binding_shrinks_payload_formulas() {
        let v = fake_variant();
        let base = CostBook::new(&v, Algorithm::SflV2, 1);
        let n = base.smashed_bytes / BYTES_F32; // elements per payload

        // f32 is the identity: every formula is untouched
        let f32b = CostBook::new(&v, Algorithm::SflV2, 1)
            .with_codec(Codec::F32, GradCodec::F32);
        assert_eq!(f32b.smashed_bytes, base.smashed_bytes);
        assert_eq!(f32b.cutgrad_bytes, base.cutgrad_bytes);

        // int8: one byte per element; int4: two elements per byte
        let i8b = CostBook::new(&v, Algorithm::SflV2, 1)
            .with_codec(Codec::Int8, GradCodec::F32);
        assert_eq!(i8b.smashed_bytes, n);
        assert_eq!(i8b.cutgrad_bytes, base.cutgrad_bytes);
        let i4b = CostBook::new(&v, Algorithm::SflV2, 1)
            .with_codec(Codec::Int4, GradCodec::F32);
        assert_eq!(i4b.smashed_bytes, n.div_ceil(2));

        // topk gradient: 8 bytes per surviving (index, value) pair,
        // sized from the *uncompressed* element count even when the
        // smashed leg is also quantized
        let tk = CostBook::new(&v, Algorithm::SflV2, 1)
            .with_codec(Codec::Int8, GradCodec::TopK(0.25));
        let k = codec::topk_k(n as usize, 0.25) as u64;
        assert_eq!(tk.smashed_bytes, n);
        assert_eq!(tk.cutgrad_bytes, 8 * k);
        assert!(tk.cutgrad_bytes < base.cutgrad_bytes);

        // per-step comm folds the compressed legs in directly
        assert_eq!(tk.comm_per_step(false), tk.smashed_bytes + tk.cutgrad_bytes);
    }
}
