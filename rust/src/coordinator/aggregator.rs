//! Fed-Server aggregation (substrate S12): weighted FedAvg over flat
//! parameter vectors, paper Eq. (8).

/// Weighted average of client parameter vectors into `out`.
///
/// Weights are normalized internally; equal weights reproduce plain FedAvg.
/// Preallocated `out` keeps the round loop allocation-free.
pub fn fedavg_into(clients: &[&[f32]], weights: &[f64], out: &mut [f32]) {
    assert!(!clients.is_empty(), "no clients to aggregate");
    assert_eq!(clients.len(), weights.len());
    let dim = out.len();
    for c in clients {
        assert_eq!(c.len(), dim, "parameter dimension mismatch");
    }
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "non-positive total weight");

    out.fill(0.0);
    for (c, &w) in clients.iter().zip(weights) {
        let wf = (w / total) as f32;
        for (o, &x) in out.iter_mut().zip(c.iter()) {
            *o += wf * x;
        }
    }
}

pub fn fedavg(clients: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    let mut out = vec![0.0; clients[0].len()];
    fedavg_into(clients, weights, &mut out);
    out
}

/// Aggregate optimizer moment vectors the same way (used for the SFLV1
/// per-client server copies where the optimizer state is averaged along
/// with the parameters).
pub fn fedavg_state(states: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    fedavg(states, weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, assert_prop};

    #[test]
    fn identical_inputs_are_fixed_point() {
        // up to f32 rounding of the normalized weights (1/3 is inexact)
        let a = vec![1.0f32, -2.0, 3.5];
        let out = fedavg(&[&a, &a, &a], &[1.0, 1.0, 1.0]);
        for (o, x) in out.iter().zip(&a) {
            assert!((o - x).abs() < 1e-6, "{o} vs {x}");
        }
    }

    #[test]
    fn weighted_mean_exact() {
        let a = vec![0.0f32, 0.0];
        let b = vec![4.0f32, 8.0];
        let out = fedavg(&[&a, &b], &[3.0, 1.0]);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dimension_mismatch_panics() {
        let a = vec![1.0f32; 3];
        let b = vec![1.0f32; 4];
        fedavg(&[&a, &b], &[1.0, 1.0]);
    }

    #[test]
    fn property_mean_within_bounds_and_linear() {
        prop::check(100, |g| {
            let dim = g.usize_in(1..50);
            let n = g.usize_in(1..6);
            let clients: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| g.f32_in(-5.0..5.0)).collect())
                .collect();
            let weights: Vec<f64> =
                (0..n).map(|_| g.f64_in(0.1..3.0)).collect();
            let refs: Vec<&[f32]> =
                clients.iter().map(|c| c.as_slice()).collect();
            let out = fedavg(&refs, &weights);

            // mean of values is within [min, max] coordinatewise
            for j in 0..dim {
                let mn = clients
                    .iter()
                    .map(|c| c[j])
                    .fold(f32::INFINITY, f32::min);
                let mx = clients
                    .iter()
                    .map(|c| c[j])
                    .fold(f32::NEG_INFINITY, f32::max);
                assert_prop!(
                    out[j] >= mn - 1e-4 && out[j] <= mx + 1e-4,
                    "coordinate {j}: {} outside [{mn}, {mx}]",
                    out[j]
                );
            }

            // scaling all weights by a constant changes nothing
            let w2: Vec<f64> = weights.iter().map(|w| w * 7.0).collect();
            let out2 = fedavg(&refs, &w2);
            for (a, b) in out.iter().zip(&out2) {
                assert_prop!((a - b).abs() < 1e-5, "weight-scale invariance");
            }
            Ok(())
        });
    }
}
