//! Tiny stderr logger wired into the `log` facade.
//!
//! `RUST_LOG`-style filtering by level only (`error|warn|info|debug|trace`,
//! default `info`).

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        eprintln!("[{t:8.2}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops.
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("RUST_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let logger = Box::new(StderrLogger {
            start: Instant::now(),
        });
        let _ = log::set_boxed_logger(logger);
        log::set_max_level(level);
    });
}
