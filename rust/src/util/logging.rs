//! Tiny stderr logger wired into the `log` facade.
//!
//! `RUST_LOG`-style filtering by level only
//! (`off|error|warn|info|debug|trace`, default `info`). An unrecognized
//! value warns once on stderr (naming the bad value) and falls back to
//! `info` — `RUST_LOG=inf` silently meaning "info" hid typos for five
//! PRs.
//!
//! Timestamps come from the telemetry clock ([`crate::telemetry::epoch`]),
//! so a `[   3.21s I]` log line and a `ts=3210000` span in a
//! `--trace_out` file refer to the same instant.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = crate::telemetry::epoch().elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        eprintln!("[{t:8.2}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Parse a `RUST_LOG` level value. `Err` carries the unrecognized input
/// back for the one-time warning.
pub fn parse_level(s: &str) -> Result<LevelFilter, String> {
    match s.to_ascii_lowercase().as_str() {
        "off" | "none" => Ok(LevelFilter::Off),
        "error" => Ok(LevelFilter::Error),
        "warn" | "warning" => Ok(LevelFilter::Warn),
        "info" => Ok(LevelFilter::Info),
        "debug" => Ok(LevelFilter::Debug),
        "trace" => Ok(LevelFilter::Trace),
        other => Err(other.to_string()),
    }
}

/// Install the logger once; later calls are no-ops.
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        // pin the shared epoch before the first log line or span
        let _ = crate::telemetry::epoch();
        let level = match std::env::var("RUST_LOG") {
            Ok(val) => match parse_level(&val) {
                Ok(l) => l,
                Err(bad) => {
                    eprintln!(
                        "warning: unrecognized RUST_LOG value {bad:?} — \
                         expected off|error|warn|info|debug|trace; \
                         defaulting to info"
                    );
                    LevelFilter::Info
                }
            },
            Err(_) => LevelFilter::Info,
        };
        let _ = log::set_boxed_logger(Box::new(StderrLogger));
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recognized_levels_parse() {
        assert_eq!(parse_level("off"), Ok(LevelFilter::Off));
        assert_eq!(parse_level("ERROR"), Ok(LevelFilter::Error));
        assert_eq!(parse_level("warn"), Ok(LevelFilter::Warn));
        assert_eq!(parse_level("info"), Ok(LevelFilter::Info));
        assert_eq!(parse_level("debug"), Ok(LevelFilter::Debug));
        assert_eq!(parse_level("trace"), Ok(LevelFilter::Trace));
    }

    #[test]
    fn bad_level_names_the_value() {
        assert_eq!(parse_level("inf"), Err("inf".to_string()));
        assert_eq!(parse_level(""), Err("".to_string()));
    }
}
