//! Hand-rolled CLI argument parser (substrate S5; clap is not vendored).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args,
//! and collects unknown `--key value` pairs as config overrides so every
//! binary accepts dotted-path settings (e.g. `--run.rounds 50`).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value-ful unless next token is another flag / absent
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags
                                .insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["run", "--rounds", "10", "--algo=heron", "--quiet"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get_usize("rounds", 0), 10);
        assert_eq!(a.get("algo"), Some("heron"));
        assert!(a.get_bool("quiet", false));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.get_bool("verbose", false));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
        assert!(!a.has("x"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--offset", "-3.5"]);
        assert_eq!(a.get_f64("offset", 0.0), -3.5);
    }
}
