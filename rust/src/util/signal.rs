//! Minimal async-signal-safe shutdown flag (no `libc` crate in the
//! offline vendor set — the one FFI symbol is declared by hand).
//!
//! `serve` installs handlers for SIGINT/SIGTERM; the handler does the
//! only thing that is async-signal-safe here — store into a static
//! atomic — and the dispatcher polls [`requested`] between wire events.
//! On a set flag it writes a final checkpoint and broadcasts `Shutdown`
//! to every client, so `^C` on the server is a *clean* protocol exit,
//! not a dropped connection (clients exit 0 on a clean `Shutdown`).
//!
//! [`request`] sets the same flag from safe code — the in-process tests
//! and the driver's test hooks trigger the graceful-shutdown path
//! without delivering a real signal.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" {
    /// POSIX `signal(2)`. The disposition argument/return is the
    /// handler's address as a machine word (`SIG_DFL` = 0, `SIG_ERR` =
    /// usize::MAX) — exactly how the C prototype lays it out.
    fn signal(signum: i32, handler: usize) -> usize;
}

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    // the only async-signal-safe action we need: flip the flag
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM → [`requested`] handlers. Idempotent; a
/// no-op on non-unix targets (the flag still works via [`request`]).
pub fn install() {
    #[cfg(unix)]
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

/// Has a shutdown been requested (signal delivered or [`request`]ed)?
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Request a shutdown from safe code (tests, in-process drivers).
pub fn request() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Clear the flag (start of a fresh serve; tests).
pub fn reset() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_sets_and_reset_clears() {
        reset();
        assert!(!requested());
        request();
        assert!(requested());
        reset();
        assert!(!requested());
    }

    #[cfg(unix)]
    #[test]
    fn installed_handler_catches_a_real_signal() {
        reset();
        install();
        // raise(3) == kill(getpid(), sig); declare kill by hand too
        extern "C" {
            fn kill(pid: i32, sig: i32) -> i32;
            fn getpid() -> i32;
        }
        unsafe {
            kill(getpid(), SIGTERM);
        }
        // delivery is synchronous for a self-directed signal on the
        // calling thread, but spin briefly to be safe
        for _ in 0..1000 {
            if requested() {
                break;
            }
            std::thread::yield_now();
        }
        assert!(requested());
        reset();
    }
}
