//! Tiny scoped worker pool (substrate S4b) for the parallel round engine.
//!
//! `run_jobs` fans a batch of independent jobs out across up to `workers`
//! OS threads (std scoped threads — no external crates) and returns the
//! results **in job order**, regardless of which worker ran what. Workers
//! pull jobs from a shared stack, so scheduling is dynamic (LPT-ish under
//! skewed job costs) while the output stays deterministic: result `i` is
//! always job `i`'s output.
//!
//! With `workers <= 1` (or a single job) everything runs inline on the
//! caller's thread — bit-identical results, no spawn overhead — which is
//! what makes `--workers 1` vs `--workers N` comparisons meaningful.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Execute `jobs` with up to `workers` threads; returns results in job
/// order. `f` must be callable from multiple threads at once.
pub fn run_jobs<J, R, F>(workers: usize, jobs: Vec<J>, f: F) -> Vec<R>
where
    J: Send,
    R: Send,
    F: Fn(J) -> R + Sync,
{
    let n = jobs.len();
    if workers <= 1 || n <= 1 {
        return jobs.into_iter().map(f).collect();
    }
    let queue: Mutex<VecDeque<(usize, J)>> =
        Mutex::new(jobs.into_iter().enumerate().collect());
    let results: Mutex<Vec<Option<R>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let threads = workers.min(n);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap_or_else(|p| p.into_inner()).pop_front();
                match job {
                    Some((idx, j)) => {
                        let r = f(j);
                        results.lock().unwrap_or_else(|p| p.into_inner())[idx] =
                            Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap_or_else(|p| p.into_inner())
        .into_iter()
        .map(|r| r.expect("worker pool lost a job result"))
        .collect()
}

/// The effective worker count for a requested setting: `0` means "auto"
/// (all available cores), and the result is clamped to the job count.
pub fn effective_workers(requested: usize, jobs: usize) -> usize {
    let w = if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    };
    w.clamp(1, jobs.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<usize> = (0..64).collect();
        let out = run_jobs(8, jobs, |j| j * 10);
        assert_eq!(out, (0..64).map(|j| j * 10).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_matches_parallel() {
        let jobs: Vec<u64> = (0..40).collect();
        let seq = run_jobs(1, jobs.clone(), |j| j.wrapping_mul(0x9E37).rotate_left(7));
        let par = run_jobs(8, jobs, |j| j.wrapping_mul(0x9E37).rotate_left(7));
        assert_eq!(seq, par);
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let count = AtomicUsize::new(0);
        let out = run_jobs(4, (0..100).collect::<Vec<_>>(), |j: usize| {
            count.fetch_add(1, Ordering::SeqCst);
            j
        });
        assert_eq!(out.len(), 100);
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_jobs(16, vec![1, 2], |j| j + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = run_jobs(4, Vec::<i32>::new(), |j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn effective_workers_rules() {
        assert_eq!(effective_workers(3, 10), 3);
        assert_eq!(effective_workers(8, 2), 2);
        assert!(effective_workers(0, 64) >= 1);
        assert_eq!(effective_workers(5, 0), 1);
    }
}
