//! Mini property-testing framework (substrate S19; proptest is not vendored).
//!
//! Deterministic: cases derive from a fixed seed so failures reproduce.
//! On failure, a simple halving shrinker minimizes the failing input where
//! the generator supports it.
//!
//! ```ignore
//! prop::check(100, |g| {
//!     let xs = g.vec_f32(0..1000, -1.0..1.0);
//!     let sum: f32 = xs.iter().sum();
//!     prop::assert_prop!(sum.is_finite(), "sum finite for {} elems", xs.len());
//!     Ok(())
//! });
//! ```

use crate::util::rng::Xoshiro256pp;
use std::ops::Range;

pub struct Gen {
    rng: Xoshiro256pp,
    pub case: usize,
}

impl Gen {
    pub fn new(seed: u64, case: usize) -> Self {
        Self {
            rng: Xoshiro256pp::new(seed.wrapping_add(case as u64 * 0x9E37)),
            case,
        }
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        r.start + self.rng.next_f64() * (r.end - r.start)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.f64_in(r.start as f64..r.end as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    pub fn vec_usize(
        &mut self,
        len: Range<usize>,
        vals: Range<usize>,
    ) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(vals.clone())).collect()
    }

    pub fn gaussian(&mut self) -> f64 {
        self.rng.gaussian()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }
}

/// Run `cases` deterministic property cases; panics with the failing case id
/// on the first violated property.
pub fn check<F>(cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(0xC0FFEE, cases, &mut prop)
}

pub fn check_seeded<F>(seed: u64, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with prop::check_seeded({seed:#x}, {}, ..)",
                case + 1
            );
        }
    }
}

#[macro_export]
macro_rules! assert_prop {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}
pub use crate::assert_prop;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut a = Gen::new(1, 3);
        let mut b = Gen::new(1, 3);
        assert_eq!(a.u64(), b.u64());
        assert_eq!(a.vec_f32(1..10, 0.0..1.0), b.vec_f32(1..10, 0.0..1.0));
    }

    #[test]
    fn check_passes_trivial_property() {
        check(50, |g| {
            let n = g.usize_in(0..100);
            assert_prop!(n < 100, "n out of range: {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(50, |g| {
            let n = g.usize_in(0..100);
            assert_prop!(n < 5, "n too big: {n}");
            Ok(())
        });
    }

    #[test]
    fn ranges_respected() {
        check(200, |g| {
            let x = g.f32_in(-2.0..3.0);
            assert_prop!((-2.0..3.0).contains(&x), "x out of range {x}");
            let v = g.vec_usize(0..5, 10..20);
            assert_prop!(v.len() < 5, "len {}", v.len());
            assert_prop!(
                v.iter().all(|&e| (10..20).contains(&e)),
                "elem out of range"
            );
            Ok(())
        });
    }
}
