//! Minimal JSON parser/serializer (substrate S3).
//!
//! serde/serde_json are not in the offline vendor set, so the manifest,
//! configs, goldens, and metric dumps go through this module. It implements
//! the full JSON grammar (RFC 8259) minus `\u` surrogate pairs outside the
//! BMP, which none of our producers emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ----- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `v.at(&["variants", "cnn_c1", "batch"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Option<Vec<_>>>()
    }

    // ----- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }

    // ----- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Value::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 1 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Value::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 1 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{}", n);
        }
    } else {
        // JSON has no Inf/NaN; emit null (matches python json.dumps default
        // failure mode being avoided upstream).
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= 1;
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex =
                            std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        out.push(
                            char::from_u32(cp).unwrap_or('\u{fffd}'),
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf8: collect the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1, 2.5, "s", true, null], "y": {"z": -3}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = parse("\"caf\\u00e9 \u{1F600}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café \u{1F600}");
    }

    #[test]
    fn integers_stay_integral_in_output() {
        let v = Value::Num(42.0);
        assert_eq!(v.to_string(), "42");
        let v = Value::Num(0.5);
        assert_eq!(v.to_string(), "0.5");
    }
}
