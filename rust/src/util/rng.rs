//! Deterministic PRNG suite (substrate S4).
//!
//! `mix64` is the cross-language stream shared bit-for-bit with
//! `python/compile/synth.py` (splitmix64 finalizer); everything the data
//! generators and partitioner draw comes from it, so Python goldens pin the
//! Rust streams. `Xoshiro256pp` is a conventional sequential RNG for places
//! where a stream-position API is awkward (shuffles, participation
//! sampling).

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// splitmix64 finalizer of (seed, stream position k). Must stay identical to
/// `synth.mix64` in python.
#[inline]
pub fn mix64(seed: u64, k: u64) -> u64 {
    let mut z = seed.wrapping_add(k.wrapping_add(1).wrapping_mul(GOLDEN));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in [0, 1) from the top 53 bits (matches `synth.u01`).
#[inline]
pub fn u01(seed: u64, k: u64) -> f64 {
    (mix64(seed, k) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Standard xoshiro256++ for sequential draws.
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        // seed the state via splitmix64 as recommended by the authors
        let mut st = [0u64; 4];
        for (i, slot) in st.iter_mut().enumerate() {
            *slot = mix64(seed, i as u64);
        }
        Self { s: st }
    }

    /// Export the raw 256-bit state (checkpoint/restore: a restored RNG
    /// continues the exact sequence the saved one would have produced).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an RNG from a [`Self::state`] export.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free enough for
    /// our n << 2^32 use cases).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Dirichlet(alpha * 1_k) draw via Gamma(alpha) marginals
    /// (Marsaglia-Tsang for alpha >= 1, boost trick below 1).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = self.next_f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_known_values_differ() {
        assert_ne!(mix64(42, 0), mix64(42, 1));
        assert_ne!(mix64(42, 0), mix64(43, 0));
        assert_eq!(mix64(42, 0), mix64(42, 0));
    }

    #[test]
    fn u01_in_range_and_uniform() {
        let vals: Vec<f64> = (0..10_000).map(|k| u01(3, k)).collect();
        assert!(vals.iter().all(|v| (0.0..1.0).contains(v)));
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256pp::new(7);
        let mut b = Xoshiro256pp::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_the_sequence() {
        let mut a = Xoshiro256pp::new(7);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Xoshiro256pp::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::new(1);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Xoshiro256pp::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_more_than_population_caps() {
        let mut r = Xoshiro256pp::new(9);
        assert_eq!(r.sample_indices(5, 10).len(), 5);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Xoshiro256pp::new(11);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_effect() {
        // small alpha => spiky, large alpha => flat
        let mut r = Xoshiro256pp::new(13);
        let max_small: f64 = (0..50)
            .map(|_| {
                r.dirichlet(0.1, 10)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 50.0;
        let max_large: f64 = (0..50)
            .map(|_| {
                r.dirichlet(50.0, 10)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 50.0;
        assert!(max_small > 0.5, "spiky {max_small}");
        assert!(max_large < 0.25, "flat {max_large}");
    }
}
