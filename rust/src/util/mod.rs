//! Offline substrates: JSON, PRNGs, CLI parsing, logging, property testing.
//!
//! These exist because the offline vendor set has no serde/clap/proptest —
//! see DESIGN.md §7 ("offline substrate policy").

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod signal;
