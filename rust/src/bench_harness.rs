//! Mini statistical benchmark harness (substrate S18; criterion is not in
//! the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and call into this module.
//! Each measurement runs warmup iterations, then timed batches until the
//! time budget is spent, and reports mean / p50 / p95 / stddev.

use crate::util::json::{self, Value};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Smoke mode (default) keeps cargo bench fast; REPRO_FULL=1 widens
        // budgets for the recorded runs.
        let full = std::env::var("REPRO_FULL").is_ok();
        Self {
            warmup: Duration::from_millis(if full { 500 } else { 100 }),
            budget: Duration::from_millis(if full { 3000 } else { 600 }),
            results: Vec::new(),
        }
    }

    /// Measure `f` (one logical operation per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        // warmup
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.warmup || warm_iters < 3 {
            f();
            warm_iters += 1;
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.budget || samples_ns.len() < 10 {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if samples_ns.len() > 5_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let var = samples_ns
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: n as u64,
            mean_ns: mean,
            p50_ns: samples_ns[n / 2],
            p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
            std_ns: var.sqrt(),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12}  ({} iters)",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p95_ns),
            m.iters
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn header(title: &str) {
        println!("\n=== {title} ===");
        println!(
            "{:<48} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p95"
        );
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// One measurement as a `heron-sfl-bench-v1` `benchmarks[]` entry —
/// the exact shape `perf_hotpath`'s baseline gate reads back.
pub fn measurement_json(m: &Measurement) -> Value {
    Value::obj(vec![
        ("name", Value::str(&m.name)),
        ("iters", Value::Num(m.iters as f64)),
        ("mean_ns", Value::Num(m.mean_ns)),
        ("p50_ns", Value::Num(m.p50_ns)),
        ("p95_ns", Value::Num(m.p95_ns)),
        ("std_ns", Value::Num(m.std_ns)),
    ])
}

/// Merge measurements and extra top-level keys into the bench report at
/// `path` (the `heron-sfl-bench-v1` schema the CI perf artifacts use),
/// creating the file when absent. Entries in `benchmarks` with the same
/// name are replaced and unrelated keys survive untouched, so several
/// bench binaries (perf_hotpath, serve_storm) can share one `BENCH_OUT`
/// artifact regardless of run order.
pub fn merge_report(
    path: &str,
    measurements: &[Measurement],
    extra: &[(&str, Value)],
) -> anyhow::Result<()> {
    let mut root: std::collections::BTreeMap<String, Value> =
        match std::fs::read_to_string(path) {
            Ok(text) => match json::parse(&text)? {
                Value::Obj(m) => m,
                _ => Default::default(),
            },
            Err(_) => Default::default(),
        };
    root.entry("schema".into())
        .or_insert_with(|| Value::str("heron-sfl-bench-v1"));
    let mut benches: Vec<Value> = match root.remove("benchmarks") {
        Some(Value::Arr(a)) => a,
        _ => Vec::new(),
    };
    for m in measurements {
        benches.retain(|e| {
            e.get("name").and_then(Value::as_str) != Some(&m.name)
        });
        benches.push(measurement_json(m));
    }
    root.insert("benchmarks".into(), Value::Arr(benches));
    for (k, v) in extra {
        root.insert((*k).to_string(), v.clone());
    }
    std::fs::write(path, Value::Obj(root).to_string_pretty())?;
    Ok(())
}

/// Simple table printer shared by the paper-table benches.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n--- {title} ---");
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for r in &self.rows {
            line(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(10),
            results: vec![],
        };
        let mut acc = 0u64;
        let m = b
            .run("noop-ish", || {
                acc = acc.wrapping_add(std::hint::black_box(1));
            })
            .clone();
        assert!(m.iters >= 10);
        assert!(m.mean_ns >= 0.0);
        assert!(m.p95_ns >= m.p50_ns * 0.5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn merge_report_replaces_by_name_and_keeps_extras() {
        let path = std::env::temp_dir()
            .join(format!("heron_merge_{}.json", std::process::id()));
        let p = path.to_str().unwrap();
        let _ = std::fs::remove_file(p);
        let m1 = Measurement {
            name: "a".into(),
            iters: 1,
            mean_ns: 10.0,
            p50_ns: 10.0,
            p95_ns: 10.0,
            std_ns: 0.0,
        };
        merge_report(p, &[m1.clone()], &[("extra_key", Value::Num(1.0))])
            .unwrap();
        // second write replaces "a", keeps extra_key, adds "b"
        let m1b = Measurement { mean_ns: 20.0, ..m1.clone() };
        let m2 = Measurement { name: "b".into(), ..m1.clone() };
        merge_report(p, &[m1b, m2], &[("other", Value::str("x"))]).unwrap();
        let v =
            json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        assert_eq!(
            v.get("schema").and_then(Value::as_str),
            Some("heron-sfl-bench-v1")
        );
        let arr = v.get("benchmarks").and_then(Value::as_arr).unwrap();
        assert_eq!(arr.len(), 2);
        let a = arr
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("a"))
            .unwrap();
        assert_eq!(a.get("mean_ns").and_then(Value::as_f64), Some(20.0));
        assert_eq!(v.get("extra_key").and_then(Value::as_f64), Some(1.0));
        assert_eq!(v.get("other").and_then(Value::as_str), Some("x"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn table_shape_enforced() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }
}
