//! Run recording: per-round curves, resource counters, CSV/JSON export
//! (substrate S15).

use crate::util::json::Value;
use std::collections::BTreeMap;
use std::path::Path;

/// One recorded training run: a series of round records plus final
/// aggregates. The benches turn these into the paper's figures.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
    pub summary: BTreeMap<String, f64>,
}

#[derive(Debug, Clone, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    /// vision: accuracy in [0,1]; lm: perplexity
    pub eval_metric: f64,
    pub comm_bytes_cum: u64,
    pub wall_seconds: f64,
}

impl RunRecord {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn set(&mut self, key: &str, v: f64) {
        self.summary.insert(key.to_string(), v);
    }

    pub fn best_metric(&self, higher_is_better: bool) -> Option<f64> {
        let it = self.rounds.iter().map(|r| r.eval_metric);
        if higher_is_better {
            it.fold(None, |a, b| Some(a.map_or(b, |x: f64| x.max(b))))
        } else {
            it.fold(None, |a, b| Some(a.map_or(b, |x: f64| x.min(b))))
        }
    }

    /// Cumulative communication when the metric first reaches `threshold`
    /// (paper Table II's "comm until 80% accuracy" criterion).
    pub fn comm_to_threshold(
        &self,
        threshold: f64,
        higher_is_better: bool,
    ) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| {
                if higher_is_better {
                    r.eval_metric >= threshold
                } else {
                    r.eval_metric <= threshold
                }
            })
            .map(|r| r.comm_bytes_cum)
    }

    pub fn to_json(&self) -> Value {
        let rounds: Vec<Value> = self
            .rounds
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("round", Value::Num(r.round as f64)),
                    ("train_loss", Value::Num(r.train_loss)),
                    ("eval_metric", Value::Num(r.eval_metric)),
                    ("comm_bytes_cum", Value::Num(r.comm_bytes_cum as f64)),
                    ("wall_seconds", Value::Num(r.wall_seconds)),
                ])
            })
            .collect();
        let summary: Vec<(String, Value)> = self
            .summary
            .iter()
            .map(|(k, v)| (k.clone(), Value::Num(*v)))
            .collect();
        Value::obj(vec![
            ("name", Value::str(&self.name)),
            ("rounds", Value::Arr(rounds)),
            (
                "summary",
                Value::Obj(summary.into_iter().collect()),
            ),
        ])
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,train_loss,eval_metric,comm_bytes_cum,wall_seconds\n",
        );
        for r in &self.rounds {
            s.push_str(&format!(
                "{},{},{},{},{}\n",
                r.round,
                r.train_loss,
                r.eval_metric,
                r.comm_bytes_cum,
                r.wall_seconds
            ));
        }
        s
    }

    pub fn save(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let stem = safe_file_stem(&self.name);
        std::fs::write(
            dir.join(format!("{stem}.json")),
            self.to_json().to_string_pretty(),
        )?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())
    }
}

/// Sanitize a run name into a file stem: commas, quotes, path
/// separators, and other shell/CSV-hostile bytes become `_`, so a run
/// named `a,b"c/d` cannot corrupt the CSV next to it or escape the
/// output directory. Empty names fall back to `"run"`.
pub fn safe_file_stem(name: &str) -> String {
    let cleaned: String = name
        .chars()
        .map(|c| match c {
            ',' | '"' | '\'' | '/' | '\\' | ':' | '\n' | '\r' | '\t' => '_',
            c if (c as u32) < 0x20 => '_',
            c => c,
        })
        .collect();
    if cleaned.is_empty() {
        "run".to_string()
    } else {
        cleaned
    }
}

/// Render an ASCII sparkline of a series (used by examples to show curves
/// in the terminal).
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() || width == 0 {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let finite = values.iter().cloned().filter(|v| v.is_finite());
    let lo = finite.clone().fold(f64::INFINITY, f64::min);
    let hi = finite.fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let step = (values.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < values.len() && out.chars().count() < width {
        let v = values[i as usize];
        // NaN (and an all-NaN series, where lo stays +inf) clamps to the
        // low bucket instead of poisoning the index cast
        let scaled = ((v - lo) / span) * 7.0;
        let b = if scaled.is_finite() {
            (scaled.round().max(0.0) as usize).min(7)
        } else {
            0
        };
        out.push(BARS[b]);
        i += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> RunRecord {
        let mut r = RunRecord::new("test");
        for i in 0..5 {
            r.push(RoundRecord {
                round: i,
                train_loss: 2.0 - i as f64 * 0.2,
                eval_metric: 0.1 + i as f64 * 0.2,
                comm_bytes_cum: (i as u64 + 1) * 1000,
                wall_seconds: i as f64,
            });
        }
        r
    }

    #[test]
    fn comm_to_threshold_finds_first_crossing() {
        let r = rec();
        assert_eq!(r.comm_to_threshold(0.5, true), Some(3000));
        assert_eq!(r.comm_to_threshold(0.95, true), None);
    }

    #[test]
    fn comm_to_threshold_lower_is_better() {
        let r = rec();
        // train "perplexity-like": eval metric decreasing? here increasing,
        // so lower-better crossing at the first round
        assert_eq!(r.comm_to_threshold(0.15, false), Some(1000));
    }

    #[test]
    fn best_metric_directions() {
        let r = rec();
        assert!((r.best_metric(true).unwrap() - 0.9).abs() < 1e-9);
        assert!((r.best_metric(false).unwrap() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrips() {
        let r = rec();
        let v = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(
            v.at(&["rounds"]).unwrap().as_arr().unwrap().len(),
            5
        );
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = rec().to_csv();
        assert_eq!(csv.lines().count(), 6);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn sparkline_renders() {
        let s = sparkline(&[0.0, 0.5, 1.0], 3);
        assert_eq!(s.chars().count(), 3);
    }

    #[test]
    fn sparkline_zero_width_is_empty() {
        assert_eq!(sparkline(&[0.0, 1.0, 2.0], 0), "");
    }

    #[test]
    fn sparkline_clamps_nan_to_low_bucket() {
        let s = sparkline(&[f64::NAN, 0.0, 1.0], 3);
        assert_eq!(s.chars().count(), 3);
        assert_eq!(s.chars().next(), Some('▁'));
        // all-NaN series must not panic either
        let all = sparkline(&[f64::NAN, f64::NAN], 2);
        assert_eq!(all, "▁▁");
    }

    #[test]
    fn safe_file_stem_escapes_hostile_names() {
        assert_eq!(safe_file_stem("plain-name_1"), "plain-name_1");
        assert_eq!(safe_file_stem("a,b\"c/d"), "a_b_c_d");
        assert_eq!(safe_file_stem("up\\..:down\n"), "up_.._down_");
        assert_eq!(safe_file_stem(""), "run");
    }

    #[test]
    fn save_with_hostile_name_stays_in_dir() {
        let dir = std::env::temp_dir().join(format!(
            "heron_metrics_test_{}",
            std::process::id()
        ));
        let mut r = rec();
        r.name = "evil,name\"quoted/slashed".to_string();
        r.save(&dir).unwrap();
        let stem = safe_file_stem(&r.name);
        assert!(dir.join(format!("{stem}.json")).exists());
        assert!(dir.join(format!("{stem}.csv")).exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
