//! Cross-language golden checks (substrate S2).
//!
//! `aot.py` executed every entry once with deterministic inputs and stored
//! output digests (shape, head, sum, l2) in the manifest. This module
//! regenerates the *same* inputs in Rust, executes the compiled HLO through
//! the runtime, and compares digests — proving the entire
//! python→HLO→PJRT→Rust pipeline end to end.
//!
//! Input reconstruction mirrors `aot.golden_input`:
//! * `x`/`y` — SynthCIFAR / SynthE2E batches under seed 777 (images match
//!   to libm ulps, integers exactly),
//! * `seed`=0x5EED, `n_pert`=1, `mu`=1e-3, `lr`=1e-2, `opt_t`=0,
//! * `opt_v` — |golden_vec| (Adam second moment must be ≥ 0),
//! * other f32 tensors — `golden_vec(n, 101 + 13·input_index)`,
//! * `base` — the variant's frozen-base blob.

use crate::data::{synth_text, synth_vision};
use crate::runtime::manifest::{DType, TensorSpec, VariantSpec};
use crate::runtime::tensor::TensorValue;
use crate::runtime::Session;
use anyhow::{bail, Context, Result};

pub const GOLDEN_DATA_SEED: u64 = 777;
pub const GOLDEN_SEED_I32: i32 = 0x5EED;

/// Mirrors `synth.golden_vec`: ((i*31 + salt) % 17 - 8) / 100.
pub fn golden_vec(n: usize, salt: i64) -> Vec<f32> {
    (0..n as i64)
        .map(|i| (((i * 31 + salt) % 17 - 8) as f32) / 100.0)
        .collect()
}

fn golden_input(
    session: &Session,
    variant: &str,
    spec: &TensorSpec,
    idx: usize,
    task: &str,
) -> Result<TensorValue> {
    golden_input_for(session.variant(variant)?, spec, idx, task)
}

/// Session-free construction against a bare [`VariantSpec`] — used by the
/// artifact generator to record goldens before any session exists.
pub fn golden_input_for(
    vspec: &VariantSpec,
    spec: &TensorSpec,
    idx: usize,
    task: &str,
) -> Result<TensorValue> {
    let salt = 101 + idx as i64 * 13;
    let n = spec.elems();
    Ok(match spec.name.as_str() {
        "base" => TensorValue::F32(vspec.blob("frozen_base")?),
        "x" => {
            let b = spec.shape[0];
            if task == "vision" {
                TensorValue::F32(synth_vision::batch(GOLDEN_DATA_SEED, 0, b).0)
            } else {
                TensorValue::I32(synth_text::batch(GOLDEN_DATA_SEED, 0, b))
            }
        }
        "y" => {
            let b = spec.shape[0];
            if task == "vision" {
                TensorValue::I32(synth_vision::batch(GOLDEN_DATA_SEED, 0, b).1)
            } else {
                TensorValue::I32(synth_text::batch(GOLDEN_DATA_SEED, 0, b))
            }
        }
        "seed" => TensorValue::ScalarI32(GOLDEN_SEED_I32),
        "n_pert" => TensorValue::ScalarI32(1),
        "mu" => TensorValue::ScalarF32(1e-3),
        "lr" => TensorValue::ScalarF32(1e-2),
        // mature Adam state: t O(1)-biased, v floored away from 0 so the
        // update is a smooth O(1)-Lipschitz function of the gradient (see
        // aot.golden_input for the full rationale)
        "opt_t" => TensorValue::ScalarF32(10.0),
        "opt_v" => TensorValue::F32(
            golden_vec(n, salt)
                .into_iter()
                .map(|x| x.abs() + 0.05)
                .collect(),
        ),
        _ => match spec.dtype {
            DType::I32 => {
                if spec.shape.is_empty() {
                    TensorValue::ScalarI32(0)
                } else {
                    TensorValue::I32(vec![0; n])
                }
            }
            DType::F32 => {
                if spec.shape.is_empty() {
                    TensorValue::ScalarF32(golden_vec(1, salt)[0])
                } else {
                    TensorValue::F32(golden_vec(n, salt))
                }
            }
        },
    })
}

/// Public alias for benches that want deterministic, well-conditioned entry
/// inputs without duplicating the construction rules.
pub fn bench_input(
    session: &Session,
    variant: &str,
    spec: &TensorSpec,
    idx: usize,
    task: &str,
) -> Result<TensorValue> {
    golden_input(session, variant, spec, idx, task)
}

/// Digest one output tensor: (head, sum, l2, len) — the manifest's golden
/// record shape. Public so the artifact generator can record goldens with
/// exactly the digest the checker recomputes.
pub fn digest(v: &TensorValue) -> (Vec<f64>, f64, f64, usize) {
    let vals: Vec<f64> = match v {
        TensorValue::F32(x) => x.iter().map(|&v| v as f64).collect(),
        TensorValue::I32(x) => x.iter().map(|&v| v as f64).collect(),
        TensorValue::ScalarF32(s) => vec![*s as f64],
        TensorValue::ScalarI32(s) => vec![*s as f64],
    };
    let head: Vec<f64> = vals.iter().take(4).cloned().collect();
    let sum: f64 = vals.iter().sum();
    let l2: f64 = vals.iter().map(|x| x * x).sum::<f64>().sqrt();
    (head, sum, l2, vals.len())
}

/// Execute one entry with golden inputs and compare against the manifest
/// digests. Returns the max relative error observed.
pub fn check_entry(
    session: &Session,
    variant: &str,
    entry: &str,
) -> Result<f64> {
    let v = session.variant(variant)?;
    let espec = v.entry(entry)?;
    let goldens = v
        .golden
        .get(entry)
        .with_context(|| format!("no goldens for {variant}/{entry}"))?
        .clone();
    let task = v.task.clone();

    let mut inputs = Vec::with_capacity(espec.inputs.len());
    for (idx, spec) in espec.inputs.iter().enumerate() {
        inputs.push(golden_input(session, variant, spec, idx, &task)?);
    }
    let outs = session.invoke(variant, entry, &inputs)?;
    if outs.len() != goldens.len() {
        bail!("output arity {} != golden {}", outs.len(), goldens.len());
    }

    // Tolerance note: the ZO estimator computes (loss(θ+μu)-loss(θ))/μ with
    // μ=1e-3, amplifying XLA-version rounding differences in the f32 loss by
    // ~1000x before they reach the Adam moment outputs; 5e-3 relative (to
    // the vector's l2) is the observed cross-version envelope with margin.
    const TOL: f64 = 5e-3;
    let mut max_rel = 0.0f64;
    for (i, (out, gold)) in outs.iter().zip(&goldens).enumerate() {
        let (head, sum, l2, len) = digest(out);
        let want_len: usize = gold.shape.iter().product::<usize>().max(1);
        if len != want_len {
            bail!("output {i}: length {len} != golden {want_len}");
        }
        // scale for relative comparison: the vector's l2 (falls back to 1)
        let scale = gold.l2.abs().max(1.0);
        let rel = |a: f64, b: f64| (a - b).abs() / scale;
        for (k, (&h, &g)) in head.iter().zip(&gold.head).enumerate() {
            let r = rel(h, g);
            max_rel = max_rel.max(r);
            if r > TOL {
                bail!(
                    "output {i} head[{k}]: {h} vs golden {g} (rel {r:.2e})"
                );
            }
        }
        let rs = rel(sum, gold.sum);
        let rl = rel(l2, gold.l2);
        max_rel = max_rel.max(rs).max(rl);
        if rs > TOL || rl > TOL {
            bail!(
                "output {i}: sum {sum} vs {} (rel {rs:.2e}), l2 {l2} vs {} (rel {rl:.2e})",
                gold.sum,
                gold.l2
            );
        }
    }
    Ok(max_rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_vec_matches_python_formula() {
        let v = golden_vec(8, 101);
        for (i, &x) in v.iter().enumerate() {
            let expect = (((i as i64 * 31 + 101) % 17 - 8) as f32) / 100.0;
            assert_eq!(x, expect);
        }
        // spot values: (0*31+101)%17=16-8=8 -> 0.08
        assert_eq!(v[0], 0.08);
    }

    #[test]
    fn digest_of_scalar() {
        let (head, sum, l2, len) = digest(&TensorValue::ScalarF32(2.0));
        assert_eq!(head, vec![2.0]);
        assert_eq!(sum, 2.0);
        assert_eq!(l2, 2.0);
        assert_eq!(len, 1);
    }
}
