//! heron-sfl CLI: run experiments, inspect artifacts, validate goldens.
//!
//! Subcommands:
//!   run        — one training run (all config flags overridable)
//!   serve      — run the experiment as a network server (framed TCP
//!                protocol; clients attach with `connect`)
//!   connect    — attach this process as a remote SFL client; `--virtual N`
//!                multiplexes N simulated edge devices through the socket
//!   bench      — load benchmarks (`bench serve-storm`: TCP dispatcher +
//!                multiplexed clients, rounds/sec and p99 round latency)
//!   list       — list artifact variants and their entries
//!   validate   — execute golden cross-language checks over the artifacts
//!   costs      — print the Table-I style cost book for a variant
//!   spectrum   — Hessian eigenvalue density of the client local loss (Fig 7)
//!   report     — summarize a `--trace_out` flight-recorder trace
//!
//! `run`, `serve`, `connect`, and `bench serve-storm` all accept
//! `--trace_out t.json` (Chrome/Perfetto trace + metrics registry);
//! `serve`/`run` additionally accept `--stats_every N` for periodic
//! one-line registry snapshots.

use anyhow::{bail, Context, Result};
use heron_sfl::analysis::lanczos;
use heron_sfl::coordinator::accounting::{fmt_bytes, table1_row, CostBook};
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::metrics::sparkline;
use heron_sfl::runtime::tensor::TensorValue;
use heron_sfl::runtime::Session;
use heron_sfl::util::cli::Args;

fn main() {
    heron_sfl::util::logging::init();
    let args = Args::parse();
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let res = match cmd {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "connect" => cmd_connect(&args),
        "bench" => cmd_bench(&args),
        "list" => cmd_list(),
        "validate" => cmd_validate(&args),
        "costs" => cmd_costs(&args),
        "spectrum" => cmd_spectrum(&args),
        "report" => cmd_report(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "heron-sfl — hybrid ZO/FO split federated learning\n\n\
         USAGE: heron-sfl <run|serve|connect|bench|list|validate|costs|spectrum> [--key value ...]\n\n\
         run flags: --variant cnn_c1 --algo heron|cse|sage|sflv1|sflv2\n\
           --clients N --rounds R --h H --k K --mu MU --n_pert P\n\
           --lr_client LR --lr_server LR --alpha A (dirichlet) --participation F\n\
           --workers W (client-phase worker threads; 0 = all cores)\n\
           --queue_capacity Q (Main-Server queue bound; 0 = never drops)\n\
           --zo_wire theta|seeds|seed_agg (HERON wire: full θ_l up, the\n\
             lean seed+per-probe-scalar upload the server replays, or\n\
             seed_agg — lean both ways: the broadcast is the aggregated\n\
             seeds+scalars roster (wire v7 SeedSync) and every client\n\
             reconstructs θ_l locally; downlink cost is dimension-free)\n\
           --drain barrier|stream (server consumption: deterministic\n\
             Eq.-7 barrier drain, or arrival-order mid-round pipelining)\n\
           --codec f32|int8|int4 (smashed-activation payload codec;\n\
             f32 is the bit-identical identity, int8/int4 are per-tensor\n\
             affine quantizers — negotiated with clients at Hello)\n\
           --grad_codec f32|topk:<ratio> (CutGradient payload codec;\n\
             top-k sparsification, locked baselines sfl_v1/sfl_v2 only)\n\
           --out results/dir (writes json+csv)\n\
           --round_deadline_ms D (straggler cutoff: finalize each round\n\
             with whatever uploads arrived within D ms — wall-clock on\n\
             the wire path, virtual time in the in-process event-sim;\n\
             0 = wait forever, bit-identical to pre-deadline builds)\n\
         serve flags: all run flags, plus\n\
           --listen ADDR (default 127.0.0.1:7070; port 0 picks one)\n\
           --conns N (client connections to wait for; default 2)\n\
           --checkpoint_every K (write a checkpoint every K rounds)\n\
           --checkpoint_path FILE (CRC-checksummed checkpoint file;\n\
             also written on SIGINT/SIGTERM before the clean Shutdown)\n\
           --restore FILE (resume a checkpointed run; finishes\n\
             bit-identical to the uninterrupted run)\n\
         connect flags: --addr ADDR (default 127.0.0.1:7070) --name NAME\n\
           --virtual N (multiplex N simulated edge devices — protocol\n\
             lanes — through this one socket; default 1)\n\
           (a client that reconnects to a live server takes over a dead\n\
             connection's lane block and fast-forwards to the open round)\n\
         bench serve-storm flags: all run flags (defaults to the storm\n\
           preset: population 1024, cohort 64, seeds uploads), plus\n\
           --conns N (sockets; default 16) --lanes L (virtual clients per\n\
           socket; default 64) --out report.json (merge a\n\
           heron-sfl-bench-v1 report)\n\
         bench codec-sweep flags: --rounds R --out report.json (vision +\n\
           LM presets x {{f32,int8,int4}} smashed codecs + a top-k\n\
           cut-gradient leg; prints the bytes-vs-accuracy Pareto table\n\
           and merges it into bench_report.json by default)\n\
         costs flags: --variant V [--n_pert P]\n\
         spectrum flags: --variant cnn_c1 [--steps M] [--probes P]\n\
         observability (run/serve/connect/bench serve-storm):\n\
           --trace_out t.json (Chrome trace-event JSON — load in Perfetto\n\
             or summarize with `heron-sfl report t.json`; also dumps the\n\
             metrics registry into the run summary)\n\
           --stats_every N (serve/run: log a one-line registry snapshot\n\
             every N rounds)\n\
         report: heron-sfl report t.json (per-phase time breakdown +\n\
           histogram table from a recorded trace)"
    );
}

/// `--trace_out FILE` starts the flight recorder (spans + metrics) for
/// this process; `--stats_every N` alone still enables the metrics
/// registry so the periodic snapshots have data. Returns true when a
/// trace file was installed and needs [`trace::shutdown`] at exit.
fn telemetry_from_args(args: &Args, process: &str) -> Result<bool> {
    if args.get_usize("stats_every", 0) > 0 {
        heron_sfl::telemetry::enable_metrics();
    }
    if let Some(path) = args.get("trace_out") {
        heron_sfl::telemetry::trace::install(path, process)?;
        return Ok(true);
    }
    Ok(false)
}

fn telemetry_finish(traced: bool) -> Result<()> {
    if traced {
        heron_sfl::telemetry::trace::shutdown()?;
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let Some(path) = args.positional.get(1) else {
        bail!("usage: heron-sfl report <trace.json>");
    };
    heron_sfl::telemetry::trace::report(path)
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    cfg.validate()?;
    log::info!("{}", cfg.describe());
    let traced = telemetry_from_args(args, "heron-sfl run")?;
    let session = Session::open_default()?;
    let mut driver = Driver::new(&session, cfg.clone())?;
    let rec = driver.run("run")?;
    telemetry_finish(traced)?;
    let curve: Vec<f64> = rec
        .rounds
        .iter()
        .filter(|r| r.eval_metric.is_finite())
        .map(|r| r.eval_metric)
        .collect();
    println!("metric curve: {}", sparkline(&curve, 60));
    println!(
        "final metric {:.4} | comm {} | client flops {:.2} G | peak mem {}",
        curve.last().copied().unwrap_or(f64::NAN),
        fmt_bytes(rec.summary["comm_bytes"] as u64),
        rec.summary["client_flops"] / 1e9,
        fmt_bytes(rec.summary["peak_mem_bytes"] as u64),
    );
    if let Some(out) = args.get("out") {
        rec.save(std::path::Path::new(out))?;
        println!("saved to {out}/run.{{json,csv}}");
    }
    let st = session.stats();
    // the training hot path runs through the typed ClientRuntime surface,
    // which bypasses the per-invoke counters — these totals cover the
    // name-based entry path (artifact/golden validation) plus the engine
    // build and the feature-plan cache, not the step loop itself
    log::info!(
        "session: compile {:.2}s | name-based entries: {} invocations, \
         exec {:.2}s, marshal {:.2}s | feature cache: {} hits / {} misses",
        st.compile_seconds,
        st.invocations,
        st.exec_seconds,
        st.marshal_seconds,
        st.feature_cache_hits,
        st.feature_cache_misses,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    cfg.validate()?;
    let conns = args.get_usize("conns", 2);
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;
    println!(
        "serving {} on {} — waiting for {conns} client connection(s)",
        cfg.describe(),
        listener.local_addr()?
    );
    let opts = heron_sfl::net::ServeOptions {
        checkpoint_every: args.get_usize("checkpoint_every", 0),
        checkpoint_path: args
            .get("checkpoint_path")
            .map(std::path::PathBuf::from),
        restore: args.get("restore").map(std::path::PathBuf::from),
        halt_after: 0,
        watch_signals: true,
        rejoin: true,
        stats_every: args.get_usize("stats_every", 0),
    };
    let traced = telemetry_from_args(args, "heron-sfl serve")?;
    // ^C / SIGTERM become a final checkpoint + clean Shutdown broadcast
    heron_sfl::util::signal::reset();
    heron_sfl::util::signal::install();
    let session = Session::open_default()?;
    let report = heron_sfl::net::serve_tcp_opts(
        &session, cfg, listener, conns, "serve", opts,
    )?;
    telemetry_finish(traced)?;
    print_net_summary(&report);
    if let Some(out) = args.get("out") {
        report.record.save(std::path::Path::new(out))?;
        println!("saved to {out}/serve.{{json,csv}}");
    }
    Ok(())
}

fn print_net_summary(report: &heron_sfl::net::NetReport) {
    let rec = &report.record;
    let curve: Vec<f64> = rec
        .rounds
        .iter()
        .filter(|r| r.eval_metric.is_finite())
        .map(|r| r.eval_metric)
        .collect();
    println!("metric curve: {}", sparkline(&curve, 60));
    println!(
        "final metric {:.4} over {} connection(s)",
        curve.last().copied().unwrap_or(f64::NAN),
        report.connections
    );
    // the whole point of heron-net: the analytic cost-book number next to
    // the bytes that actually crossed the wire
    println!(
        "comm (analytic CostBook) {} | wire measured: {} sent, {} recv, {} frames | NACKs {}",
        fmt_bytes(rec.summary["comm_bytes"] as u64),
        fmt_bytes(report.wire.bytes_sent),
        fmt_bytes(report.wire.bytes_recv),
        report.wire.frames_sent + report.wire.frames_recv,
        report.nacks_sent,
    );
    // `--zo_wire seed_agg` under `--trace_out`/`--stats_every`: the
    // measured broadcast bytes and the dense-sync bytes they displaced
    if let Some(&down) = rec.summary.get("net.downlink.bytes") {
        let saved = rec
            .summary
            .get("net.downlink.bytes_saved")
            .copied()
            .unwrap_or(0.0);
        println!(
            "downlink measured {} | saved vs dense θ sync {}",
            fmt_bytes(down as u64),
            fmt_bytes(saved as u64),
        );
    }
    if report.disconnects > 0 || report.clients_cut > 0 {
        println!(
            "churn: {} disconnect(s) ({} mid-frame) | {} client slot(s) cut \
             from rounds",
            report.disconnects,
            report.mid_frame_disconnects,
            report.clients_cut,
        );
    }
}

fn cmd_connect(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let name = args.get_or("name", "client");
    let lanes = args.get_usize("virtual", 1);
    let traced = telemetry_from_args(args, "heron-sfl connect")?;
    let session = Session::open_default()?;
    let transport = heron_sfl::net::TcpTransport::connect(addr)?;
    println!("connected to {addr} as {name} ({lanes} virtual client(s))");
    let rep = heron_sfl::net::run_client_virtual(
        &session,
        Box::new(transport),
        name,
        lanes,
    )?;
    telemetry_finish(traced)?;
    println!(
        "served clients {:?}: {} rounds, {} local phases | wire: {} sent, {} recv | NACKs {} | server said: {}",
        rep.assigned,
        rep.rounds,
        rep.phases,
        fmt_bytes(rep.wire.bytes_sent),
        fmt_bytes(rep.wire.bytes_recv),
        rep.nacks,
        rep.shutdown_reason,
    );
    // one line per multiplexed run for the CI smoke to grep: every lane
    // either ran a local phase or legitimately owned no clients
    println!(
        "{}/{} lanes complete",
        heron_sfl::net::storm::lanes_complete(&rep),
        rep.lanes,
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("serve-storm") => cmd_bench_serve_storm(args),
        Some("codec-sweep") => cmd_bench_codec_sweep(args),
        other => bail!(
            "unknown bench mode {other:?} — try `heron-sfl bench serve-storm` \
             or `heron-sfl bench codec-sweep` (the full storm sweep lives in \
             `cargo bench --bench serve_storm`)"
        ),
    }
}

/// One storm point from the CLI: real TCP dispatcher + `--conns` sockets
/// × `--lanes` virtual clients each, reporting round throughput and tail
/// latency. The fixed 3-point sweep with the baseline gate lives in
/// `benches/serve_storm.rs`; this mode is for ad-hoc sizing runs and the
/// CI smoke.
fn cmd_bench_serve_storm(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => heron_sfl::net::storm_config(),
    };
    cfg.apply_args(args)?;
    cfg.validate()?;
    let conns = args.get_usize("conns", 16);
    let lanes = args.get_usize("lanes", 64);
    println!(
        "storm point: {} | {conns} socket(s) x {lanes} lane(s) = {} virtual clients",
        cfg.describe(),
        conns * lanes,
    );
    let traced = telemetry_from_args(args, "heron-sfl serve-storm")?;
    let session = Session::open_default()?;
    let p = heron_sfl::net::run_storm(&session, cfg, conns, lanes)?;
    telemetry_finish(traced)?;
    println!(
        "{} virtual clients / {} sockets: {:.2} rounds/s | mean round {:.1} ms | p99 round {:.1} ms",
        p.total_lanes,
        p.conns,
        p.rounds_per_sec,
        p.mean_round_seconds * 1e3,
        p.p99_round_seconds * 1e3,
    );
    println!(
        "{}/{} lanes complete | NACKs {} | wire {}",
        p.lanes_complete,
        p.total_lanes,
        p.nacks,
        fmt_bytes(p.wire_bytes),
    );
    if let Some(out) = args.get("out") {
        heron_sfl::bench_harness::merge_report(
            out,
            &[],
            &[
                (
                    "serve_storm_rounds_per_sec",
                    heron_sfl::util::json::Value::Num(p.rounds_per_sec),
                ),
                (
                    "serve_storm_p99_round_latency_seconds",
                    heron_sfl::util::json::Value::Num(p.p99_round_seconds),
                ),
                (
                    "serve_storm_virtual_clients",
                    heron_sfl::util::json::Value::Num(p.total_lanes as f64),
                ),
                (
                    "serve_storm_conns",
                    heron_sfl::util::json::Value::Num(p.conns as f64),
                ),
            ],
        )?;
        println!("merged storm point into {out}");
    }
    Ok(())
}

/// Bytes-vs-accuracy Pareto sweep over the payload codecs: the vision and
/// LM presets each run under every smashed codec (`f32` is the pinned
/// identity leg) plus a top-k cut-gradient leg on the locked `sfl_v1`
/// baseline — the only family that ships a per-step CutGradient. The
/// table prints and lands as a `codec_sweep` array in the shared
/// `heron-sfl-bench-v1` report (default `bench_report.json`).
fn cmd_bench_codec_sweep(args: &Args) -> Result<()> {
    use heron_sfl::experiments;
    use heron_sfl::net::codec::{Codec, GradCodec};
    use heron_sfl::util::json::Value;

    let rounds =
        args.get_usize("rounds", experiments::scaled_rounds(3, 12));
    let out = args.get_or("out", "bench_report.json");
    let traced = telemetry_from_args(args, "heron-sfl codec-sweep")?;
    let session = Session::open_default()?;

    let presets: [(&str, RunConfig); 2] = [
        ("vision", experiments::vision_base(rounds)),
        ("lm", experiments::lm_base("gpt2nano_c1_a1", rounds)),
    ];
    let mut legs: Vec<(String, RunConfig)> = Vec::new();
    for (pname, base) in &presets {
        for codec in [Codec::F32, Codec::Int8, Codec::Int4] {
            let mut cfg = base.clone();
            cfg.codec = codec;
            legs.push((format!("{pname}/{}", codec.name()), cfg));
        }
        let mut cfg = base.clone();
        cfg.algorithm = Algorithm::SflV1;
        cfg.grad_codec = GradCodec::TopK(0.25);
        legs.push((format!("{pname}/topk"), cfg));
    }

    let mut t = heron_sfl::bench_harness::Table::new(&[
        "leg", "algorithm", "codec", "grad_codec", "comm/run",
        "final metric",
    ]);
    let mut rows: Vec<Value> = Vec::new();
    for (name, cfg) in legs {
        cfg.validate()?;
        let rec = experiments::run(&session, cfg.clone(), &name)?;
        let metric = rec
            .rounds
            .iter()
            .filter(|r| r.eval_metric.is_finite())
            .map(|r| r.eval_metric)
            .next_back()
            .unwrap_or(f64::NAN);
        let comm = rec.summary["comm_bytes"];
        t.row(vec![
            name.clone(),
            cfg.algorithm.name().to_string(),
            cfg.codec.name().to_string(),
            cfg.grad_codec.spec(),
            fmt_bytes(comm as u64),
            format!("{metric:.4}"),
        ]);
        rows.push(Value::obj(vec![
            ("leg", Value::str(&name)),
            ("algorithm", Value::str(cfg.algorithm.name())),
            ("codec", Value::str(cfg.codec.name())),
            ("grad_codec", Value::str(&cfg.grad_codec.spec())),
            ("comm_bytes", Value::Num(comm)),
            ("final_metric", Value::Num(metric)),
        ]));
    }
    telemetry_finish(traced)?;
    t.print(&format!(
        "codec Pareto sweep — bytes vs final accuracy ({rounds} rounds)"
    ));
    heron_sfl::bench_harness::merge_report(
        out,
        &[],
        &[("codec_sweep", Value::Arr(rows))],
    )?;
    println!("merged codec sweep into {out}");
    Ok(())
}

fn cmd_list() -> Result<()> {
    let session = Session::open_default()?;
    for (name, v) in &session.manifest.variants {
        println!(
            "{name:<24} task={:<6} batch={:<4} θc={:<7} θa={:<7} θs={:<8} entries: {}",
            v.task,
            v.batch,
            v.size_client,
            v.size_aux,
            v.size_server,
            v.entries
                .keys()
                .cloned()
                .collect::<Vec<_>>()
                .join(",")
        );
    }
    Ok(())
}

fn cmd_costs(args: &Args) -> Result<()> {
    let session = Session::open_default()?;
    let variant = args.get_or("variant", "cnn_c1");
    let n_pert = args.get_usize("n_pert", 1) as u64;
    let v = session.variant(variant)?;
    let mut t = heron_sfl::bench_harness::Table::new(&[
        "Method", "Comms/round/client", "Peak Memory", "FLOPs/step",
    ]);
    for alg in Algorithm::all() {
        t.row(table1_row(v, alg, n_pert.max(2)));
    }
    t.print(&format!("Table I instantiated for {variant}"));
    let book = CostBook::new(v, Algorithm::Heron, n_pert);
    println!(
        "\nHERON peak memory {} vs CSE-FSL {}",
        fmt_bytes(book.peak_mem_bytes),
        fmt_bytes(CostBook::new(v, Algorithm::CseFsl, 1).peak_mem_bytes)
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let session = Session::open_default()?;
    let only = args.get("variant");
    let mut total = 0usize;
    let mut failed = 0usize;
    for (name, v) in &session.manifest.variants {
        if let Some(o) = only {
            if o != name {
                continue;
            }
        }
        for (entry, goldens) in &v.golden {
            total += 1;
            match heron_sfl::golden::check_entry(&session, name, entry) {
                Ok(max_rel) => {
                    println!("ok   {name}/{entry} (max rel err {max_rel:.2e})");
                }
                Err(e) => {
                    failed += 1;
                    println!("FAIL {name}/{entry}: {e:#}");
                }
            }
            let _ = goldens;
        }
    }
    println!("\n{}/{} golden checks passed", total - failed, total);
    if failed > 0 {
        bail!("{failed} golden checks failed");
    }
    Ok(())
}

fn cmd_spectrum(args: &Args) -> Result<()> {
    let session = Session::open_default()?;
    let variant = args.get_or("variant", "cnn_c1");
    let steps = args.get_usize("steps", 24);
    let probes = args.get_usize("probes", 4);
    let v = session.variant(variant)?;
    if !v.entries.contains_key("hvp") {
        bail!("variant {variant} has no hvp entry (use cnn_c1)");
    }

    struct EntryHvp<'a> {
        session: &'a Session,
        variant: String,
        theta: Vec<f32>,
        x: TensorValue,
        y: Vec<i32>,
        base: Option<Vec<f32>>,
    }
    impl lanczos::Hvp for EntryHvp<'_> {
        fn dim(&self) -> usize {
            self.theta.len()
        }
        fn apply(&mut self, vdir: &[f32]) -> Result<Vec<f32>> {
            let mut c = heron_sfl::runtime::Call::new(
                self.session,
                &self.variant,
                "hvp",
            );
            if let Some(b) = &self.base {
                c = c.arg("base", b.clone());
            }
            let outs = c
                .arg("theta_l", self.theta.clone())
                .arg("x", self.x.clone())
                .arg("y", TensorValue::I32(self.y.clone()))
                .arg("v", vdir.to_vec())
                .run()?;
            outs.get("hv").context("hv")?.clone().into_f32()
        }
    }

    let theta = v.blob("init_theta_l")?;
    let (xs, ys) =
        heron_sfl::data::synth_vision::batch(42, 0, v.batch);
    let base = if v.size_base > 0 {
        Some(v.blob("frozen_base")?)
    } else {
        None
    };
    let mut h = EntryHvp {
        session: &session,
        variant: variant.to_string(),
        theta,
        x: TensorValue::F32(xs),
        y: ys,
        base,
    };
    let hist = lanczos::spectral_density(&mut h, steps, probes, 31)?;
    hist.print(&format!(
        "Hessian eigenvalue density — {variant} local loss (Fig 7)"
    ));
    println!(
        "mass within 5% of spectral range around zero: {:.1}%",
        hist.mass_near_zero((hist.hi - hist.lo) * 0.05) * 100.0
    );
    let kappa = lanczos::effective_rank(&mut h, steps, probes)?;
    println!("effective rank tr(H)/||H||: {kappa:.1} (dim {})", lanczos::Hvp::dim(&h));
    Ok(())
}
