//! Loss-landscape analysis: Hessian spectrum via stochastic Lanczos
//! quadrature (paper Fig 7 / Appendix B evidence for Assumption 5).

pub mod lanczos;
