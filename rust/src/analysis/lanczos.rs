//! Stochastic Lanczos quadrature over the client-side local-loss Hessian
//! (substrate S17, reproduces paper Fig 7).
//!
//! The Hessian is only touched through matrix-vector products — the `hvp`
//! HLO entry — so the algorithm is the classic matrix-free Lanczos:
//! m steps produce a tridiagonal T whose Ritz values/weights give a
//! quadrature of the spectral density; averaging over probe vectors yields
//! the eigenvalue-density histogram, and the trace/op-norm ratio estimates
//! the paper's effective rank (Assumption 5).

use anyhow::Result;

/// Abstract H·v oracle (implemented over runtime::Session in the benches,
/// and by dense matrices in tests).
pub trait Hvp {
    fn dim(&self) -> usize;
    fn apply(&mut self, v: &[f32]) -> Result<Vec<f32>>;
}

/// Ritz values and quadrature weights from one Lanczos run.
#[derive(Debug, Clone)]
pub struct RitzQuadrature {
    pub values: Vec<f64>,
    pub weights: Vec<f64>,
}

/// m-step Lanczos with full reorthogonalization (m is small — ≤ 64 — so the
/// O(m^2 d) cost is irrelevant and numerical stability wins).
pub fn lanczos<H: Hvp>(
    h: &mut H,
    m: usize,
    probe_seed: u32,
) -> Result<RitzQuadrature> {
    let d = h.dim();
    let m = m.min(d);
    // Rademacher probe
    let mut v: Vec<f32> = (0..d)
        .map(|i| {
            if crate::zo::stream::hash_u32(probe_seed, i as u32) & 1 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect();
    normalize(&mut v);

    let mut basis: Vec<Vec<f32>> = vec![v.clone()];
    let mut alphas: Vec<f64> = Vec::with_capacity(m);
    let mut betas: Vec<f64> = Vec::with_capacity(m);

    let mut w_prev: Option<Vec<f32>> = None;
    let mut beta_prev = 0.0f64;
    for j in 0..m {
        let mut w = h.apply(&basis[j])?;
        if let Some(prev) = &w_prev {
            for i in 0..d {
                w[i] -= (beta_prev as f32) * prev[i];
            }
        }
        let alpha = dot(&w, &basis[j]);
        for i in 0..d {
            w[i] -= (alpha as f32) * basis[j][i];
        }
        // full reorthogonalization
        for b in &basis {
            let c = dot(&w, b);
            for i in 0..d {
                w[i] -= c as f32 * b[i];
            }
        }
        alphas.push(alpha);
        let beta = norm(&w);
        if j + 1 < m {
            if beta < 1e-10 {
                break; // invariant subspace found
            }
            for x in &mut w {
                *x /= beta as f32;
            }
            betas.push(beta);
            beta_prev = beta;
            w_prev = Some(basis[j].clone());
            basis.push(w);
        }
    }

    let (values, first_components) = tridiag_eigen(&alphas, &betas);
    let weights = first_components.iter().map(|c| c * c).collect();
    Ok(RitzQuadrature { values, weights })
}

/// Spectral density histogram averaged over `probes` Lanczos runs.
pub fn spectral_density<H: Hvp>(
    h: &mut H,
    m: usize,
    probes: usize,
    bins: usize,
) -> Result<Histogram> {
    let mut quads = Vec::new();
    for p in 0..probes {
        quads.push(lanczos(h, m, 0xF16_7 + p as u32)?);
    }
    let lo = quads
        .iter()
        .flat_map(|q| q.values.iter().cloned())
        .fold(f64::INFINITY, f64::min);
    let hi = quads
        .iter()
        .flat_map(|q| q.values.iter().cloned())
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0.0f64; bins];
    for q in &quads {
        for (v, w) in q.values.iter().zip(&q.weights) {
            let b = (((v - lo) / span) * (bins as f64 - 1.0)).round() as usize;
            counts[b.min(bins - 1)] += w / probes as f64;
        }
    }
    Ok(Histogram {
        lo,
        hi,
        counts,
    })
}

/// Effective-rank estimate tr(|H|)/||H||_2 via the same quadratures
/// (Assumption 5's κ). The absolute spectrum is used because a training
/// Hessian is indefinite — plain tr(H) cancels between positive and
/// negative curvature and can even go negative; Assumption 5's H_l is the
/// PSD curvature envelope, for which |λ| is the faithful proxy.
pub fn effective_rank<H: Hvp>(
    h: &mut H,
    m: usize,
    probes: usize,
) -> Result<f64> {
    let d = h.dim() as f64;
    let mut trace_abs = 0.0;
    let mut opnorm: f64 = 0.0;
    for p in 0..probes {
        let q = lanczos(h, m, 0x7ACE + p as u32)?;
        // quadrature estimate of tr(|H|)/d is sum w_i * |lambda_i|
        trace_abs += q
            .values
            .iter()
            .zip(&q.weights)
            .map(|(v, w)| v.abs() * w)
            .sum::<f64>()
            * d;
        opnorm = opnorm.max(
            q.values
                .iter()
                .cloned()
                .fold(0.0f64, |a, b| a.max(b.abs())),
        );
    }
    trace_abs /= probes as f64;
    Ok(if opnorm > 0.0 { trace_abs / opnorm } else { 0.0 })
}

#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<f64>,
}

impl Histogram {
    pub fn print(&self, title: &str) {
        println!("\n--- {title} ---");
        let max = self.counts.iter().cloned().fold(1e-12, f64::max);
        let n = self.counts.len();
        for (i, c) in self.counts.iter().enumerate() {
            let lo = self.lo + (self.hi - self.lo) * i as f64 / n as f64;
            let bar_len = ((c / max) * 50.0).round() as usize;
            // log-ish marker so small-but-nonzero bins stay visible
            let bar: String = "#".repeat(bar_len.max(usize::from(*c > 1e-9)));
            println!("{lo:>+10.4}  {c:>9.5}  {bar}");
        }
    }

    /// Mass within `eps` of zero — the paper's "heavily concentrated at
    /// zero" observation.
    pub fn mass_near_zero(&self, eps: f64) -> f64 {
        let n = self.counts.len();
        let total: f64 = self.counts.iter().sum();
        let mut near = 0.0;
        for (i, c) in self.counts.iter().enumerate() {
            let center =
                self.lo + (self.hi - self.lo) * (i as f64 + 0.5) / n as f64;
            if center.abs() <= eps {
                near += c;
            }
        }
        if total > 0.0 {
            near / total
        } else {
            0.0
        }
    }
}

// ---------------------------------------------------------------------------
// small linear algebra helpers
// ---------------------------------------------------------------------------

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

fn norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

fn normalize(a: &mut [f32]) {
    let n = norm(a) as f32;
    if n > 0.0 {
        for x in a {
            *x /= n;
        }
    }
}

/// Eigen-decomposition of a symmetric tridiagonal matrix via implicit-shift
/// QL (Numerical-Recipes-style `tqli`), returning eigenvalues and the first
/// component of each eigenvector (all Lanczos quadrature needs).
fn tridiag_eigen(alphas: &[f64], betas: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = alphas.len();
    if n == 0 {
        return (vec![], vec![]);
    }
    let mut d = alphas.to_vec();
    let mut e = vec![0.0; n];
    e[..n - 1].copy_from_slice(&betas[..n.saturating_sub(1)]);
    // z tracks only the first row of the eigenvector matrix
    let mut z = vec![0.0; n];
    z[0] = 1.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small off-diagonal
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                break;
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // first-row eigenvector update
                f = z[i + 1];
                z[i + 1] = s * z[i] + c * f;
                z[i] = c * z[i] - s * f;
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // sort by eigenvalue
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    (
        idx.iter().map(|&i| d[i]).collect(),
        idx.iter().map(|&i| z[i]).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense symmetric test oracle.
    struct Dense {
        a: Vec<Vec<f32>>,
    }

    impl Hvp for Dense {
        fn dim(&self) -> usize {
            self.a.len()
        }
        fn apply(&mut self, v: &[f32]) -> Result<Vec<f32>> {
            Ok(self
                .a
                .iter()
                .map(|row| row.iter().zip(v).map(|(&x, &y)| x * y).sum())
                .collect())
        }
    }

    fn diag(vals: &[f32]) -> Dense {
        let n = vals.len();
        let mut a = vec![vec![0.0; n]; n];
        for i in 0..n {
            a[i][i] = vals[i];
        }
        Dense { a }
    }

    #[test]
    fn tridiag_eigen_2x2_known() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3; first components 1/sqrt(2)
        let (vals, z) = tridiag_eigen(&[2.0, 2.0], &[1.0]);
        assert!((vals[0] - 1.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
        for c in z {
            assert!((c.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        }
    }

    #[test]
    fn lanczos_recovers_diagonal_spectrum() {
        let vals: Vec<f32> = vec![0.0, 0.0, 0.0, 0.0, 1.0, 5.0, 10.0, -2.0];
        let mut h = diag(&vals);
        let q = lanczos(&mut h, 8, 3).unwrap();
        // extreme eigenvalues must be found accurately
        let max = q.values.iter().cloned().fold(f64::MIN, f64::max);
        let min = q.values.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - 10.0).abs() < 1e-4, "max {max}");
        assert!((min + 2.0).abs() < 1e-4, "min {min}");
        // weights sum to ~1
        let wsum: f64 = q.weights.iter().sum();
        assert!((wsum - 1.0).abs() < 1e-6, "wsum {wsum}");
    }

    #[test]
    fn spectral_density_concentrates_where_spectrum_is() {
        // mostly-zero spectrum: histogram mass near zero should dominate
        let mut vals = vec![0.0f32; 60];
        vals.extend_from_slice(&[8.0, 9.0, 10.0, -1.0]);
        let mut h = diag(&vals);
        let hist = spectral_density(&mut h, 16, 4, 21).unwrap();
        assert!(hist.mass_near_zero(1.0) > 0.7);
    }

    #[test]
    fn effective_rank_of_identity_is_dim() {
        let mut h = diag(&vec![1.0f32; 32]);
        let k = effective_rank(&mut h, 16, 3).unwrap();
        assert!((k - 32.0).abs() < 2.0, "kappa {k}");
    }

    #[test]
    fn effective_rank_of_rank1_is_small() {
        let mut vals = vec![0.0f32; 63];
        vals.push(10.0);
        let mut h = diag(&vals);
        let k = effective_rank(&mut h, 16, 3).unwrap();
        assert!(k < 3.0, "kappa {k}");
    }
}
