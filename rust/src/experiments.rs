//! Shared experiment harness for the paper-reproduction benches.
//!
//! Every bench target (one per paper table/figure) builds on these helpers:
//! `scaled_rounds` keeps default `cargo bench` runs CI-sized while
//! `REPRO_FULL=1` restores paper-fidelity budgets; `run` executes one
//! configured training run end to end.

use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::config::RunConfig;
use crate::coordinator::round::Driver;
use crate::metrics::RunRecord;
use crate::runtime::Session;
use anyhow::Result;

/// True when the full-fidelity flag is set.
pub fn full_mode() -> bool {
    std::env::var("REPRO_FULL").map(|v| v != "0").unwrap_or(false)
}

/// Pick a round budget: `smoke` rounds by default, `full` with REPRO_FULL=1,
/// overridable via ROUNDS env.
pub fn scaled_rounds(smoke: usize, full: usize) -> usize {
    if let Ok(r) = std::env::var("ROUNDS") {
        if let Ok(n) = r.parse() {
            return n;
        }
    }
    if full_mode() {
        full
    } else {
        smoke
    }
}

/// Execute one run and return its record.
pub fn run(session: &Session, cfg: RunConfig, name: &str) -> Result<RunRecord> {
    log::info!("[experiment] {}", cfg.describe());
    let mut driver = Driver::new(session, cfg)?;
    driver.run(name)
}

/// Baseline vision config shared by the Fig 2/3/4 + Table II benches
/// (paper §VI-B: ResNet on CIFAR-10, 5 clients, Adam 1e-4 — scaled to the
/// MiniResNet/SynthCIFAR substrate).
pub fn vision_base(rounds: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: Algorithm::Heron,
        n_clients: 5,
        rounds,
        local_steps: 2,
        upload_every: 1,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 4096,
        eval_every: 1,
        ..Default::default()
    }
}

/// Baseline language config shared by Fig 5/6 + Table III benches
/// (paper §VI-C: GPT2 on E2E, 3 clients, LoRA).
pub fn lm_base(variant: &str, rounds: usize) -> RunConfig {
    RunConfig {
        variant: variant.into(),
        algorithm: Algorithm::Heron,
        n_clients: 3,
        rounds,
        local_steps: 2,
        upload_every: 1,
        lr_client: 1e-3,
        lr_server: 1e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1536,
        eval_every: 1,
        ..Default::default()
    }
}

/// Format a metric series as "v0 -> vN (best B)".
pub fn curve_summary(rec: &RunRecord, higher_better: bool) -> String {
    let m: Vec<f64> = rec
        .rounds
        .iter()
        .filter(|r| r.eval_metric.is_finite())
        .map(|r| r.eval_metric)
        .collect();
    if m.is_empty() {
        return "n/a".into();
    }
    format!(
        "{:.3} -> {:.3} (best {:.3})",
        m.first().unwrap(),
        m.last().unwrap(),
        rec.best_metric(higher_better).unwrap_or(f64::NAN)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_rounds_respects_default() {
        // no env manipulation in tests (parallel safety); just check the
        // arithmetic path with current env
        let r = scaled_rounds(3, 50);
        assert!(r == 3 || r == 50 || std::env::var("ROUNDS").is_ok());
    }

    #[test]
    fn base_configs_valid() {
        vision_base(5).validate().unwrap();
        lm_base("gpt2nano_c1_a1", 5).validate().unwrap();
    }
}
