//! HERON-SFL: hybrid zeroth/first-order split federated learning.
//!
//! Reproduction of *"Lean Clients, Full Accuracy: Hybrid Zeroth- and
//! First-Order Split Federated Learning"* as a three-layer Rust + JAX +
//! Pallas system (see DESIGN.md). This crate is the L3 coordinator: the
//! split-federated protocol, data plane, resource accounting, and analysis
//! tooling. Model compute executes through artifact entry points behind
//! [`runtime::Session`] — by default the deterministic native reference
//! engine (the offline vendor set has no XLA toolchain); Python is never
//! on the request path. The round driver fans independent client phases
//! out across a worker pool with bit-deterministic results for any worker
//! count (`--workers`).
//!
//! Layout:
//! * [`util`] — offline substrates (JSON, PRNG, CLI, worker pool,
//!   property testing)
//! * [`runtime`] — artifact manifest + native execution engine
//! * [`data`] — synthetic datasets + federated partitioning
//! * [`coordinator`] — the SFL protocol: algorithms, rounds, accounting
//! * [`net`] — wire protocol + transports for networked client↔server
//!   runs (`serve`/`connect`), bit-identical to the in-process driver
//! * [`metrics`] — run recording and reporting
//! * [`telemetry`] — flight recorder: spans (`span!`), the global
//!   metrics registry, and Chrome-trace export (`--trace_out`)
//! * [`zo`] — pure-Rust ZO reference + streaming perturbation (Remark 4)
//! * [`analysis`] — Hessian spectrum tooling (Fig 7)
//! * [`bench_harness`] — statistical micro-benchmark runner

pub mod analysis;
pub mod bench_harness;
pub mod coordinator;
pub mod golden;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod telemetry;
pub mod util;
pub mod zo;
