//! HERON-SFL: hybrid zeroth/first-order split federated learning.
//!
//! Reproduction of *"Lean Clients, Full Accuracy: Hybrid Zeroth- and
//! First-Order Split Federated Learning"* as a three-layer Rust + JAX +
//! Pallas system (see DESIGN.md). This crate is the L3 coordinator: the
//! split-federated protocol, data plane, resource accounting, and analysis
//! tooling. All model compute executes through AOT-compiled HLO artifacts
//! loaded by [`runtime::Session`]; Python is never on the request path.
//!
//! Layout:
//! * [`util`] — offline substrates (JSON, PRNG, CLI, property testing)
//! * [`runtime`] — PJRT artifact loading + invocation
//! * [`data`] — synthetic datasets + federated partitioning
//! * [`coordinator`] — the SFL protocol: algorithms, rounds, accounting
//! * [`metrics`] — run recording and reporting
//! * [`zo`] — pure-Rust ZO reference + streaming perturbation (Remark 4)
//! * [`analysis`] — Hessian spectrum tooling (Fig 7)
//! * [`bench_harness`] — statistical micro-benchmark runner

pub mod analysis;
pub mod bench_harness;
pub mod coordinator;
pub mod golden;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod util;
pub mod zo;
