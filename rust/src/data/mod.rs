//! Data plane: synthetic datasets, federated partitioning, batch loading.
//!
//! The generators mirror `python/compile/synth.py` (shared mix64 streams);
//! partitioning implements the paper's IID and Dirichlet(α) label-skew
//! settings (Fig 3a).

pub mod loader;
pub mod partition;
pub mod synth_text;
pub mod synth_vision;
