//! SynthE2E: slot-grammar restaurant corpus (substrate S7).
//!
//! Byte-identical mirror of `synth.e2e_record` / `synth.encode` in Python:
//! field choices come from the shared `mix64` stream, so record index i under
//! seed s is the same string in both languages (pinned by the golden test).

use crate::util::rng::mix64;

pub const SEQ_LEN: usize = 96;
pub const VOCAB: usize = 96; // printable ASCII 32..126 -> 1..95; pad/other -> 0
pub const PAD: i32 = 0;

pub const NAMES: [&str; 16] = [
    "Alimentum", "Aromi", "Blue Spice", "Clowns", "Cocum", "Cotto",
    "Fitzbillies", "Giraffe", "Green Man", "Loch Fyne", "Strada", "Zizzi",
    "The Mill", "The Eagle", "The Punter", "Wildwood",
];
pub const EATTYPE: [&str; 3] = ["pub", "restaurant", "coffee shop"];
pub const FOOD: [&str; 6] =
    ["Chinese", "English", "French", "Indian", "Italian", "Japanese"];
pub const PRICE: [&str; 3] = ["cheap", "moderate", "expensive"];
pub const AREA: [&str; 2] = ["city centre", "riverside"];
pub const RATING: [&str; 3] = ["low", "average", "high"];

fn pick<'a>(seed: u64, k: u64, options: &[&'a str]) -> &'a str {
    options[(mix64(seed, k) % options.len() as u64) as usize]
}

/// Fine-tuning-distribution record ("style 1" — exact mirror of python's
/// `e2e_record(style=1)`). The frozen base was pretrained on the style-0
/// layout; the reordered MR and new templates are the domain shift that LoRA
/// fine-tuning adapts to (paper §VI-C). MRs use 3-char abbreviations so the
/// worst-case record (94 chars) fits SEQ_LEN=96 without truncation.
pub fn record(seed: u64, index: u64) -> String {
    let base = index * 8;
    let name = pick(seed, base, &NAMES);
    let eat = pick(seed, base + 1, &EATTYPE);
    let food = pick(seed, base + 2, &FOOD);
    let price = pick(seed, base + 3, &PRICE);
    let area = pick(seed, base + 4, &AREA);
    let rating = pick(seed, base + 5, &RATING);
    let form = mix64(seed, base + 6) % 3;
    let mr = format!(
        "{};{};{};{};{};{name}>",
        &food[..3],
        &price[..3],
        &area[..3],
        &eat[..3],
        &rating[..3]
    );
    let text = match form {
        0 => format!("In {area}, {name} offers {price} {food} dishes."),
        1 => format!("{name}: {price} {food} cuisine, {rating} rating."),
        _ => format!("Visit {name} for {food} food at {price} prices."),
    };
    mr + &text
}

/// Byte-level tokenizer: printable ASCII -> 1..95, else PAD; pad/truncate to
/// SEQ_LEN.
pub fn encode_into(s: &str, out: &mut [i32]) {
    debug_assert_eq!(out.len(), SEQ_LEN);
    out.fill(PAD);
    for (i, b) in s.bytes().take(SEQ_LEN).enumerate() {
        out[i] = if (32..=126).contains(&b) {
            (b - 31) as i32
        } else {
            PAD
        };
    }
}

pub fn encode(s: &str) -> Vec<i32> {
    let mut out = vec![PAD; SEQ_LEN];
    encode_into(s, &mut out);
    out
}

pub fn batch_into(seed: u64, start: u64, count: usize, out: &mut [i32]) {
    debug_assert_eq!(out.len(), count * SEQ_LEN);
    for i in 0..count {
        let rec = record(seed, start + i as u64);
        encode_into(&rec, &mut out[i * SEQ_LEN..(i + 1) * SEQ_LEN]);
    }
}

pub fn batch(seed: u64, start: u64, count: usize) -> Vec<i32> {
    let mut out = vec![PAD; count * SEQ_LEN];
    batch_into(seed, start, count, &mut out);
    out
}

/// Decode tokens back to a string (diagnostics / examples).
pub fn decode(tokens: &[i32]) -> String {
    tokens
        .iter()
        .take_while(|&&t| t != PAD)
        .map(|&t| (t as u8 + 31) as char)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_deterministic_and_structured() {
        let r = record(42, 0);
        assert_eq!(r, record(42, 0));
        let (mr, text) = r.split_once('>').expect("has >");
        assert_eq!(mr.matches(';').count(), 5);
        assert!(text.len() > 10);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = "Hello, world!";
        let toks = encode(s);
        assert_eq!(decode(&toks), s);
        assert!(toks.iter().all(|&t| t >= 0 && (t as usize) < VOCAB));
    }

    #[test]
    fn records_fit_seq_len_mostly() {
        // grammar is designed so records fit in SEQ_LEN
        let over = (0..200).filter(|&i| record(1, i).len() > SEQ_LEN).count();
        assert_eq!(over, 0, "{over} records overflow SEQ_LEN");
    }

    #[test]
    fn batch_matches_scalar() {
        let b = batch(7, 3, 2);
        assert_eq!(&b[..SEQ_LEN], &encode(&record(7, 3))[..]);
        assert_eq!(&b[SEQ_LEN..], &encode(&record(7, 4))[..]);
    }

    #[test]
    fn corpus_has_diversity() {
        let mut distinct = std::collections::HashSet::new();
        for i in 0..100 {
            distinct.insert(record(11, i));
        }
        assert!(distinct.len() > 90);
    }
}
