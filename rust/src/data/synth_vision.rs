//! SynthCIFAR: 10-class procedural images (substrate S6).
//!
//! Mirrors `python/compile/synth.py` exactly: labels and the uniform noise
//! stream come from the shared `mix64` generator (bit-identical integers);
//! the sinusoidal base pattern matches to libm ulp differences (the golden
//! test in tests/golden.rs compares against digests the Python side wrote
//! into the manifest).

use crate::util::rng::{mix64, u01};

pub const H: usize = 16;
pub const W: usize = 16;
pub const C: usize = 3;
pub const CLASSES: usize = 10;
pub const PIXELS: usize = H * W * C;

const SIGNAL: f64 = 0.55;
const NOISE: f64 = 1.0;
const TWO_PI: f64 = std::f64::consts::TAU;

#[inline]
pub fn label(seed: u64, index: u64) -> u32 {
    (mix64(seed, index * 3) % CLASSES as u64) as u32
}

/// Write one image (HWC f32) into `out` (len PIXELS). Allocation-free so the
/// batch loader can reuse buffers on the hot path.
///
/// Class determines the grating frequencies and chroma tint; each *sample*
/// draws a random spatial phase and amplitude plus strong pixel noise (see
/// synth.vision_image — a fixed per-class pattern is learnable to 100%
/// within one federated round, which destroys the convergence curves).
pub fn image_into(seed: u64, index: u64, out: &mut [f32]) {
    debug_assert_eq!(out.len(), PIXELS);
    let lab = label(seed, index) as u64;
    let fu = (1 + lab % 3) as f64;
    let fv = (1 + (lab / 3) % 3) as f64;
    let tint = (lab % 4) as f64 * (TWO_PI / 3.0 / 4.0);
    let noise_seed = mix64(seed, index * 3 + 1);
    let nuis_seed = mix64(seed, index * 3 + 2);
    let r_phase = u01(nuis_seed, 0) * TWO_PI;
    let r_amp = 0.6 + 0.4 * u01(nuis_seed, 1);

    let mut p = 0usize;
    for h in 0..H {
        for w in 0..W {
            let base_arg = TWO_PI * (fu * h as f64 / H as f64
                + fv * w as f64 / W as f64)
                + r_phase;
            for c in 0..C {
                let base = (base_arg + c as f64 * tint).sin();
                let noise = 2.0 * (u01(noise_seed, p as u64) - 0.5);
                out[p] = (r_amp * SIGNAL * base + NOISE * noise) as f32;
                p += 1;
            }
        }
    }
}

pub fn image(seed: u64, index: u64) -> Vec<f32> {
    let mut out = vec![0.0; PIXELS];
    image_into(seed, index, &mut out);
    out
}

/// Fill a batch of `count` images/labels starting at `start` into the
/// provided buffers.
pub fn batch_into(
    seed: u64,
    start: u64,
    count: usize,
    xs: &mut [f32],
    ys: &mut [i32],
) {
    debug_assert_eq!(xs.len(), count * PIXELS);
    debug_assert_eq!(ys.len(), count);
    for i in 0..count {
        let idx = start + i as u64;
        image_into(seed, idx, &mut xs[i * PIXELS..(i + 1) * PIXELS]);
        ys[i] = label(seed, idx) as i32;
    }
}

pub fn batch(seed: u64, start: u64, count: usize) -> (Vec<f32>, Vec<i32>) {
    let mut xs = vec![0.0; count * PIXELS];
    let mut ys = vec![0; count];
    batch_into(seed, start, count, &mut xs, &mut ys);
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_cover_all_classes() {
        let mut seen = [false; CLASSES];
        for i in 0..500 {
            seen[label(1, i) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn images_deterministic() {
        assert_eq!(image(3, 7), image(3, 7));
        assert_ne!(image(3, 7), image(3, 8));
    }

    #[test]
    fn image_range_bounded() {
        let img = image(1, 0);
        assert!(img.iter().all(|v| v.abs() < 1.5));
    }

    #[test]
    fn batch_matches_scalar_api() {
        let (xs, ys) = batch(5, 10, 4);
        for j in 0..4 {
            assert_eq!(ys[j], label(5, 10 + j as u64) as i32);
            assert_eq!(&xs[j * PIXELS..(j + 1) * PIXELS], &image(5, 10 + j as u64)[..]);
        }
    }

    #[test]
    fn same_class_images_decorrelated_by_phase() {
        // the per-sample random phase is a translation nuisance: same-class
        // images must not be trivially pixel-correlated (otherwise the task
        // saturates within one federated round)
        let (i0, mut i1) = (0u64, 1u64);
        while label(9, i1) != label(9, i0) {
            i1 += 1;
        }
        let a = image(9, i0);
        let b = image(9, i1);
        let ma = a.iter().sum::<f32>() / a.len() as f32;
        let mb = b.iter().sum::<f32>() / b.len() as f32;
        let (mut num, mut da, mut db) = (0.0f64, 0.0f64, 0.0f64);
        for (x, y) in a.iter().zip(&b) {
            let (u, v) = ((x - ma) as f64, (y - mb) as f64);
            num += u * v;
            da += u * u;
            db += v * v;
        }
        assert!((num / (da * db).sqrt()).abs() < 0.9);
    }

    #[test]
    fn amplitude_jitter_within_bounds() {
        // signal amplitude in [0.6, 1.0]*SIGNAL plus noise in [-NOISE, NOISE]
        for i in 0..50 {
            let img = image(3, i);
            assert!(img.iter().all(|v| v.abs() <= (SIGNAL + NOISE) as f32 + 1e-5));
        }
    }
}
