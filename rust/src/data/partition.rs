//! Federated data partitioning (substrate S8).
//!
//! Assigns a virtual dataset (indices 0..n into the synthetic generators) to
//! N clients either IID or non-IID via the standard Dirichlet(α) label-skew
//! construction (paper Fig 3a). Deterministic given the seed.

use crate::data::synth_vision;
use crate::util::rng::Xoshiro256pp;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    Iid,
    /// Label-skewed: per class, proportions over clients ~ Dirichlet(alpha).
    Dirichlet { alpha: f64 },
}

#[derive(Debug, Clone)]
pub struct Partition {
    /// per-client list of sample indices (into the generator stream)
    pub clients: Vec<Vec<u64>>,
    pub scheme: Scheme,
}

impl Partition {
    /// Partition `n_samples` vision samples under `seed` across `n_clients`.
    pub fn vision(
        seed: u64,
        n_samples: u64,
        n_clients: usize,
        scheme: Scheme,
    ) -> Self {
        let mut rng = Xoshiro256pp::new(seed ^ 0x9A27);
        let clients = match scheme {
            Scheme::Iid => iid(&mut rng, n_samples, n_clients),
            Scheme::Dirichlet { alpha } => {
                // group indices by label, then split each class by a
                // Dirichlet draw over clients
                let mut by_class: Vec<Vec<u64>> =
                    vec![Vec::new(); synth_vision::CLASSES];
                for i in 0..n_samples {
                    by_class[synth_vision::label(seed, i) as usize].push(i);
                }
                let mut clients: Vec<Vec<u64>> = vec![Vec::new(); n_clients];
                for idxs in by_class {
                    let props = rng.dirichlet(alpha, n_clients);
                    // cumulative split of this class across clients
                    let total = idxs.len();
                    let mut start = 0usize;
                    let mut acc = 0.0f64;
                    for (c, p) in props.iter().enumerate() {
                        acc += p;
                        let end = if c + 1 == n_clients {
                            total
                        } else {
                            ((acc * total as f64).round() as usize).min(total)
                        };
                        clients[c].extend_from_slice(&idxs[start..end]);
                        start = end;
                    }
                }
                for c in &mut clients {
                    rng.shuffle(c);
                }
                clients
            }
        };
        Partition { clients, scheme }
    }

    /// Text partitioning: record streams are unlabeled, so non-IID is
    /// simulated by giving each client a distinct contiguous shard (distinct
    /// template/field statistics emerge from disjoint index ranges).
    pub fn text(
        seed: u64,
        n_samples: u64,
        n_clients: usize,
        scheme: Scheme,
    ) -> Self {
        let mut rng = Xoshiro256pp::new(seed ^ 0x7E27);
        let clients = match scheme {
            Scheme::Iid => iid(&mut rng, n_samples, n_clients),
            Scheme::Dirichlet { .. } => {
                let per = (n_samples as usize) / n_clients;
                (0..n_clients)
                    .map(|c| {
                        let s = c as u64 * per as u64;
                        (s..s + per as u64).collect()
                    })
                    .collect()
            }
        };
        Partition { clients, scheme }
    }

    pub fn sizes(&self) -> Vec<usize> {
        self.clients.iter().map(Vec::len).collect()
    }

    /// Fraction of samples on the most loaded client (skew diagnostic).
    pub fn max_share(&self) -> f64 {
        let total: usize = self.sizes().iter().sum();
        let max = self.sizes().into_iter().max().unwrap_or(0);
        if total == 0 {
            0.0
        } else {
            max as f64 / total as f64
        }
    }

    /// Per-client label histogram (vision only).
    pub fn label_histograms(&self, seed: u64) -> Vec<[usize; 10]> {
        self.clients
            .iter()
            .map(|idxs| {
                let mut h = [0usize; 10];
                for &i in idxs {
                    h[synth_vision::label(seed, i) as usize] += 1;
                }
                h
            })
            .collect()
    }
}

fn iid(rng: &mut Xoshiro256pp, n_samples: u64, n_clients: usize) -> Vec<Vec<u64>> {
    let mut all: Vec<u64> = (0..n_samples).collect();
    rng.shuffle(&mut all);
    let per = all.len() / n_clients;
    (0..n_clients)
        .map(|c| all[c * per..(c + 1) * per].to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_covers_disjoint() {
        let p = Partition::vision(1, 1000, 5, Scheme::Iid);
        let mut all: Vec<u64> = p.clients.concat();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "overlapping shards");
        assert_eq!(n, 1000);
        assert!(p.sizes().iter().all(|&s| s == 200));
    }

    #[test]
    fn dirichlet_disjoint_and_complete() {
        let p = Partition::vision(2, 2000, 10, Scheme::Dirichlet { alpha: 0.5 });
        let mut all: Vec<u64> = p.clients.concat();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
        assert_eq!(n, 2000);
    }

    #[test]
    fn dirichlet_alpha_controls_skew() {
        let spiky =
            Partition::vision(3, 5000, 10, Scheme::Dirichlet { alpha: 0.1 });
        let flat =
            Partition::vision(3, 5000, 10, Scheme::Dirichlet { alpha: 100.0 });
        // low alpha concentrates labels: max per-client class share higher
        let skew = |p: &Partition| -> f64 {
            p.label_histograms(3)
                .iter()
                .map(|h| {
                    let tot: usize = h.iter().sum();
                    if tot == 0 {
                        0.0
                    } else {
                        *h.iter().max().unwrap() as f64 / tot as f64
                    }
                })
                .sum::<f64>()
                / 10.0
        };
        assert!(skew(&spiky) > skew(&flat) + 0.1,
            "spiky {} flat {}", skew(&spiky), skew(&flat));
    }

    #[test]
    fn deterministic() {
        let a = Partition::vision(7, 500, 4, Scheme::Dirichlet { alpha: 0.3 });
        let b = Partition::vision(7, 500, 4, Scheme::Dirichlet { alpha: 0.3 });
        assert_eq!(a.clients, b.clients);
    }

    #[test]
    fn text_shards() {
        let p = Partition::text(1, 900, 3, Scheme::Dirichlet { alpha: 0.5 });
        assert_eq!(p.sizes(), vec![300, 300, 300]);
        // contiguous disjoint shards
        assert!(p.clients[0].iter().all(|&i| i < 300));
        assert!(p.clients[1].iter().all(|&i| (300..600).contains(&i)));
    }
}
