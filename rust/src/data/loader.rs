//! Batch loader over a client's shard of the virtual dataset.
//!
//! Buffers are reused across batches (the hot path allocates nothing after
//! warmup). Epoch order is a deterministic reshuffle of the shard.

use crate::data::{synth_text, synth_vision};
use crate::util::rng::Xoshiro256pp;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Task {
    Vision,
    Lm,
}

pub struct Loader {
    task: Task,
    data_seed: u64,
    shard: Vec<u64>,
    order: Vec<u32>,
    cursor: usize,
    epoch: u64,
    batch: usize,
    rng: Xoshiro256pp,
    // reused buffers
    pub xs_f32: Vec<f32>,
    pub xs_i32: Vec<i32>,
    pub ys: Vec<i32>,
}

impl Loader {
    pub fn new(
        task: Task,
        data_seed: u64,
        shard: Vec<u64>,
        batch: usize,
        shuffle_seed: u64,
    ) -> Self {
        assert!(!shard.is_empty(), "empty shard");
        let order: Vec<u32> = (0..shard.len() as u32).collect();
        let x_elems = match task {
            Task::Vision => batch * synth_vision::PIXELS,
            Task::Lm => batch * synth_text::SEQ_LEN,
        };
        let mut s = Self {
            task,
            data_seed,
            shard,
            order,
            cursor: 0,
            epoch: 0,
            batch,
            rng: Xoshiro256pp::new(shuffle_seed),
            xs_f32: vec![0.0; if task == Task::Vision { x_elems } else { 0 }],
            xs_i32: vec![0; if task == Task::Lm { x_elems } else { 0 }],
            ys: vec![0; batch],
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Fill the internal buffers with the next batch (wraps across epochs,
    /// sampling with replacement at the shard tail if needed).
    pub fn next_batch(&mut self) {
        for i in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            let idx = self.shard[self.order[self.cursor] as usize];
            self.cursor += 1;
            match self.task {
                Task::Vision => {
                    synth_vision::image_into(
                        self.data_seed,
                        idx,
                        &mut self.xs_f32
                            [i * synth_vision::PIXELS..(i + 1) * synth_vision::PIXELS],
                    );
                    self.ys[i] =
                        synth_vision::label(self.data_seed, idx) as i32;
                }
                Task::Lm => {
                    let rec = synth_text::record(self.data_seed, idx);
                    synth_text::encode_into(
                        &rec,
                        &mut self.xs_i32
                            [i * synth_text::SEQ_LEN..(i + 1) * synth_text::SEQ_LEN],
                    );
                }
            }
        }
        if self.task == Task::Lm {
            // LM target = input (next-token shift happens in-graph)
            self.ys.clear();
        }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }
}

/// Evaluation batch over a held-out range of the generator stream
/// (indices >= `holdout_start` are never assigned to clients).
pub fn eval_batch_vision(
    data_seed: u64,
    holdout_start: u64,
    count: usize,
) -> (Vec<f32>, Vec<i32>) {
    synth_vision::batch(data_seed, holdout_start, count)
}

pub fn eval_batch_text(
    data_seed: u64,
    holdout_start: u64,
    count: usize,
) -> Vec<i32> {
    synth_text::batch(data_seed, holdout_start, count)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_loader_cycles_epochs() {
        let mut l = Loader::new(Task::Vision, 1, (0..10).collect(), 4, 2);
        for _ in 0..10 {
            l.next_batch();
            assert_eq!(l.xs_f32.len(), 4 * synth_vision::PIXELS);
            assert_eq!(l.ys.len(), 4);
        }
        assert!(l.epoch() >= 3);
    }

    #[test]
    fn lm_loader_fills_tokens() {
        let mut l = Loader::new(Task::Lm, 1, (0..6).collect(), 2, 3);
        l.next_batch();
        assert_eq!(l.xs_i32.len(), 2 * synth_text::SEQ_LEN);
        assert!(l.xs_i32.iter().any(|&t| t != 0));
    }

    #[test]
    fn deterministic_given_seeds() {
        let mut a = Loader::new(Task::Vision, 5, (0..20).collect(), 4, 9);
        let mut b = Loader::new(Task::Vision, 5, (0..20).collect(), 4, 9);
        for _ in 0..5 {
            a.next_batch();
            b.next_batch();
            assert_eq!(a.ys, b.ys);
            assert_eq!(a.xs_f32, b.xs_f32);
        }
    }

    #[test]
    fn labels_match_generator() {
        let mut l = Loader::new(Task::Vision, 7, vec![3, 8, 1], 3, 1);
        l.next_batch();
        for &y in &l.ys {
            assert!((0..10).contains(&y));
        }
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn empty_shard_panics() {
        Loader::new(Task::Vision, 1, vec![], 4, 1);
    }
}
