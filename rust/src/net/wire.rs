//! Framed binary wire codec (substrate S20) for the SFL client↔server
//! protocol — hand-rolled like `util::json` (serde/bincode are not in the
//! offline vendor set).
//!
//! ## Frame layout (all integers little-endian)
//!
//! | offset | size | field                                   |
//! |-------:|-----:|-----------------------------------------|
//! | 0      | 2    | magic `b"HN"`                            |
//! | 2      | 1    | protocol version (`VERSION`)             |
//! | 3      | 1    | message tag                              |
//! | 4      | 4    | payload length `n` (u32)                 |
//! | 8      | n    | payload (per-message field layout)       |
//! | 8+n    | 4    | CRC-32 (poly 0xEDB88320) of bytes 0..8+n |
//!
//! Variable-length fields inside a payload are u32-length-prefixed;
//! `f32`/`f64` travel as their IEEE-754 bit patterns, so model parameters
//! cross the wire bit-exactly. Decoding never panics: truncation, bad
//! magic/version/tag, checksum mismatch, and malformed payloads all come
//! back as typed [`WireError`]s (property-tested against random
//! corruption in `rust/tests/net_wire.rs`).

use std::fmt;
use std::io::{Read, Write};

/// First two bytes of every frame.
pub const MAGIC: [u8; 2] = *b"HN";
/// Protocol version; bumped on any frame/payload layout change.
/// v2: `ZoUpdate` gained the per-probe `gscales` vector (the
/// `--zo_wire seeds` replay record).
/// v3: new `SmashedSeq` message (tag 13) — the `--drain stream` upload,
/// a `Smashed` extended with the client's per-round sequence number and
/// virtual send time. No existing payload layout changed (barrier-mode
/// frames differ from v2 only in this header version byte), but v2 and
/// v3 peers still refuse each other at the handshake, as for any bump.
/// v4: client multiplexing — one connection can carry many virtual
/// clients ("lanes"). `Hello` declares the connection's lane count,
/// `Assign` is sent once per lane and names it, and every client→server
/// upload (`ModelSync`, `ZoUpdate`, `Smashed`, `SmashedSeq`,
/// `LocalDone`) is stamped with the originating `lane` so the server
/// can validate ownership and upload sequencing per `(connection,
/// lane)`, not per connection. A classic single-client connection is
/// simply `lanes == 1`, lane id 0.
/// v5: churn + restore — `Assign` gains `rejoin_round` (the round index
/// the connection joins at: 0 for a fresh run, the current round for a
/// mid-run rejoin or a `--restore`d server) and `phases` (per assigned
/// client, how many completed local phases to fast-forward its data
/// stream by, so a rejoining/restored client resumes the exact batch
/// sequence an uninterrupted one would see). Both fields are decoded
/// unconditionally — v4 and v5 peers refuse each other at the
/// handshake, as for any bump.
/// v6: payload codecs — `Hello` gains `codecs` (the codec ids this
/// client can decode, see `net::codec::SUPPORTED`), and the
/// `Smashed`/`SmashedSeq` smashed field and the `CutGrad` gradient
/// become opaque self-describing codec envelopes (`Vec<u8>`, layout in
/// `net::codec`) instead of raw f32 vectors. Only the payload envelope
/// changed: frame framing/CRC and every v5 control message
/// (`Assign`/`ModelSync`/`ZoUpdate`/acks/barriers/…) are untouched.
/// v7: new `SeedSync` message (tag 14) — the `--zo_wire seed_agg`
/// dimension-free round sync. Past the bootstrap round the server
/// broadcasts, instead of a dense `ModelSync`, the previous round's
/// whole cohort as `(client id, FedAvg weight, per-step seeds,
/// per-probe gradient scalars)` and every client reconstructs the
/// aggregate θ_l locally via `zo::aggregate_trajectories`. SeedSync is
/// deliberately *exempt* from the v6 codec-envelope rule: its vectors
/// are raw typed fields (i32 seeds, f32 scalars, f64 weights) because
/// the replay contract is bit-exact — envelopes exist for the lossy
/// smashed/cut-grad payloads only. No existing payload layout changed.
pub const VERSION: u8 = 7;
/// Frame bytes that are not payload: 8-byte header + 4-byte CRC.
pub const FRAME_OVERHEAD: u64 = 12;
/// Upper bound on a payload (decoder rejects larger length fields before
/// allocating — a corrupt length must not OOM the peer).
pub const MAX_PAYLOAD: u32 = 1 << 28;
/// `ModelSync.client` value for a server→clients broadcast.
pub const BROADCAST: u32 = u32::MAX;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Frame (or payload field) ends before its declared length.
    Truncated,
    BadMagic([u8; 2]),
    BadVersion(u8),
    BadTag(u8),
    BadChecksum { want: u32, got: u32 },
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    TooLarge(u32),
    /// Structurally invalid payload (bad lengths, trailing bytes, …).
    Malformed(&'static str),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic(m) => write!(f, "bad magic {m:02x?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (speak {VERSION})")
            }
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::BadChecksum { want, got } => {
                write!(f, "checksum mismatch: frame says {want:08x}, computed {got:08x}")
            }
            WireError::TooLarge(n) => {
                write!(f, "payload length {n} exceeds cap {MAX_PAYLOAD}")
            }
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE, poly 0xEDB88320) — table generated at compile time
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[n] = c;
        n += 1;
    }
    t
}

const CRC_TABLE: [u32; 256] = crc_table();

/// Feed `data` into a running CRC state (start from `0xFFFF_FFFF`,
/// finalize by XORing with `0xFFFF_FFFF`) — lets `read_frame` checksum
/// header and payload from separate buffers without concatenating them.
fn crc32_feed(mut state: u32, data: &[u8]) -> u32 {
    for &b in data {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

pub fn crc32(data: &[u8]) -> u32 {
    crc32_feed(0xFFFF_FFFF, data) ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// The SFL protocol message set. One frame carries exactly one message.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// client → server: first message on a fresh connection. `lanes` is
    /// the number of virtual clients this connection multiplexes (v4);
    /// a plain `connect` declares 1. `codecs` (v6) advertises the
    /// payload codec ids this client can decode — the dispatcher
    /// validates its `RunConfig` codec choice against it and refuses
    /// the connection on a miss.
    Hello { name: String, protocol: u32, lanes: u32, codecs: Vec<u8> },
    /// server → client: logical client ids one lane owns + the full run
    /// config (exact-string JSON, see `RunConfig::to_json`). Sent once
    /// per declared lane, in lane order. `rejoin_round` is the round
    /// index this connection joins at (0 for a fresh run; the open
    /// round for a mid-run rejoin; the restored round after
    /// `serve --restore`) — a rejoining client must never replay a
    /// stale round. `phases` carries, per entry of `client_ids`, the
    /// number of completed local phases to fast-forward that client's
    /// data stream by (all zeros for a fresh run).
    Assign {
        lane: u32,
        client_ids: Vec<u32>,
        config: String,
        rejoin_round: u32,
        phases: Vec<u32>,
    },
    /// server → clients: a round is starting; `participants` is the
    /// sampled cohort (all connections learn it, participants act on it).
    RoundBarrier { round: u32, participants: Vec<u32> },
    /// Model parameters. Down: θ_l^t broadcast (`client == BROADCAST`,
    /// `lane == BROADCAST`) or a locked-phase kickoff for one client;
    /// up: a client's updated θ_l, stamped with its lane.
    ModelSync { lane: u32, round: u32, client: u32, theta: Vec<f32> },
    /// client → server: the lean per-step ZO record — counter-derived
    /// perturbation seeds plus one scalar (the step loss) per local step
    /// (paper Remark 4; FO baselines report the same shape). In
    /// `--zo_wire seeds` mode `gscales` additionally carries the
    /// flattened `h × n_p` per-probe gradient scalars and **replaces the
    /// θ upload entirely**: the server replays the update through
    /// `zo::replay_trajectory`, bit-identical to the client's own θ.
    /// Empty in `theta` mode.
    ZoUpdate {
        lane: u32,
        client: u32,
        round: u32,
        seeds: Vec<i32>,
        scalars: Vec<f32>,
        gscales: Vec<f32>,
    },
    /// client → server: one smashed-data upload (decoupled: enqueued for
    /// the barrier drain; locked: answered by a `CutGrad`). `smashed`
    /// (v6) is a self-describing codec envelope (`net::codec`) under
    /// the run's negotiated `--codec`; the dispatcher decodes it before
    /// consumption.
    Smashed {
        lane: u32,
        client: u32,
        round: u32,
        step: u32,
        smashed: Vec<u8>,
        targets: Vec<i32>,
    },
    /// client → server (`--drain stream` runs only): a smashed upload
    /// tagged for arrival-order consumption. `seq` is the client's
    /// per-round upload index (1-based, strictly increasing — the
    /// dispatcher rejects gaps or reordering, so a misbehaving
    /// transport cannot silently reshuffle the consumption schedule);
    /// `sent_at` is the client's virtual lane time at upload, feeding
    /// the event-sim's arrival-driven server-occupancy schedule.
    SmashedSeq {
        lane: u32,
        client: u32,
        round: u32,
        step: u32,
        seq: u32,
        sent_at: f64,
        smashed: Vec<u8>,
        targets: Vec<i32>,
    },
    /// server → client: locked-exchange reply — loss + cut gradient.
    /// `g` (v6) is a codec envelope under the run's `--grad_codec`.
    CutGrad { client: u32, round: u32, step: u32, loss: f32, g: Vec<u8> },
    /// server → client: FSL-SAGE alignment feedback (cut gradient for the
    /// client's last upload); answered by a `ModelSync` up.
    AlignGrad { client: u32, round: u32, g: Vec<f32> },
    /// server → client: receipt for a decoupled `Smashed` upload.
    /// `accepted == false` is the typed NACK for a queue-capacity drop.
    UploadAck {
        client: u32,
        round: u32,
        step: u32,
        accepted: bool,
        reason: String,
    },
    /// client → server: one logical client's local phase is complete;
    /// carries the client-side analytic accounting.
    LocalDone {
        lane: u32,
        client: u32,
        round: u32,
        comm_bytes: u64,
        flops: u64,
        lane_time: f64,
        lane_idle: f64,
    },
    /// server → clients: round epilogue (train-loss mean, analytic comm,
    /// measured wire bytes) — doubles as the next-round flow-control gate.
    RoundSummary {
        round: u32,
        train_loss: f64,
        comm_bytes: u64,
        wire_bytes: u64,
    },
    /// server → clients: the run is over; close the connection.
    Shutdown { reason: String },
    /// server → clients (v7, `--zo_wire seed_agg`): the dimension-free
    /// round sync replacing the dense θ_l `ModelSync` broadcast past
    /// the bootstrap round. Carries the *previous* round's cohort in
    /// the server's aggregation order: per participant `i`, its id
    /// `clients[i]`, its FedAvg weight `weights[i]`, its `h` per-step
    /// seeds `seeds[i·h .. (i+1)·h]`, and its `h·n_p` per-probe
    /// gradient scalars `gscales[i·h·n_p .. (i+1)·h·n_p]` (`h` and
    /// `n_p` come from the run config, so the flattening is
    /// self-describing). Receivers replay every record from their
    /// cached round-start θ_l and FedAvg-accumulate in shipped order
    /// (`zo::aggregate_trajectories`) — bit-identical to the dense
    /// broadcast they would have received.
    SeedSync {
        round: u32,
        clients: Vec<u32>,
        weights: Vec<f64>,
        seeds: Vec<i32>,
        gscales: Vec<f32>,
    },
}

impl Msg {
    pub fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Assign { .. } => 2,
            Msg::RoundBarrier { .. } => 3,
            Msg::ModelSync { .. } => 4,
            Msg::ZoUpdate { .. } => 5,
            Msg::Smashed { .. } => 6,
            Msg::CutGrad { .. } => 7,
            Msg::AlignGrad { .. } => 8,
            Msg::UploadAck { .. } => 9,
            Msg::LocalDone { .. } => 10,
            Msg::RoundSummary { .. } => 11,
            Msg::Shutdown { .. } => 12,
            Msg::SmashedSeq { .. } => 13,
            Msg::SeedSync { .. } => 14,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::Assign { .. } => "Assign",
            Msg::RoundBarrier { .. } => "RoundBarrier",
            Msg::ModelSync { .. } => "ModelSync",
            Msg::ZoUpdate { .. } => "ZoUpdate",
            Msg::Smashed { .. } => "Smashed",
            Msg::CutGrad { .. } => "CutGrad",
            Msg::AlignGrad { .. } => "AlignGrad",
            Msg::UploadAck { .. } => "UploadAck",
            Msg::LocalDone { .. } => "LocalDone",
            Msg::RoundSummary { .. } => "RoundSummary",
            Msg::Shutdown { .. } => "Shutdown",
            Msg::SmashedSeq { .. } => "SmashedSeq",
            Msg::SeedSync { .. } => "SeedSync",
        }
    }
}

const MIN_TAG: u8 = 1;
const MAX_TAG: u8 = 14;

// ---------------------------------------------------------------------------
// payload writer
// ---------------------------------------------------------------------------

struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_u8(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    fn vec_u32(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
    }
    fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn vec_f32(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }
    fn vec_f64(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f64(x);
        }
    }
}

// ---------------------------------------------------------------------------
// payload reader (bounds-checked; never panics)
// ---------------------------------------------------------------------------

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.b.len() - self.pos < n {
            return Err(WireError::Truncated);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32, WireError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Validated element count for a length-prefixed vector: the declared
    /// count must fit in the remaining bytes *before* anything allocates.
    fn vec_len(&mut self, elem_size: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let remaining = self.b.len() - self.pos;
        match n.checked_mul(elem_size) {
            Some(bytes) if bytes <= remaining => Ok(n),
            _ => Err(WireError::Malformed("vector length exceeds payload")),
        }
    }
    fn str(&mut self) -> Result<String, WireError> {
        let n = self.vec_len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("non-utf8 string"))
    }
    fn vec_u8(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.vec_len(1)?;
        Ok(self.take(n)?.to_vec())
    }
    fn vec_u32(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.vec_len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }
    fn vec_i32(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.vec_len(4)?;
        (0..n).map(|_| self.i32()).collect()
    }
    fn vec_f32(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.vec_len(4)?;
        (0..n).map(|_| self.f32()).collect()
    }
    fn vec_f64(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.vec_len(8)?;
        (0..n).map(|_| self.f64()).collect()
    }
    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::Malformed("trailing payload bytes"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------------

fn encode_payload(msg: &Msg, w: &mut Wr) {
    match msg {
        Msg::Hello { name, protocol, lanes, codecs } => {
            w.str(name);
            w.u32(*protocol);
            w.u32(*lanes);
            w.vec_u8(codecs);
        }
        Msg::Assign { lane, client_ids, config, rejoin_round, phases } => {
            w.u32(*lane);
            w.vec_u32(client_ids);
            w.str(config);
            w.u32(*rejoin_round);
            w.vec_u32(phases);
        }
        Msg::RoundBarrier { round, participants } => {
            w.u32(*round);
            w.vec_u32(participants);
        }
        Msg::ModelSync { lane, round, client, theta } => {
            w.u32(*lane);
            w.u32(*round);
            w.u32(*client);
            w.vec_f32(theta);
        }
        Msg::ZoUpdate { lane, client, round, seeds, scalars, gscales } => {
            w.u32(*lane);
            w.u32(*client);
            w.u32(*round);
            w.vec_i32(seeds);
            w.vec_f32(scalars);
            w.vec_f32(gscales);
        }
        Msg::Smashed { lane, client, round, step, smashed, targets } => {
            w.u32(*lane);
            w.u32(*client);
            w.u32(*round);
            w.u32(*step);
            w.vec_u8(smashed);
            w.vec_i32(targets);
        }
        Msg::SmashedSeq {
            lane,
            client,
            round,
            step,
            seq,
            sent_at,
            smashed,
            targets,
        } => {
            w.u32(*lane);
            w.u32(*client);
            w.u32(*round);
            w.u32(*step);
            w.u32(*seq);
            w.f64(*sent_at);
            w.vec_u8(smashed);
            w.vec_i32(targets);
        }
        Msg::CutGrad { client, round, step, loss, g } => {
            w.u32(*client);
            w.u32(*round);
            w.u32(*step);
            w.f32(*loss);
            w.vec_u8(g);
        }
        Msg::AlignGrad { client, round, g } => {
            w.u32(*client);
            w.u32(*round);
            w.vec_f32(g);
        }
        Msg::UploadAck { client, round, step, accepted, reason } => {
            w.u32(*client);
            w.u32(*round);
            w.u32(*step);
            w.u8(*accepted as u8);
            w.str(reason);
        }
        Msg::LocalDone {
            lane,
            client,
            round,
            comm_bytes,
            flops,
            lane_time,
            lane_idle,
        } => {
            w.u32(*lane);
            w.u32(*client);
            w.u32(*round);
            w.u64(*comm_bytes);
            w.u64(*flops);
            w.f64(*lane_time);
            w.f64(*lane_idle);
        }
        Msg::RoundSummary { round, train_loss, comm_bytes, wire_bytes } => {
            w.u32(*round);
            w.f64(*train_loss);
            w.u64(*comm_bytes);
            w.u64(*wire_bytes);
        }
        Msg::Shutdown { reason } => {
            w.str(reason);
        }
        Msg::SeedSync { round, clients, weights, seeds, gscales } => {
            w.u32(*round);
            w.vec_u32(clients);
            w.vec_f64(weights);
            w.vec_i32(seeds);
            w.vec_f32(gscales);
        }
    }
}

fn decode_payload(tag: u8, payload: &[u8]) -> Result<Msg, WireError> {
    let mut r = Rd { b: payload, pos: 0 };
    let msg = match tag {
        1 => Msg::Hello {
            name: r.str()?,
            protocol: r.u32()?,
            lanes: r.u32()?,
            codecs: r.vec_u8()?,
        },
        2 => Msg::Assign {
            lane: r.u32()?,
            client_ids: r.vec_u32()?,
            config: r.str()?,
            rejoin_round: r.u32()?,
            phases: r.vec_u32()?,
        },
        3 => Msg::RoundBarrier { round: r.u32()?, participants: r.vec_u32()? },
        4 => Msg::ModelSync {
            lane: r.u32()?,
            round: r.u32()?,
            client: r.u32()?,
            theta: r.vec_f32()?,
        },
        5 => Msg::ZoUpdate {
            lane: r.u32()?,
            client: r.u32()?,
            round: r.u32()?,
            seeds: r.vec_i32()?,
            scalars: r.vec_f32()?,
            gscales: r.vec_f32()?,
        },
        6 => Msg::Smashed {
            lane: r.u32()?,
            client: r.u32()?,
            round: r.u32()?,
            step: r.u32()?,
            smashed: r.vec_u8()?,
            targets: r.vec_i32()?,
        },
        7 => Msg::CutGrad {
            client: r.u32()?,
            round: r.u32()?,
            step: r.u32()?,
            loss: r.f32()?,
            g: r.vec_u8()?,
        },
        8 => Msg::AlignGrad {
            client: r.u32()?,
            round: r.u32()?,
            g: r.vec_f32()?,
        },
        9 => Msg::UploadAck {
            client: r.u32()?,
            round: r.u32()?,
            step: r.u32()?,
            accepted: match r.u8()? {
                0 => false,
                1 => true,
                _ => return Err(WireError::Malformed("bool out of range")),
            },
            reason: r.str()?,
        },
        10 => Msg::LocalDone {
            lane: r.u32()?,
            client: r.u32()?,
            round: r.u32()?,
            comm_bytes: r.u64()?,
            flops: r.u64()?,
            lane_time: r.f64()?,
            lane_idle: r.f64()?,
        },
        11 => Msg::RoundSummary {
            round: r.u32()?,
            train_loss: r.f64()?,
            comm_bytes: r.u64()?,
            wire_bytes: r.u64()?,
        },
        12 => Msg::Shutdown { reason: r.str()? },
        13 => Msg::SmashedSeq {
            lane: r.u32()?,
            client: r.u32()?,
            round: r.u32()?,
            step: r.u32()?,
            seq: r.u32()?,
            sent_at: r.f64()?,
            smashed: r.vec_u8()?,
            targets: r.vec_i32()?,
        },
        14 => Msg::SeedSync {
            round: r.u32()?,
            clients: r.vec_u32()?,
            weights: r.vec_f64()?,
            seeds: r.vec_i32()?,
            gscales: r.vec_f32()?,
        },
        t => return Err(WireError::BadTag(t)),
    };
    r.finish()?;
    Ok(msg)
}

/// Encode one message as a complete frame (header + payload + CRC).
pub fn encode_frame(msg: &Msg) -> Vec<u8> {
    let mut w = Wr { buf: Vec::with_capacity(64) };
    // header placeholder, then payload, then backfill the length
    w.buf.extend_from_slice(&MAGIC);
    w.u8(VERSION);
    w.u8(msg.tag());
    w.u32(0);
    encode_payload(msg, &mut w);
    let plen = (w.buf.len() - 8) as u32;
    w.buf[4..8].copy_from_slice(&plen.to_le_bytes());
    let crc = crc32(&w.buf);
    w.buf.extend_from_slice(&crc.to_le_bytes());
    w.buf
}

/// Decode one frame from the front of `buf`. Returns the message and the
/// total frame size consumed. Never panics on hostile input.
pub fn decode_frame(buf: &[u8]) -> Result<(Msg, usize), WireError> {
    if buf.len() < 8 {
        return Err(WireError::Truncated);
    }
    if buf[0..2] != MAGIC {
        return Err(WireError::BadMagic([buf[0], buf[1]]));
    }
    if buf[2] != VERSION {
        return Err(WireError::BadVersion(buf[2]));
    }
    let tag = buf[3];
    if !(MIN_TAG..=MAX_TAG).contains(&tag) {
        return Err(WireError::BadTag(tag));
    }
    let plen = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if plen > MAX_PAYLOAD {
        return Err(WireError::TooLarge(plen));
    }
    let total = 8 + plen as usize + 4;
    if buf.len() < total {
        return Err(WireError::Truncated);
    }
    let body = &buf[..8 + plen as usize];
    let want =
        u32::from_le_bytes(buf[8 + plen as usize..total].try_into().unwrap());
    let got = crc32(body);
    if want != got {
        return Err(WireError::BadChecksum { want, got });
    }
    let msg = decode_payload(tag, &buf[8..8 + plen as usize])?;
    Ok((msg, total))
}

// ---------------------------------------------------------------------------
// blocking stream I/O
// ---------------------------------------------------------------------------

/// `encode_frame` + sender-side payload cap: a frame no compliant
/// decoder would accept must fail at the source, not at the receiver.
pub fn encode_frame_checked(msg: &Msg) -> Result<Vec<u8>, WireError> {
    let frame = encode_frame(msg);
    let plen = frame.len() as u64 - FRAME_OVERHEAD;
    if plen > MAX_PAYLOAD as u64 {
        return Err(WireError::TooLarge(plen.min(u32::MAX as u64) as u32));
    }
    Ok(frame)
}

/// Write one framed message; returns the bytes put on the wire.
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> std::io::Result<u64> {
    let frame = encode_frame_checked(msg).map_err(wire_io)?;
    w.write_all(&frame)?;
    w.flush()?;
    Ok(frame.len() as u64)
}

/// Read one framed message (blocking). Returns `Ok(None)` on a clean EOF
/// at a frame boundary (peer closed); mid-frame EOF and every codec
/// violation surface as errors.
pub fn read_frame(
    r: &mut impl Read,
) -> std::io::Result<Option<(Msg, u64)>> {
    let mut header = [0u8; 8];
    let mut filled = 0usize;
    while filled < 8 {
        let n = r.read(&mut header[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(None); // clean close between frames
            }
            return Err(wire_io(WireError::Truncated));
        }
        filled += n;
    }
    if header[0..2] != MAGIC {
        return Err(wire_io(WireError::BadMagic([header[0], header[1]])));
    }
    if header[2] != VERSION {
        return Err(wire_io(WireError::BadVersion(header[2])));
    }
    let tag = header[3];
    if !(MIN_TAG..=MAX_TAG).contains(&tag) {
        return Err(wire_io(WireError::BadTag(tag)));
    }
    let plen = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if plen > MAX_PAYLOAD {
        return Err(wire_io(WireError::TooLarge(plen)));
    }
    let mut rest = vec![0u8; plen as usize + 4];
    r.read_exact(&mut rest).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            wire_io(WireError::Truncated)
        } else {
            e
        }
    })?;
    let payload = &rest[..plen as usize];
    let want =
        u32::from_le_bytes(rest[plen as usize..].try_into().unwrap());
    let got =
        crc32_feed(crc32_feed(0xFFFF_FFFF, &header), payload) ^ 0xFFFF_FFFF;
    if want != got {
        return Err(wire_io(WireError::BadChecksum { want, got }));
    }
    let msg = decode_payload(tag, payload).map_err(wire_io)?;
    Ok(Some((msg, FRAME_OVERHEAD + plen as u64)))
}

fn wire_io(e: WireError) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<Msg> {
        vec![
            Msg::Hello {
                name: "edge-0".into(),
                protocol: 1,
                lanes: 64,
                codecs: crate::net::codec::SUPPORTED.to_vec(),
            },
            Msg::Assign {
                lane: 7,
                client_ids: vec![0, 2, 4],
                config: "{\"variant\": \"cnn_c1\"}".into(),
                rejoin_round: 2,
                phases: vec![1, 0, 2],
            },
            Msg::RoundBarrier { round: 3, participants: vec![1, 2] },
            Msg::ModelSync {
                lane: BROADCAST,
                round: 3,
                client: BROADCAST,
                theta: vec![1.5, -0.25, f32::MIN_POSITIVE],
            },
            Msg::ZoUpdate {
                lane: 1,
                client: 2,
                round: 3,
                seeds: vec![-7, 12345],
                scalars: vec![0.5, 2.25],
                gscales: vec![0.125, -0.0625, 1.5, -2.0],
            },
            Msg::Smashed {
                lane: 0,
                client: 1,
                round: 0,
                step: 2,
                smashed: crate::net::codec::encode_f32(&[0.0; 8]),
                targets: vec![3, 1, 4],
            },
            Msg::SmashedSeq {
                lane: 3,
                client: 1,
                round: 0,
                step: 2,
                seq: 1,
                sent_at: 3.5,
                smashed: crate::net::codec::encode_int8(&[0.25; 8]),
                targets: vec![3, 1, 4],
            },
            Msg::CutGrad {
                client: 1,
                round: 0,
                step: 2,
                loss: 2.75,
                g: crate::net::codec::encode_f32(&[-1.0, 1.0]),
            },
            Msg::AlignGrad { client: 4, round: 9, g: vec![0.125] },
            Msg::UploadAck {
                client: 1,
                round: 0,
                step: 2,
                accepted: false,
                reason: "queue full".into(),
            },
            Msg::LocalDone {
                lane: 2,
                client: 5,
                round: 7,
                comm_bytes: 1 << 40,
                flops: 123456789,
                lane_time: 0.75,
                lane_idle: 0.0,
            },
            Msg::RoundSummary {
                round: 7,
                train_loss: 1.875,
                comm_bytes: 4096,
                wire_bytes: 5000,
            },
            Msg::Shutdown { reason: "done".into() },
            // 2 participants × h=2 steps × n_p=2 probes, f64 weights
            Msg::SeedSync {
                round: 4,
                clients: vec![1, 3],
                weights: vec![0.375, 0.625],
                seeds: vec![-11, 42, 7, -9],
                gscales: vec![
                    0.5, -0.25, 0.125, -2.0, 1.0, 0.75, -0.5, 0.0625,
                ],
            },
        ]
    }

    #[test]
    fn crc32_reference_vector() {
        // the canonical CRC-32/ISO-HDLC check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn every_message_roundtrips() {
        for msg in samples() {
            let frame = encode_frame(&msg);
            let (back, used) = decode_frame(&frame).unwrap();
            assert_eq!(used, frame.len(), "{}", msg.name());
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn tags_are_unique_and_in_range() {
        let mut seen = std::collections::BTreeSet::new();
        for msg in samples() {
            assert!((MIN_TAG..=MAX_TAG).contains(&msg.tag()));
            assert!(seen.insert(msg.tag()), "duplicate tag {}", msg.tag());
        }
        assert_eq!(seen.len(), (MAX_TAG - MIN_TAG + 1) as usize);
    }

    #[test]
    fn truncation_is_rejected_at_every_length() {
        let frame = encode_frame(&samples()[4]);
        for cut in 0..frame.len() {
            let err = decode_frame(&frame[..cut]).unwrap_err();
            assert_eq!(err, WireError::Truncated, "cut at {cut}");
        }
    }

    #[test]
    fn corruption_is_detected() {
        let frame = encode_frame(&samples()[3]);
        // payload flip → checksum
        let mut f = frame.clone();
        f[10] ^= 0x40;
        assert!(matches!(
            decode_frame(&f).unwrap_err(),
            WireError::BadChecksum { .. }
        ));
        // version byte → BadVersion (before checksum)
        let mut f = frame.clone();
        f[2] = 9;
        assert_eq!(decode_frame(&f).unwrap_err(), WireError::BadVersion(9));
        // unknown tag → BadTag
        let mut f = frame.clone();
        f[3] = 200;
        assert_eq!(decode_frame(&f).unwrap_err(), WireError::BadTag(200));
        // magic → BadMagic
        let mut f = frame;
        f[0] = b'X';
        assert!(matches!(
            decode_frame(&f).unwrap_err(),
            WireError::BadMagic(_)
        ));
    }

    #[test]
    fn oversized_length_rejected_without_allocating() {
        let mut f = encode_frame(&samples()[0]);
        f[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(
            decode_frame(&f).unwrap_err(),
            WireError::TooLarge(MAX_PAYLOAD + 1)
        );
    }

    #[test]
    fn stream_io_roundtrips_and_counts_bytes() {
        let mut buf: Vec<u8> = Vec::new();
        let mut want_bytes = 0u64;
        for msg in samples() {
            want_bytes += write_frame(&mut buf, &msg).unwrap();
        }
        assert_eq!(want_bytes as usize, buf.len());
        let mut cur = std::io::Cursor::new(buf);
        let mut got = Vec::new();
        let mut got_bytes = 0u64;
        while let Some((m, n)) = read_frame(&mut cur).unwrap() {
            got_bytes += n;
            got.push(m);
        }
        assert_eq!(got, samples());
        assert_eq!(got_bytes, want_bytes);
    }

    #[test]
    fn mid_frame_eof_errors_clean_eof_is_none() {
        let frame = encode_frame(&samples()[0]);
        // EOF in the middle of a frame is a hard error...
        let mut cur =
            std::io::Cursor::new(frame[..frame.len() - 1].to_vec());
        assert!(read_frame(&mut cur).is_err());
        // ...but a close at a frame boundary is a clean end-of-stream.
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());
    }
}
