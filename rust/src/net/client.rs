//! Networked client endpoint: drives the local ZO/FO phase of one or more
//! logical clients against a remote `heron-sfl serve` dispatcher.
//!
//! One connection can multiplex many **virtual clients**: `connect
//! --virtual N` declares N lanes in its `Hello` and receives one
//! `Assign{lane, ..}` per lane, so N simulated edge devices ride a
//! single socket. Every upload is stamped with its lane id and (in
//! `--drain stream` runs) a per-lane strictly-increasing sequence
//! number, which is what lets the dispatcher validate ordering per
//! `(conn, lane)` instead of per connection. Per-client model state is
//! materialized lazily ([`ClientPool`]) on first participation, so a
//! storm client fronting thousands of registered-but-rarely-sampled
//! devices does not pay O(population) memory up front.
//!
//! The endpoint is deliberately thin: after the `Hello`/`Assign`
//! handshake it reconstructs the *exact* run setup the server uses — the
//! config arrives as exact-string JSON (`RunConfig::to_json`, identical
//! across all lanes), the client states come from the same
//! [`ClientPool`] construction, and every step runs the same
//! `coordinator::local` functions the in-process driver fans out to its
//! worker pool. Control payloads carry bit-exact f32, and the codec'd
//! payloads (smashed/cut-grad, v6) follow the encode-once rule of
//! `net::codec` — the envelope this endpoint ships is byte-identical to
//! the one the in-process transcode produces — so the trajectory cannot
//! diverge from `Driver::run_round`, however the clients are spread
//! over sockets and lanes.
//!
//! Message handling is a single blocking loop:
//!
//! * `RoundBarrier` — remember `(round, participants)`, reset the
//!   per-lane upload sequence counters.
//! * `ModelSync{client: BROADCAST}` — decoupled fan-out: run
//!   `client_local_phase` for each owned participant (ascending id
//!   across all lanes, matching the in-process job order), with a sink
//!   that ships `Smashed` frames (`SmashedSeq`, carrying the lane's
//!   upload sequence number + virtual send time, in `--drain stream`
//!   runs) and blocks on the `UploadAck` (counting typed NACKs per
//!   lane); reply `ZoUpdate` (per-step seeds + loss scalars — plus the
//!   per-probe `gscales` in `--zo_wire seeds` mode, which then
//!   **replaces** the θ upload), `ModelSync` (updated θ, `theta` mode
//!   only), `LocalDone` (analytic counters).
//! * `SeedSync` — wire v7 lean broadcast (`--zo_wire seed_agg`): the
//!   previous round's aggregated `(client, weight, seeds, gscales)`
//!   roster instead of a dense θ_l; this endpoint reconstructs the
//!   round-start θ_l locally via `zo::aggregate_trajectories` from the
//!   cached previous sync (bit-identical to the server's own
//!   aggregation), caches it, then runs the same decoupled fan-out.
//! * `ModelSync{client: ci}` — locked SFLV1/V2 phase for `ci`: per step,
//!   cut forward → `Smashed` → wait `CutGrad` → backprop; then θ up.
//! * `AlignGrad` — FSL-SAGE: `aux_align` against the stored last upload,
//!   reply the realigned θ.
//! * `RoundSummary` — bookkeeping; `Shutdown` — return the report.

use crate::coordinator::accounting::CostBook;
use crate::coordinator::config::RunConfig;
use crate::coordinator::drain::DrainMode;
use crate::coordinator::eventsim::{DeviceProfile, WireRoundStats};
use crate::coordinator::local::{
    self, ClientPool, ClientState, LocalCtx, SmashedSink, UploadTag,
};
use crate::coordinator::round::OptState;
use crate::coordinator::server_queue::SmashedBatch;
use crate::data::loader::Task;
use crate::net::codec;
use crate::net::transport::Transport;
use crate::net::wire::{Msg, BROADCAST, VERSION};
use crate::runtime::Session;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// End-of-run statistics from one client process.
#[derive(Debug, Clone)]
pub struct ClientReport {
    pub name: String,
    pub assigned: Vec<u32>,
    /// virtual-client lanes driven through this connection
    pub lanes: usize,
    /// logical clients assigned to each lane
    pub lane_clients: Vec<usize>,
    /// rounds observed (RoundSummary count)
    pub rounds: usize,
    /// local phases executed (decoupled + locked), all lanes
    pub phases: u64,
    /// local phases executed per lane
    pub lane_phases: Vec<u64>,
    /// uploads rejected by the server queue (typed NACKs received)
    pub nacks: u64,
    /// typed NACKs received per lane
    pub lane_nacks: Vec<u64>,
    pub wire: WireRoundStats,
    pub shutdown_reason: String,
}

/// Marker error: the server said `Shutdown` while this endpoint was in
/// the middle of an exchange (e.g. blocked on an `UploadAck`). It
/// unwinds the phase like any error but the main loop recognizes it and
/// turns it into a *clean* exit — a server that checkpoints and shuts
/// down mid-round must not make its clients exit non-zero.
#[derive(Debug)]
struct CleanShutdown(String);

impl std::fmt::Display for CleanShutdown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server shutdown: {}", self.0)
    }
}

impl std::error::Error for CleanShutdown {}

/// If `e` is (or wraps) a [`CleanShutdown`], the shutdown reason.
fn as_shutdown(e: &anyhow::Error) -> Option<String> {
    e.chain()
        .find_map(|c| c.downcast_ref::<CleanShutdown>())
        .map(|s| s.0.clone())
}

fn send(t: &Mutex<Box<dyn Transport>>, msg: &Msg) -> Result<()> {
    t.lock().unwrap_or_else(|p| p.into_inner()).send(msg)
}

fn recv(t: &Mutex<Box<dyn Transport>>) -> Result<Option<Msg>> {
    t.lock().unwrap_or_else(|p| p.into_inner()).recv()
}

/// The networked [`SmashedSink`]: every push is a framed upload with a
/// blocking `UploadAck` round-trip; `accepted == false` (the server's
/// typed NACK for a queue-capacity drop) is counted and reported back as
/// "dropped", mirroring the in-process `ServerQueue::push` contract. In
/// a `--drain stream` run the upload travels as `SmashedSeq` — the
/// barrier `Smashed` layout extended with the **lane's** per-round
/// upload sequence number (stamped here, continuous across every client
/// the lane runs this round — the exact counter the dispatcher validates
/// per `(conn, lane)`) and the virtual send time.
struct NetSink<'a> {
    t: &'a Mutex<Box<dyn Transport>>,
    /// local lane id this phase runs on; stamped into every upload
    lane: u32,
    /// the lane's per-round upload counter (shared across the lane's
    /// clients, reset at each RoundBarrier)
    seq: &'a AtomicU32,
    nacks: &'a AtomicU64,
    err: Mutex<Option<anyhow::Error>>,
    /// `--drain stream`: ship `SmashedSeq` instead of `Smashed`
    stream: bool,
}

impl NetSink<'_> {
    fn exchange(
        &self,
        b: SmashedBatch,
        tag: UploadTag,
        enc: Option<Vec<u8>>,
    ) -> Result<bool> {
        let (up_client, up_step) = (b.client, b.step);
        // encode-once: a lossy codec already produced the envelope at
        // the producer (`local::upload_smashed`) — ship it verbatim;
        // under the default f32 codec the identity envelope is built
        // here from the exact batch values
        let smashed =
            enc.unwrap_or_else(|| codec::encode_f32(&b.smashed));
        let mut g = self.t.lock().unwrap_or_else(|p| p.into_inner());
        let msg = if self.stream {
            Msg::SmashedSeq {
                lane: self.lane,
                client: b.client as u32,
                round: b.round as u32,
                step: b.step as u32,
                seq: self.seq.fetch_add(1, Ordering::Relaxed) + 1,
                sent_at: tag.sent_at,
                smashed,
                targets: b.targets,
            }
        } else {
            Msg::Smashed {
                lane: self.lane,
                client: b.client as u32,
                round: b.round as u32,
                step: b.step as u32,
                smashed,
                targets: b.targets,
            }
        };
        g.send(&msg)?;
        let _ack = crate::span!("upload_ack_wait", client = up_client, step = up_step);
        match g.recv()? {
            Some(Msg::UploadAck { accepted, reason, .. }) => {
                if !accepted {
                    self.nacks.fetch_add(1, Ordering::Relaxed);
                    log::warn!("upload NACKed: {reason}");
                }
                Ok(accepted)
            }
            Some(Msg::Shutdown { reason }) => {
                Err(anyhow::Error::new(CleanShutdown(reason)))
            }
            other => bail!("expected UploadAck, got {other:?}"),
        }
    }
}

impl SmashedSink for NetSink<'_> {
    fn push_smashed(
        &self,
        b: SmashedBatch,
        tag: UploadTag,
        enc: Option<Vec<u8>>,
    ) -> bool {
        // latch: after one failed exchange the transport is in an unknown
        // state — never touch it again from this phase (a blocked recv
        // here would deadlock client and server), just let the phase
        // finish so the caller sees the stored error
        {
            let g = self.err.lock().unwrap_or_else(|p| p.into_inner());
            if g.is_some() {
                return false;
            }
        }
        match self.exchange(b, tag, enc) {
            Ok(accepted) => accepted,
            Err(e) => {
                *self.err.lock().unwrap_or_else(|p| p.into_inner()) = Some(e);
                false
            }
        }
    }
}

/// Connect-side entry point: handshake with a single lane, then serve
/// rounds until the dispatcher says `Shutdown`.
pub fn run_client(
    session: &Session,
    transport: Box<dyn Transport>,
    name: &str,
) -> Result<ClientReport> {
    run_client_virtual(session, transport, name, 1)
}

/// Connect-side entry point multiplexing `lanes` virtual clients over
/// one connection (`connect --virtual N`): the `Hello` declares the lane
/// count, one `Assign` arrives per lane, and every upload is stamped
/// with its lane. Per-client model state materializes lazily on first
/// participation.
pub fn run_client_virtual(
    session: &Session,
    transport: Box<dyn Transport>,
    name: &str,
    lanes: usize,
) -> Result<ClientReport> {
    if lanes == 0 {
        bail!("connect: need at least one lane");
    }
    crate::telemetry::trace::set_thread_label(&format!("client-{name}"));
    let counters = transport.counters();
    let t = Mutex::new(transport);
    send(&t, &Msg::Hello {
        name: name.into(),
        protocol: VERSION as u32,
        lanes: lanes as u32,
        codecs: codec::SUPPORTED.to_vec(),
    })?;

    // one Assign per declared lane, in lane order; every lane carries
    // the identical exact-string config
    let mut assigned: Vec<u32> = Vec::new();
    let mut lane_of: BTreeMap<usize, u32> = BTreeMap::new();
    let mut cfg_json: Option<String> = None;
    // restore/rejoin handshake: the round the run resumes at, plus how
    // many local phases each assigned client has already completed —
    // the fast-forward distance for its data stream
    let mut resume_round = 0u32;
    let mut phase_done: BTreeMap<usize, u32> = BTreeMap::new();
    for k in 0..lanes as u32 {
        match recv(&t)? {
            Some(Msg::Assign {
                lane,
                client_ids,
                config,
                rejoin_round,
                phases,
            }) => {
                if lane != k {
                    bail!("Assign for lane {lane}, expected lane {k}");
                }
                match &cfg_json {
                    None => cfg_json = Some(config),
                    Some(first) if *first == config => {}
                    Some(_) => {
                        bail!("lane {k}: config differs from lane 0's")
                    }
                }
                if k > 0 && rejoin_round != resume_round {
                    bail!(
                        "lane {k}: rejoin round {rejoin_round} differs from \
                         lane 0's {resume_round}"
                    );
                }
                resume_round = rejoin_round;
                if phases.len() != client_ids.len() {
                    bail!(
                        "lane {k}: {} phase counts for {} clients",
                        phases.len(),
                        client_ids.len()
                    );
                }
                for (&ci, &n) in client_ids.iter().zip(&phases) {
                    if lane_of.insert(ci as usize, k).is_some() {
                        bail!("client {ci} assigned to two lanes");
                    }
                    phase_done.insert(ci as usize, n);
                }
                assigned.extend(client_ids);
            }
            Some(Msg::Shutdown { reason }) => bail!("server refused: {reason}"),
            other => bail!("expected Assign for lane {k}, got {other:?}"),
        }
    }
    let cfg = {
        let raw = cfg_json.expect("at least one lane");
        let v = crate::util::json::parse(&raw)
            .map_err(|e| anyhow::anyhow!("Assign config: {e}"))?;
        RunConfig::from_json(&v)?
    };
    let lane_clients: Vec<usize> = (0..lanes)
        .map(|k| lane_of.values().filter(|&&l| l as usize == k).count())
        .collect();
    log::info!(
        "assigned {} clients over {lanes} lane(s): {}",
        assigned.len(),
        cfg.describe()
    );

    let v = session.variant(&cfg.variant)?.clone();
    let task = if v.task == "lm" { Task::Lm } else { Task::Vision };
    let base = if v.size_base > 0 {
        Some(v.blob("frozen_base")?)
    } else {
        None
    };
    let nc = v.size_client;
    let book = CostBook::new(&v, cfg.algorithm, cfg.n_pert as u64)
        .with_zo_wire(
            cfg.zo_wire,
            cfg.local_steps as u64,
            cfg.participants_per_round() as u64,
        )
        .with_codec(cfg.codec, cfg.grad_codec);
    session.warmup(&cfg.variant, cfg.algorithm.required_entries())?;
    // lazy: a lane's client state is built the first time that client is
    // actually sampled into a cohort — a storm client fronting a large
    // population never materializes the absentees
    let mut pool = ClientPool::new(&v, &cfg, task);
    let profile = DeviceProfile::edge_default();

    // restore/rejoin fast-forward: an uninterrupted client would have
    // consumed `phases × local_steps` batches per client by now, and the
    // loader is a deterministic stream — skipping exactly that many
    // batches puts every data stream on the batch the resumed round
    // would read, which is what keeps a restored run bit-identical
    if resume_round > 0 {
        log::info!("resuming at round {resume_round}; fast-forwarding loaders");
    }
    for (&ci, &n) in &phase_done {
        if n == 0 {
            continue;
        }
        let cs = pool.state(ci);
        for _ in 0..(n as usize) * cfg.local_steps {
            cs.loader.next_batch();
        }
    }

    let lane_nacks: Vec<AtomicU64> =
        (0..lanes).map(|_| AtomicU64::new(0)).collect();
    let lane_seq: Vec<AtomicU32> =
        (0..lanes).map(|_| AtomicU32::new(0)).collect();
    let mut lane_phases: Vec<u64> = vec![0; lanes];
    let mut phases = 0u64;
    let mut rounds = 0usize;
    let mut barrier: Option<(u32, Vec<u32>)> = None;
    // this round's θ per owned client (FSL-SAGE alignment reads/updates it)
    let mut round_theta: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
    // `--zo_wire seed_agg`: the round-start global θ this endpoint last
    // received or reconstructed — the replay origin for the next
    // `SeedSync`. One model-sized vector per process, populated by the
    // dense bootstrap broadcast; `None` until then.
    let mut global_theta: Option<Vec<f32>> = None;
    let env = FanoutEnv {
        session,
        t: &t,
        cfg: &cfg,
        book: &book,
        base: base.as_deref(),
        task,
        profile,
        nc,
        assigned: &assigned,
        lane_of: &lane_of,
        lane_seq: &lane_seq,
        lane_nacks: &lane_nacks,
    };

    let shutdown_reason = 'main: loop {
        let msg = match recv(&t)? {
            Some(m) => m,
            None => bail!("server closed the connection without Shutdown"),
        };
        match msg {
            Msg::RoundBarrier { round, participants } => {
                round_theta.clear();
                // the upload seq is a per-round, per-lane counter
                for s in &lane_seq {
                    s.store(0, Ordering::Relaxed);
                }
                barrier = Some((round, participants));
            }
            Msg::ModelSync { round, client, theta, .. }
                if client == BROADCAST =>
            {
                let (bar_round, participants) = barrier
                    .as_ref()
                    .context("ModelSync before RoundBarrier")?;
                if *bar_round != round {
                    bail!("ModelSync round {round} != barrier {bar_round}");
                }
                // `--zo_wire seed_agg`: a dense broadcast is the
                // bootstrap (first round, or re-bootstrap after a
                // restore/rejoin) — cache it as the replay origin for
                // subsequent SeedSync rounds
                if cfg.zo_wire.lean_downlink() {
                    global_theta = Some(theta.clone());
                }
                if let Some(reason) = decoupled_fanout(
                    &env,
                    &mut pool,
                    round,
                    participants,
                    &theta,
                    &mut phases,
                    &mut lane_phases,
                    &mut round_theta,
                )? {
                    break 'main reason;
                }
            }
            Msg::SeedSync { round, clients, weights, seeds, gscales } => {
                // wire v7 dimension-free broadcast: reconstruct this
                // round's θ_l locally by replaying the previous round's
                // aggregated seed/scalar roster from the cached
                // round-start θ — the dense ModelSync never travels
                if !cfg.zo_wire.lean_downlink() {
                    bail!(
                        "SeedSync broadcast under --zo_wire {}",
                        cfg.zo_wire.name()
                    );
                }
                let (bar_round, participants) = barrier
                    .as_ref()
                    .context("SeedSync before RoundBarrier")?;
                if *bar_round != round {
                    bail!("SeedSync round {round} != barrier {bar_round}");
                }
                let theta_prev = global_theta
                    .as_ref()
                    .context("SeedSync before any dense bootstrap sync")?;
                let p = clients.len();
                let h = cfg.local_steps;
                let np = cfg.n_pert.max(1);
                if p == 0
                    || weights.len() != p
                    || seeds.len() != p * h
                    || gscales.len() != p * h * np
                {
                    bail!(
                        "SeedSync shape: {p} clients, {} weights, {} seeds, \
                         {} gscales (local_steps={h}, n_pert={np})",
                        weights.len(),
                        seeds.len(),
                        gscales.len()
                    );
                }
                let records: Vec<(&[i32], &[f32])> = (0..p)
                    .map(|i| {
                        (
                            &seeds[i * h..(i + 1) * h],
                            &gscales[i * h * np..(i + 1) * h * np],
                        )
                    })
                    .collect();
                let theta = crate::zo::aggregate_trajectories(
                    theta_prev,
                    &records,
                    &weights,
                    cfg.n_pert,
                )
                .context("SeedSync aggregate replay failed")?;
                global_theta = Some(theta.clone());
                if let Some(reason) = decoupled_fanout(
                    &env,
                    &mut pool,
                    round,
                    participants,
                    &theta,
                    &mut phases,
                    &mut lane_phases,
                    &mut round_theta,
                )? {
                    break 'main reason;
                }
            }
            Msg::ModelSync { lane, round, client, theta } => {
                // locked SFLV1/V2 phase for one client
                let ci = client as usize;
                let Some(&own) = lane_of.get(&ci) else {
                    bail!("locked kickoff for client {ci} not assigned here");
                };
                if lane != own {
                    bail!(
                        "locked kickoff for client {ci} on lane {lane}, \
                         assigned to lane {own}"
                    );
                }
                let _round_span =
                    crate::span!("client_round", round = round, client = client);
                let theta_end = match locked_phase(
                    session,
                    &t,
                    &cfg,
                    pool.state(ci),
                    base.as_deref(),
                    nc,
                    task,
                    ci,
                    lane,
                    round,
                    theta,
                ) {
                    Ok(th) => th,
                    Err(e) => match as_shutdown(&e) {
                        Some(reason) => break 'main reason,
                        None => return Err(e),
                    },
                };
                phases += 1;
                lane_phases[lane as usize] += 1;
                send(&t, &Msg::ModelSync {
                    lane,
                    client,
                    round,
                    theta: theta_end.clone(),
                })?;
                round_theta.insert(ci, theta_end);
            }
            Msg::AlignGrad { client, round, g } => {
                let ci = client as usize;
                let Some(&lane) = lane_of.get(&ci) else {
                    bail!("AlignGrad for client {client} not assigned here");
                };
                let (sm, y, _x) = pool
                    .state(ci)
                    .last_upload
                    .clone()
                    .context("sage alignment without upload")?;
                let theta = round_theta
                    .get(&ci)
                    .context("alignment before local phase")?
                    .clone();
                let new_theta = local::aux_align_apply(
                    session,
                    &cfg.variant,
                    base.as_deref(),
                    theta,
                    sm,
                    y,
                    g,
                    cfg.lr_client,
                )?;
                send(&t, &Msg::ModelSync {
                    lane,
                    client,
                    round,
                    theta: new_theta.clone(),
                })?;
                round_theta.insert(ci, new_theta);
            }
            Msg::RoundSummary { round, train_loss, comm_bytes, wire_bytes } => {
                rounds += 1;
                log::info!(
                    "round {round}: loss {train_loss:.4} | analytic comm {} | wire {}",
                    crate::coordinator::accounting::fmt_bytes(comm_bytes),
                    crate::coordinator::accounting::fmt_bytes(wire_bytes),
                );
            }
            Msg::Shutdown { reason } => break reason,
            other => bail!("unexpected {} from server", other.name()),
        }
    };

    let lane_nacks: Vec<u64> =
        lane_nacks.iter().map(|n| n.load(Ordering::Relaxed)).collect();
    Ok(ClientReport {
        name: name.into(),
        assigned,
        lanes,
        lane_clients,
        rounds,
        phases,
        lane_phases,
        nacks: lane_nacks.iter().sum(),
        lane_nacks,
        wire: counters.snapshot(),
        shutdown_reason,
    })
}

/// Shared immutable context for the decoupled fan-out — the per-round
/// local-phase sweep that both the dense `ModelSync` broadcast and the
/// wire v7 `SeedSync` broadcast dispatch to once they have this round's
/// θ_l in hand.
struct FanoutEnv<'a> {
    session: &'a Session,
    t: &'a Mutex<Box<dyn Transport>>,
    cfg: &'a RunConfig,
    book: &'a CostBook,
    base: Option<&'a [f32]>,
    task: Task,
    profile: DeviceProfile,
    nc: usize,
    assigned: &'a [u32],
    lane_of: &'a BTreeMap<usize, u32>,
    lane_seq: &'a [AtomicU32],
    lane_nacks: &'a [AtomicU64],
}

/// Decoupled fan-out for every owned participant of `round`, in
/// ascending client order across ALL lanes (= the in-process job order;
/// lane assignment interleaves ids, so the union must be re-sorted).
/// Returns `Ok(Some(reason))` when a `Shutdown` landed mid-upload — the
/// caller turns that into a clean exit, not a failure.
#[allow(clippy::too_many_arguments)]
fn decoupled_fanout(
    env: &FanoutEnv<'_>,
    pool: &mut ClientPool,
    round: u32,
    participants: &[u32],
    theta: &[f32],
    phases: &mut u64,
    lane_phases: &mut [u64],
    round_theta: &mut BTreeMap<usize, Vec<f32>>,
) -> Result<Option<String>> {
    let cfg = env.cfg;
    let mut mine: Vec<usize> = env
        .assigned
        .iter()
        .map(|&c| c as usize)
        .filter(|c| participants.contains(&(*c as u32)))
        .collect();
    mine.sort_unstable();
    let _round_span = crate::span!("client_round", round = round);
    let ctx = LocalCtx {
        session: env.session,
        cfg,
        book: env.book,
        base: env.base,
        task: env.task,
        round_idx: round as usize,
        profile: env.profile,
        nc: env.nc,
    };
    for ci in mine {
        let lane = env.lane_of[&ci];
        let sink = NetSink {
            t: env.t,
            lane,
            seq: &env.lane_seq[lane as usize],
            nacks: &env.lane_nacks[lane as usize],
            err: Mutex::new(None),
            stream: cfg.drain == DrainMode::Stream,
        };
        let out = local::client_local_phase(
            &ctx,
            ci,
            pool.state(ci),
            theta.to_vec(),
            &sink,
        )?;
        if let Some(e) =
            sink.err.lock().unwrap_or_else(|p| p.into_inner()).take()
        {
            // a Shutdown that landed mid-upload is a clean end of run
            if let Some(reason) = as_shutdown(&e) {
                return Ok(Some(reason));
            }
            return Err(e.context("smashed upload failed"));
        }
        *phases += 1;
        lane_phases[lane as usize] += 1;
        // the lean wire modes replace the θ upload with the per-probe
        // replay record; the server reconstructs θ bit-identically from
        // (seed, gscales) — and in seed_agg mode additionally rebroad-
        // casts the roster so clients can do the same
        let lean = cfg.zo_wire.lean_uplink();
        send(env.t, &Msg::ZoUpdate {
            lane,
            client: ci as u32,
            round,
            seeds: out.seeds.clone(),
            scalars: out.losses.iter().map(|&l| l as f32).collect(),
            gscales: if lean {
                out.gscales.clone()
            } else {
                Vec::new()
            },
        })?;
        if !lean {
            send(env.t, &Msg::ModelSync {
                lane,
                client: ci as u32,
                round,
                theta: out.theta.clone(),
            })?;
        }
        send(env.t, &Msg::LocalDone {
            lane,
            client: ci as u32,
            round,
            comm_bytes: out.comm_bytes,
            flops: out.flops,
            lane_time: out.lane.time,
            lane_idle: out.lane.idle,
        })?;
        round_theta.insert(ci, out.theta);
    }
    Ok(None)
}

/// The client half of the locked SFLV1/V2 exchange: per local step, cut
/// forward → `Smashed` up → wait for the `CutGrad` → backprop with the
/// relayed gradient (the training lock the decoupled methods remove).
#[allow(clippy::too_many_arguments)]
fn locked_phase(
    session: &Session,
    t: &Mutex<Box<dyn Transport>>,
    cfg: &RunConfig,
    cs: &mut ClientState,
    base: Option<&[f32]>,
    nc: usize,
    task: Task,
    ci: usize,
    lane: u32,
    round: u32,
    mut theta: Vec<f32>,
) -> Result<Vec<f32>> {
    let mut opt_c = std::mem::replace(&mut cs.opt_client, OptState::None);
    for step in 1..=cfg.local_steps {
        cs.loader.next_batch();
        let (x, y) = local::loader_batch_xy(task, &cs.loader);
        let smashed = local::locked_client_fwd(
            session,
            &cfg.variant,
            base,
            &theta[..nc],
            &x,
        )?;
        // encode-once at the producer: the dispatcher decodes this exact
        // envelope, so its view of the activations matches the
        // in-process transcode bit-for-bit
        send(t, &Msg::Smashed {
            lane,
            client: ci as u32,
            round,
            step: step as u32,
            smashed: codec::encode(cfg.codec, &smashed),
            targets: y,
        })?;
        let g = match recv(t)? {
            Some(Msg::CutGrad { client, step: s, g, .. })
                if client as usize == ci && s as usize == step =>
            {
                codec::decode_expect(&g, cfg.grad_codec.id())
                    .map_err(|e| anyhow::anyhow!("CutGrad payload: {e}"))?
            }
            Some(Msg::Shutdown { reason }) => {
                return Err(anyhow::Error::new(CleanShutdown(reason)));
            }
            other => bail!("expected CutGrad for step {step}, got {other:?}"),
        };
        let new_c = local::locked_client_bp(
            session,
            &cfg.variant,
            base,
            &theta[..nc],
            &mut opt_c,
            x,
            g,
            cfg.lr_client,
        )?;
        theta[..nc].copy_from_slice(&new_c);
    }
    cs.opt_client = opt_c;
    Ok(theta)
}
