//! Networked client endpoint: drives the local ZO/FO phase of one or more
//! logical clients against a remote `heron-sfl serve` dispatcher.
//!
//! The endpoint is deliberately thin: after the `Hello`/`Assign`
//! handshake it reconstructs the *exact* run setup the server uses — the
//! config arrives as exact-string JSON (`RunConfig::to_json`), the client
//! states come from the same `build_client_states`, and every step runs
//! the same `coordinator::local` functions the in-process driver fans out
//! to its worker pool. The wire carries bit-exact f32 payloads, so the
//! trajectory cannot diverge from `Driver::run_round`.
//!
//! Message handling is a single blocking loop:
//!
//! * `RoundBarrier` — remember `(round, participants)`.
//! * `ModelSync{client: BROADCAST}` — decoupled fan-out: run
//!   `client_local_phase` for each owned participant (ascending id), with
//!   a sink that ships `Smashed` frames (`SmashedSeq`, carrying the
//!   per-round upload sequence number + virtual send time, in `--drain
//!   stream` runs) and blocks on the `UploadAck`
//!   (counting typed NACKs); reply `ZoUpdate` (per-step seeds + loss
//!   scalars — plus the per-probe `gscales` in `--zo_wire seeds` mode,
//!   which then **replaces** the θ upload), `ModelSync` (updated θ,
//!   `theta` mode only), `LocalDone` (analytic counters).
//! * `ModelSync{client: ci}` — locked SFLV1/V2 phase for `ci`: per step,
//!   cut forward → `Smashed` → wait `CutGrad` → backprop; then θ up.
//! * `AlignGrad` — FSL-SAGE: `aux_align` against the stored last upload,
//!   reply the realigned θ.
//! * `RoundSummary` — bookkeeping; `Shutdown` — return the report.

use crate::coordinator::accounting::CostBook;
use crate::coordinator::config::{RunConfig, ZoWireMode};
use crate::coordinator::drain::DrainMode;
use crate::coordinator::eventsim::{DeviceProfile, WireRoundStats};
use crate::coordinator::local::{
    self, build_client_states, ClientState, LocalCtx, SmashedSink, UploadTag,
};
use crate::coordinator::round::OptState;
use crate::coordinator::server_queue::SmashedBatch;
use crate::data::loader::Task;
use crate::net::transport::Transport;
use crate::net::wire::{Msg, BROADCAST, VERSION};
use crate::runtime::Session;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// End-of-run statistics from one client process.
#[derive(Debug, Clone)]
pub struct ClientReport {
    pub name: String,
    pub assigned: Vec<u32>,
    /// rounds observed (RoundSummary count)
    pub rounds: usize,
    /// local phases executed (decoupled + locked)
    pub phases: u64,
    /// uploads rejected by the server queue (typed NACKs received)
    pub nacks: u64,
    pub wire: WireRoundStats,
    pub shutdown_reason: String,
}

fn send(t: &Mutex<Box<dyn Transport>>, msg: &Msg) -> Result<()> {
    t.lock().unwrap_or_else(|p| p.into_inner()).send(msg)
}

fn recv(t: &Mutex<Box<dyn Transport>>) -> Result<Option<Msg>> {
    t.lock().unwrap_or_else(|p| p.into_inner()).recv()
}

/// The networked [`SmashedSink`]: every push is a framed upload with a
/// blocking `UploadAck` round-trip; `accepted == false` (the server's
/// typed NACK for a queue-capacity drop) is counted and reported back as
/// "dropped", mirroring the in-process `ServerQueue::push` contract. In
/// a `--drain stream` run the upload travels as `SmashedSeq` — the
/// barrier `Smashed` layout extended with the per-round sequence number
/// and virtual send time the dispatcher's arrival-order consumption
/// validates and measures.
struct NetSink<'a> {
    t: &'a Mutex<Box<dyn Transport>>,
    nacks: &'a AtomicU64,
    err: Mutex<Option<anyhow::Error>>,
    /// `--drain stream`: ship `SmashedSeq` instead of `Smashed`
    stream: bool,
}

impl NetSink<'_> {
    fn exchange(&self, b: SmashedBatch, tag: UploadTag) -> Result<bool> {
        let mut g = self.t.lock().unwrap_or_else(|p| p.into_inner());
        let msg = if self.stream {
            Msg::SmashedSeq {
                client: b.client as u32,
                round: b.round as u32,
                step: b.step as u32,
                seq: tag.seq as u32,
                sent_at: tag.sent_at,
                smashed: b.smashed,
                targets: b.targets,
            }
        } else {
            Msg::Smashed {
                client: b.client as u32,
                round: b.round as u32,
                step: b.step as u32,
                smashed: b.smashed,
                targets: b.targets,
            }
        };
        g.send(&msg)?;
        match g.recv()? {
            Some(Msg::UploadAck { accepted, reason, .. }) => {
                if !accepted {
                    self.nacks.fetch_add(1, Ordering::Relaxed);
                    log::warn!("upload NACKed: {reason}");
                }
                Ok(accepted)
            }
            other => bail!("expected UploadAck, got {other:?}"),
        }
    }
}

impl SmashedSink for NetSink<'_> {
    fn push_smashed(&self, b: SmashedBatch, tag: UploadTag) -> bool {
        // latch: after one failed exchange the transport is in an unknown
        // state — never touch it again from this phase (a blocked recv
        // here would deadlock client and server), just let the phase
        // finish so the caller sees the stored error
        {
            let g = self.err.lock().unwrap_or_else(|p| p.into_inner());
            if g.is_some() {
                return false;
            }
        }
        match self.exchange(b, tag) {
            Ok(accepted) => accepted,
            Err(e) => {
                *self.err.lock().unwrap_or_else(|p| p.into_inner()) = Some(e);
                false
            }
        }
    }
}

/// Connect-side entry point: handshake, then serve rounds until the
/// dispatcher says `Shutdown`.
pub fn run_client(
    session: &Session,
    transport: Box<dyn Transport>,
    name: &str,
) -> Result<ClientReport> {
    let counters = transport.counters();
    let t = Mutex::new(transport);
    send(&t, &Msg::Hello { name: name.into(), protocol: VERSION as u32 })?;
    let (assigned, cfg) = match recv(&t)? {
        Some(Msg::Assign { client_ids, config }) => {
            let v = crate::util::json::parse(&config)
                .map_err(|e| anyhow::anyhow!("Assign config: {e}"))?;
            (client_ids, RunConfig::from_json(&v)?)
        }
        Some(Msg::Shutdown { reason }) => bail!("server refused: {reason}"),
        other => bail!("expected Assign, got {other:?}"),
    };
    log::info!(
        "assigned clients {assigned:?}: {}",
        cfg.describe()
    );

    let v = session.variant(&cfg.variant)?.clone();
    let task = if v.task == "lm" { Task::Lm } else { Task::Vision };
    let base = if v.size_base > 0 {
        Some(v.blob("frozen_base")?)
    } else {
        None
    };
    let nc = v.size_client;
    let book = CostBook::new(&v, cfg.algorithm, cfg.n_pert as u64)
        .with_zo_wire(cfg.zo_wire, cfg.local_steps as u64);
    session.warmup(&cfg.variant, cfg.algorithm.required_entries())?;
    let mut states: Vec<ClientState> = build_client_states(&v, &cfg, task);
    let profile = DeviceProfile::edge_default();

    let nacks = AtomicU64::new(0);
    let mut phases = 0u64;
    let mut rounds = 0usize;
    let mut barrier: Option<(u32, Vec<u32>)> = None;
    // this round's θ per owned client (FSL-SAGE alignment reads/updates it)
    let mut round_theta: BTreeMap<usize, Vec<f32>> = BTreeMap::new();

    let shutdown_reason = loop {
        let msg = match recv(&t)? {
            Some(m) => m,
            None => bail!("server closed the connection without Shutdown"),
        };
        match msg {
            Msg::RoundBarrier { round, participants } => {
                round_theta.clear();
                barrier = Some((round, participants));
            }
            Msg::ModelSync { round, client, theta } if client == BROADCAST => {
                // decoupled fan-out for every owned participant, in
                // ascending client order (= participant order within this
                // connection, matching the in-process job order)
                let (bar_round, participants) = barrier
                    .as_ref()
                    .context("ModelSync before RoundBarrier")?;
                if *bar_round != round {
                    bail!("ModelSync round {round} != barrier {bar_round}");
                }
                let mine: Vec<usize> = assigned
                    .iter()
                    .map(|&c| c as usize)
                    .filter(|c| participants.contains(&(*c as u32)))
                    .collect();
                let ctx = LocalCtx {
                    session,
                    cfg: &cfg,
                    book: &book,
                    base: base.as_deref(),
                    task,
                    round_idx: round as usize,
                    profile,
                    nc,
                };
                for ci in mine {
                    let sink = NetSink {
                        t: &t,
                        nacks: &nacks,
                        err: Mutex::new(None),
                        stream: cfg.drain == DrainMode::Stream,
                    };
                    let out = local::client_local_phase(
                        &ctx,
                        ci,
                        &mut states[ci],
                        theta.clone(),
                        &sink,
                    )?;
                    if let Some(e) =
                        sink.err.lock().unwrap_or_else(|p| p.into_inner()).take()
                    {
                        return Err(e.context("smashed upload failed"));
                    }
                    phases += 1;
                    // the lean seeds mode replaces the θ upload with the
                    // per-probe replay record; the server reconstructs θ
                    // bit-identically from (seed, gscales)
                    let lean = cfg.zo_wire == ZoWireMode::Seeds;
                    send(&t, &Msg::ZoUpdate {
                        client: ci as u32,
                        round,
                        seeds: out.seeds.clone(),
                        scalars: out.losses.iter().map(|&l| l as f32).collect(),
                        gscales: if lean {
                            out.gscales.clone()
                        } else {
                            Vec::new()
                        },
                    })?;
                    if !lean {
                        send(&t, &Msg::ModelSync {
                            client: ci as u32,
                            round,
                            theta: out.theta.clone(),
                        })?;
                    }
                    send(&t, &Msg::LocalDone {
                        client: ci as u32,
                        round,
                        comm_bytes: out.comm_bytes,
                        flops: out.flops,
                        lane_time: out.lane.time,
                        lane_idle: out.lane.idle,
                    })?;
                    round_theta.insert(ci, out.theta);
                }
            }
            Msg::ModelSync { round, client, theta } => {
                // locked SFLV1/V2 phase for one client
                let ci = client as usize;
                if !assigned.contains(&client) {
                    bail!("locked kickoff for client {ci} not assigned here");
                }
                let theta_end = locked_phase(
                    session, &t, &cfg, &mut states[ci], base.as_deref(), nc,
                    task, ci, round, theta,
                )?;
                phases += 1;
                send(&t, &Msg::ModelSync {
                    client,
                    round,
                    theta: theta_end.clone(),
                })?;
                round_theta.insert(ci, theta_end);
            }
            Msg::AlignGrad { client, round, g } => {
                if !assigned.contains(&client) {
                    bail!("AlignGrad for client {client} not assigned here");
                }
                let ci = client as usize;
                let (sm, y, _x) = states[ci]
                    .last_upload
                    .clone()
                    .context("sage alignment without upload")?;
                let theta = round_theta
                    .get(&ci)
                    .context("alignment before local phase")?
                    .clone();
                let new_theta = local::aux_align_apply(
                    session,
                    &cfg.variant,
                    base.as_deref(),
                    theta,
                    sm,
                    y,
                    g,
                    cfg.lr_client,
                )?;
                send(&t, &Msg::ModelSync {
                    client,
                    round,
                    theta: new_theta.clone(),
                })?;
                round_theta.insert(ci, new_theta);
            }
            Msg::RoundSummary { round, train_loss, comm_bytes, wire_bytes } => {
                rounds += 1;
                log::info!(
                    "round {round}: loss {train_loss:.4} | analytic comm {} | wire {}",
                    crate::coordinator::accounting::fmt_bytes(comm_bytes),
                    crate::coordinator::accounting::fmt_bytes(wire_bytes),
                );
            }
            Msg::Shutdown { reason } => break reason,
            other => bail!("unexpected {} from server", other.name()),
        }
    };

    Ok(ClientReport {
        name: name.into(),
        assigned,
        rounds,
        phases,
        nacks: nacks.load(Ordering::Relaxed),
        wire: counters.snapshot(),
        shutdown_reason,
    })
}

/// The client half of the locked SFLV1/V2 exchange: per local step, cut
/// forward → `Smashed` up → wait for the `CutGrad` → backprop with the
/// relayed gradient (the training lock the decoupled methods remove).
fn locked_phase(
    session: &Session,
    t: &Mutex<Box<dyn Transport>>,
    cfg: &RunConfig,
    cs: &mut ClientState,
    base: Option<&[f32]>,
    nc: usize,
    task: Task,
    ci: usize,
    round: u32,
    mut theta: Vec<f32>,
) -> Result<Vec<f32>> {
    let mut opt_c = std::mem::replace(&mut cs.opt_client, OptState::None);
    for step in 1..=cfg.local_steps {
        cs.loader.next_batch();
        let (x, y) = local::loader_batch_xy(task, &cs.loader);
        let smashed = local::locked_client_fwd(
            session,
            &cfg.variant,
            base,
            &theta[..nc],
            &x,
        )?;
        send(t, &Msg::Smashed {
            client: ci as u32,
            round,
            step: step as u32,
            smashed,
            targets: y,
        })?;
        let g = match recv(t)? {
            Some(Msg::CutGrad { client, step: s, g, .. })
                if client as usize == ci && s as usize == step =>
            {
                g
            }
            other => bail!("expected CutGrad for step {step}, got {other:?}"),
        };
        let new_c = local::locked_client_bp(
            session,
            &cfg.variant,
            base,
            &theta[..nc],
            &mut opt_c,
            x,
            g,
            cfg.lr_client,
        )?;
        theta[..nc].copy_from_slice(&new_c);
    }
    cs.opt_client = opt_c;
    Ok(theta)
}
