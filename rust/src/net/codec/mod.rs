//! Pluggable payload codecs for wire v6 (substrate S21): encode/decode
//! tensor payloads with self-describing headers.
//!
//! HERON-SFL's thesis is lean clients, but the smashed-activation and
//! cut-gradient payloads shipped full f32 through v5. This module makes
//! the payload *representation* a negotiated capability, orthogonal to
//! the frame layer: `wire.rs` frames/CRCs an opaque `Vec<u8>` envelope,
//! and this module defines what the bytes mean.
//!
//! ## Codecs
//!
//! | tag | codec  | envelope layout (little-endian)                    |
//! |-----|--------|----------------------------------------------------|
//! | 0   | `f32`  | `u8 tag, u32 n, n×f32` — identity, bit-exact       |
//! | 1   | `int8` | `u8 tag, u32 n, f32 scale, f32 zero_point, n×u8`   |
//! | 2   | `int4` | `u8 tag, u32 n, f32 scale, f32 zero_point, ⌈n/2⌉×u8` |
//! | 3   | `topk` | `u8 tag, u32 n, u32 k, k×(u32 idx, f32 value)`     |
//!
//! `int8`/`int4` are per-tensor affine: `zero_point` is the payload's
//! finite minimum, `scale = (max−min)/qmax` (`qmax` 255 or 15), and
//! `q = round((x − zero_point)/scale)` clamped to `[0, qmax]`, so the
//! reconstruction error is bounded by `scale/2` per element. A constant,
//! empty, or all-non-finite payload encodes with `scale = 0` (every
//! element decodes to the zero point); non-finite elements quantize to
//! bucket 0 — deterministic, never a NaN comparison. `int4` packs two
//! quanta per byte, low nibble first; an odd tail pads the high nibble
//! with 0. `topk` keeps the `k = max(1, ⌈ratio·n⌉)` largest-|value|
//! elements (ties break toward the lower index) as sorted
//! `(index, value)` pairs and decodes to a dense vector with zeros
//! elsewhere — the classic gradient sparsifier.
//!
//! ## The encode-once rule
//!
//! Quantization happens **exactly once per payload**, at the producer:
//! the networked client encodes and ships the bytes verbatim; the
//! in-process driver runs [`transcode`] (encode, then replace the values
//! with their own decode) at the same protocol point. Re-encoding a
//! dequantized payload is *not* bit-stable — the scale would be
//! recomputed from already-rounded values — so both execution modes
//! share the single encode, which is what pins `--codec f32` (and every
//! lossy codec's client-visible trajectory) bit-identical between
//! in-process and TCP-loopback runs (`rust/tests/net_loopback.rs`).
//!
//! Decoding never panics and never allocates more than
//! [`MAX_ELEMS`]×4 bytes: every count is validated against the actual
//! envelope length (and the cap) *before* any allocation, and malformed
//! input is a typed [`CodecError`] (property-tested in
//! `rust/tests/net_codec.rs`).
//!
//! Telemetry: the instrumented entry points ([`encode`], [`encode_grad`],
//! [`decode`], [`decode_expect`]) record `net.codec.encode`/`.decode`
//! spans plus `net.codec.{encode,decode}_us` histograms and a
//! `net.codec.bytes_saved` counter (f32-envelope bytes minus encoded
//! bytes) into the metrics registry when it is enabled.

use crate::telemetry::{metrics_enabled, now_us, registry};
use std::fmt;

/// Wire tag of the identity f32 codec.
pub const TAG_F32: u8 = 0;
/// Wire tag of the int8 affine codec.
pub const TAG_INT8: u8 = 1;
/// Wire tag of the int4 affine codec.
pub const TAG_INT4: u8 = 2;
/// Wire tag of the top-k gradient sparsifier.
pub const TAG_TOPK: u8 = 3;

/// Codec ids this build can decode — what a client advertises in
/// `Hello.codecs` and the dispatcher validates its `RunConfig` choice
/// against.
pub const SUPPORTED: [u8; 4] = [TAG_F32, TAG_INT8, TAG_INT4, TAG_TOPK];

/// Hard cap on a decoded payload's element count: a hostile header must
/// not make the decoder allocate unbounded memory (16M elements = 64 MiB
/// of f32 — far above any payload this crate ships, far below an OOM).
pub const MAX_ELEMS: u32 = 1 << 24;

const H_F32: usize = 5; // tag + n
const H_AFFINE: usize = 13; // tag + n + scale + zero_point
const H_TOPK: usize = 9; // tag + n + k

/// Typed decode failure. Decoding rejects — it never panics, and it
/// validates lengths before allocating.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The envelope is shorter than its header claims.
    Truncated,
    /// Unknown codec tag.
    BadTag(u8),
    /// A non-finite scale or zero point.
    BadScale,
    /// The declared element count exceeds [`MAX_ELEMS`].
    TooLarge(u32),
    /// A top-k index at or past the declared element count.
    BadIndex { idx: u32, n: u32 },
    /// The envelope tag differs from the negotiated codec.
    WrongCodec { got: u8, want: u8 },
    /// Any other structural violation (trailing bytes, k > n, …).
    Malformed(&'static str),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "codec payload truncated"),
            CodecError::BadTag(t) => write!(f, "unknown codec tag {t}"),
            CodecError::BadScale => {
                write!(f, "non-finite quantization scale or zero point")
            }
            CodecError::TooLarge(n) => write!(
                f,
                "declared element count {n} exceeds the cap {MAX_ELEMS}"
            ),
            CodecError::BadIndex { idx, n } => {
                write!(f, "top-k index {idx} out of range for {n} elements")
            }
            CodecError::WrongCodec { got, want } => write!(
                f,
                "payload codec tag {got} differs from the negotiated {want}"
            ),
            CodecError::Malformed(m) => write!(f, "malformed codec payload: {m}"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---------------------------------------------------------------------------
// negotiated codec choices (RunConfig `codec` / `grad_codec`)
// ---------------------------------------------------------------------------

/// Which codec smashed-activation payloads use (`--codec`). The default
/// `f32` is the identity and pins pre-v6 byte accounting exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    #[default]
    F32,
    Int8,
    Int4,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::Int8 => "int8",
            Codec::Int4 => "int4",
        }
    }

    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "f32" => Some(Codec::F32),
            "int8" => Some(Codec::Int8),
            "int4" => Some(Codec::Int4),
            _ => None,
        }
    }

    pub fn id(self) -> u8 {
        match self {
            Codec::F32 => TAG_F32,
            Codec::Int8 => TAG_INT8,
            Codec::Int4 => TAG_INT4,
        }
    }
}

/// Which codec cut-gradient payloads use (`--grad_codec`): the identity
/// or top-k sparsification with a keep ratio (serialized `topk:<ratio>`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum GradCodec {
    #[default]
    F32,
    TopK(f32),
}

impl GradCodec {
    /// The serialized spec string (`f32` or `topk:<ratio>`); `{}` is
    /// shortest-roundtrip formatting, so `parse(spec())` is exact.
    pub fn spec(self) -> String {
        match self {
            GradCodec::F32 => "f32".to_string(),
            GradCodec::TopK(r) => format!("topk:{r}"),
        }
    }

    pub fn parse(s: &str) -> Option<GradCodec> {
        if s == "f32" {
            return Some(GradCodec::F32);
        }
        let ratio = s.strip_prefix("topk:")?.parse::<f32>().ok()?;
        ratio.is_finite().then_some(GradCodec::TopK(ratio))
    }

    pub fn id(self) -> u8 {
        match self {
            GradCodec::F32 => TAG_F32,
            GradCodec::TopK(_) => TAG_TOPK,
        }
    }
}

// ---------------------------------------------------------------------------
// analytic sizes (CostBook formulas + loopback byte pins)
// ---------------------------------------------------------------------------

/// `k` for an n-element top-k payload: `max(1, ⌈ratio·n⌉)` clamped to n
/// (0 for an empty payload).
pub fn topk_k(n: usize, ratio: f32) -> usize {
    if n == 0 {
        0
    } else {
        (((n as f64) * (ratio as f64)).ceil() as usize).clamp(1, n)
    }
}

/// Information bytes per n-element payload — what the analytic CostBook
/// charges (headers are per-message overhead, accounted next to the
/// frame envelope).
pub fn info_bytes(codec: Codec, n: u64) -> u64 {
    match codec {
        Codec::F32 => 4 * n,
        Codec::Int8 => n,
        Codec::Int4 => n.div_ceil(2),
    }
}

/// [`info_bytes`] for the gradient codec (`topk`: 8 bytes per kept
/// element).
pub fn info_bytes_grad(codec: GradCodec, n: u64) -> u64 {
    match codec {
        GradCodec::F32 => 4 * n,
        GradCodec::TopK(r) => 8 * topk_k(n as usize, r) as u64,
    }
}

/// Codec header bytes per payload (the explicit per-message overhead in
/// the measured-vs-analytic cross-check).
pub fn header_bytes(codec: Codec) -> u64 {
    match codec {
        Codec::F32 => H_F32 as u64,
        Codec::Int8 | Codec::Int4 => H_AFFINE as u64,
    }
}

/// [`header_bytes`] for the gradient codec.
pub fn header_bytes_grad(codec: GradCodec) -> u64 {
    match codec {
        GradCodec::F32 => H_F32 as u64,
        GradCodec::TopK(_) => H_TOPK as u64,
    }
}

/// Exact encoded envelope length for an n-element payload.
pub fn encoded_len(codec: Codec, n: usize) -> usize {
    header_bytes(codec) as usize + info_bytes(codec, n as u64) as usize
}

/// [`encoded_len`] for the gradient codec.
pub fn encoded_len_grad(codec: GradCodec, n: usize) -> usize {
    header_bytes_grad(codec) as usize
        + info_bytes_grad(codec, n as u64) as usize
}

// ---------------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------------

/// Encode a smashed payload under the negotiated codec (instrumented).
pub fn encode(codec: Codec, data: &[f32]) -> Vec<u8> {
    let _s = crate::span!("net.codec.encode", n = data.len());
    let t0 = if metrics_enabled() { now_us() } else { 0 };
    let out = match codec {
        Codec::F32 => encode_f32(data),
        Codec::Int8 => encode_int8(data),
        Codec::Int4 => encode_int4(data),
    };
    note_encode(data.len(), out.len(), t0);
    out
}

/// Encode a cut-gradient payload under the negotiated gradient codec
/// (instrumented).
pub fn encode_grad(codec: GradCodec, data: &[f32]) -> Vec<u8> {
    let _s = crate::span!("net.codec.encode", n = data.len());
    let t0 = if metrics_enabled() { now_us() } else { 0 };
    let out = match codec {
        GradCodec::F32 => encode_f32(data),
        GradCodec::TopK(r) => encode_topk(data, r),
    };
    note_encode(data.len(), out.len(), t0);
    out
}

fn note_encode(n: usize, enc_len: usize, t0: u64) {
    if metrics_enabled() {
        registry::histogram("net.codec.encode_us")
            .observe(now_us().saturating_sub(t0));
        let raw = encoded_len(Codec::F32, n) as u64;
        registry::counter("net.codec.bytes_saved")
            .add(raw.saturating_sub(enc_len as u64));
    }
}

fn put_header(out: &mut Vec<u8>, tag: u8, n: usize) {
    out.push(tag);
    out.extend_from_slice(&(n as u32).to_le_bytes());
}

/// The identity codec: the payload's exact f32 bit patterns.
pub fn encode_f32(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(H_F32 + 4 * data.len());
    put_header(&mut out, TAG_F32, data.len());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// `(zero_point, range)` over the payload's *finite* values; a constant,
/// empty, or all-non-finite payload gets range 0 (scale 0 ⇒ every
/// element decodes to the zero point).
fn affine_params(data: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in data {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        return (if lo.is_finite() { lo } else { 0.0 }, 0.0);
    }
    (lo, hi - lo)
}

fn quantize(v: f32, zp: f32, scale: f32, qmax: f32) -> u8 {
    if scale <= 0.0 || !v.is_finite() {
        return 0;
    }
    ((v - zp) / scale).round().clamp(0.0, qmax) as u8
}

/// Per-tensor affine int8: one byte per element plus scale/zero-point.
pub fn encode_int8(data: &[f32]) -> Vec<u8> {
    let (zp, range) = affine_params(data);
    let scale = range / 255.0;
    let mut out = Vec::with_capacity(H_AFFINE + data.len());
    put_header(&mut out, TAG_INT8, data.len());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&zp.to_le_bytes());
    for &v in data {
        out.push(quantize(v, zp, scale, 255.0));
    }
    out
}

/// Per-tensor affine int4: two quanta per byte (low nibble first, odd
/// tail pads the high nibble with 0).
pub fn encode_int4(data: &[f32]) -> Vec<u8> {
    let (zp, range) = affine_params(data);
    let scale = range / 15.0;
    let mut out = Vec::with_capacity(H_AFFINE + data.len().div_ceil(2));
    put_header(&mut out, TAG_INT4, data.len());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&zp.to_le_bytes());
    for pair in data.chunks(2) {
        let lo = quantize(pair[0], zp, scale, 15.0);
        let hi = if pair.len() == 2 {
            quantize(pair[1], zp, scale, 15.0)
        } else {
            0
        };
        out.push(lo | (hi << 4));
    }
    out
}

/// Top-k sparsification: keep the k largest-|value| elements (ties break
/// toward the lower index; non-finite values never outrank a finite
/// one), shipped as index-sorted `(u32 idx, f32 value)` pairs.
pub fn encode_topk(data: &[f32], ratio: f32) -> Vec<u8> {
    let n = data.len();
    let k = topk_k(n, ratio);
    // selection key: |v| for finite values, −1 for NaN/±inf — a strict
    // total order, so the k-partition is deterministic
    let key = |i: u32| {
        let v = data[i as usize];
        if v.is_finite() {
            v.abs()
        } else {
            -1.0
        }
    };
    let mut idx: Vec<u32> = (0..n as u32).collect();
    if k < n {
        idx.select_nth_unstable_by(k, |&a, &b| {
            key(b).total_cmp(&key(a)).then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    let mut out = Vec::with_capacity(H_TOPK + 8 * k);
    put_header(&mut out, TAG_TOPK, n);
    out.extend_from_slice(&(k as u32).to_le_bytes());
    for &i in &idx {
        out.extend_from_slice(&i.to_le_bytes());
        out.extend_from_slice(&data[i as usize].to_le_bytes());
    }
    out
}

// ---------------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------------

/// Decode a self-describing codec envelope (instrumented). Rejects —
/// never panics — on malformed input, with every length validated
/// against the actual envelope before any allocation.
pub fn decode(bytes: &[u8]) -> Result<Vec<f32>, CodecError> {
    let _s = crate::span!("net.codec.decode", len = bytes.len());
    let t0 = if metrics_enabled() { now_us() } else { 0 };
    let out = decode_inner(bytes)?;
    if metrics_enabled() {
        registry::histogram("net.codec.decode_us")
            .observe(now_us().saturating_sub(t0));
    }
    Ok(out)
}

/// [`decode`], additionally requiring the envelope tag to be the
/// negotiated codec id — the dispatcher's ingress check (a client must
/// not ship f32 into an int8 run and skew the measured bytes).
pub fn decode_expect(bytes: &[u8], want: u8) -> Result<Vec<f32>, CodecError> {
    match bytes.first() {
        None => Err(CodecError::Truncated),
        Some(&got) if got != want => {
            Err(CodecError::WrongCodec { got, want })
        }
        Some(_) => decode(bytes),
    }
}

fn check_len(got: usize, want: usize) -> Result<(), CodecError> {
    match got.cmp(&want) {
        std::cmp::Ordering::Less => Err(CodecError::Truncated),
        std::cmp::Ordering::Greater => {
            Err(CodecError::Malformed("trailing bytes after the payload"))
        }
        std::cmp::Ordering::Equal => Ok(()),
    }
}

fn read_f32(bytes: &[u8], at: usize) -> f32 {
    f32::from_le_bytes(bytes[at..at + 4].try_into().expect("4 bytes"))
}

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().expect("4 bytes"))
}

fn read_affine(body: &[u8]) -> Result<(f32, f32), CodecError> {
    let scale = read_f32(body, 0);
    let zp = read_f32(body, 4);
    if !scale.is_finite() || !zp.is_finite() {
        return Err(CodecError::BadScale);
    }
    Ok((scale, zp))
}

fn decode_inner(bytes: &[u8]) -> Result<Vec<f32>, CodecError> {
    if bytes.len() < H_F32 {
        return Err(CodecError::Truncated);
    }
    let tag = bytes[0];
    let n = read_u32(bytes, 1);
    if n > MAX_ELEMS {
        return Err(CodecError::TooLarge(n));
    }
    let n = n as usize;
    let body = &bytes[H_F32..];
    match tag {
        TAG_F32 => {
            check_len(body.len(), 4 * n)?;
            Ok(body
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
                .collect())
        }
        TAG_INT8 => {
            check_len(body.len(), 8 + n)?;
            let (scale, zp) = read_affine(body)?;
            Ok(body[8..].iter().map(|&q| zp + q as f32 * scale).collect())
        }
        TAG_INT4 => {
            check_len(body.len(), 8 + n.div_ceil(2))?;
            let (scale, zp) = read_affine(body)?;
            let packed = &body[8..];
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                let b = packed[i / 2];
                let q = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
                out.push(zp + q as f32 * scale);
            }
            Ok(out)
        }
        TAG_TOPK => {
            if body.len() < 4 {
                return Err(CodecError::Truncated);
            }
            let k = read_u32(body, 0);
            if k as usize > n {
                return Err(CodecError::Malformed(
                    "top-k count exceeds the element count",
                ));
            }
            check_len(body.len(), 4 + 8 * k as usize)?;
            let mut out = vec![0.0f32; n];
            for pair in body[4..].chunks_exact(8) {
                let idx = u32::from_le_bytes(
                    pair[..4].try_into().expect("4 bytes"),
                );
                if idx as usize >= n {
                    return Err(CodecError::BadIndex { idx, n: n as u32 });
                }
                out[idx as usize] = f32::from_le_bytes(
                    pair[4..].try_into().expect("4 bytes"),
                );
            }
            Ok(out)
        }
        t => Err(CodecError::BadTag(t)),
    }
}

// ---------------------------------------------------------------------------
// transcode (the in-process half of the encode-once rule)
// ---------------------------------------------------------------------------

/// Encode once, then replace `data` with its own decode — the exact
/// values the dispatcher would see after the wire. Returns the encoded
/// envelope (the networked sink ships it verbatim; in-process callers
/// drop it).
pub fn transcode(codec: Codec, data: &mut Vec<f32>) -> Vec<u8> {
    let enc = encode(codec, data);
    *data = decode(&enc).expect("self-encoded payload decodes");
    enc
}

/// [`transcode`] under the gradient codec.
pub fn transcode_grad(codec: GradCodec, data: &mut Vec<f32>) -> Vec<u8> {
    let enc = encode_grad(codec, data);
    *data = decode(&enc).expect("self-encoded payload decodes");
    enc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_specs_roundtrip() {
        for c in [Codec::F32, Codec::Int8, Codec::Int4] {
            assert_eq!(Codec::parse(c.name()), Some(c));
        }
        assert_eq!(Codec::parse("gzip"), None);
        for gc in [GradCodec::F32, GradCodec::TopK(0.25)] {
            assert_eq!(GradCodec::parse(&gc.spec()), Some(gc));
        }
        assert_eq!(GradCodec::parse("topk:0.1"), Some(GradCodec::TopK(0.1)));
        assert_eq!(GradCodec::parse("topk:nan"), None);
        assert_eq!(GradCodec::parse("topk:"), None);
        assert_eq!(GradCodec::parse("topk"), None);
    }

    #[test]
    fn encoded_lens_are_exact() {
        for n in [0usize, 1, 2, 3, 7, 64, 4096] {
            let data: Vec<f32> =
                (0..n).map(|i| i as f32 * 0.25 - 1.0).collect();
            assert_eq!(encode_f32(&data).len(), encoded_len(Codec::F32, n));
            assert_eq!(encode_int8(&data).len(), encoded_len(Codec::Int8, n));
            assert_eq!(encode_int4(&data).len(), encoded_len(Codec::Int4, n));
            assert_eq!(
                encode_topk(&data, 0.25).len(),
                encoded_len_grad(GradCodec::TopK(0.25), n)
            );
        }
    }

    #[test]
    fn f32_roundtrip_is_bitwise() {
        let data = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 1e30, -0.0];
        let enc = encode(Codec::F32, &data);
        let back = decode(&enc).unwrap();
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn affine_error_is_bounded_by_half_scale() {
        let data: Vec<f32> = (0..257).map(|i| (i as f32).sin() * 3.0).collect();
        for (enc, qmax) in
            [(encode_int8(&data), 255.0f32), (encode_int4(&data), 15.0)]
        {
            let scale = f32::from_le_bytes(enc[5..9].try_into().unwrap());
            assert!(scale > 0.0 && scale.is_finite());
            let back = decode(&enc).unwrap();
            let bound = scale as f64 / 2.0 + 1e-6;
            for (a, b) in data.iter().zip(&back) {
                assert!(
                    ((a - b).abs() as f64) <= bound,
                    "|{a} - {b}| > {bound} (qmax {qmax})"
                );
            }
        }
    }

    #[test]
    fn constant_and_nonfinite_payloads_are_deterministic() {
        for data in [
            vec![],
            vec![2.5f32; 9],
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY],
            vec![f32::NAN, 1.0, 3.0],
        ] {
            for enc in [encode_int8(&data), encode_int4(&data)] {
                let back = decode(&enc).unwrap();
                assert_eq!(back.len(), data.len());
                for v in &back {
                    assert!(v.is_finite(), "{data:?} decoded non-finite");
                }
            }
        }
        // constant payload decodes exactly: scale 0, zero point = value
        let c = vec![2.5f32; 9];
        assert_eq!(decode(&encode_int8(&c)).unwrap(), c);
    }

    #[test]
    fn topk_keeps_largest_and_zeroes_rest() {
        let data = vec![0.1f32, -5.0, 0.2, 4.0, -0.3];
        let enc = encode_topk(&data, 0.4); // k = 2
        assert_eq!(enc.len(), H_TOPK + 8 * 2);
        let back = decode(&enc).unwrap();
        assert_eq!(back, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
        // ties break toward the lower index
        let tie = vec![1.0f32, -1.0, 1.0];
        let back = decode(&encode_topk(&tie, 0.5)).unwrap(); // k = 2
        assert_eq!(back, vec![1.0, -1.0, 0.0]);
        // ratio 1.0 keeps everything bitwise
        let back = decode(&encode_topk(&data, 1.0)).unwrap();
        assert_eq!(back, data);
        // k floors at 1 for any non-empty payload
        assert_eq!(topk_k(5, 1e-6), 1);
        assert_eq!(topk_k(0, 0.5), 0);
    }

    #[test]
    fn decode_rejects_hostile_envelopes() {
        assert_eq!(decode(&[]), Err(CodecError::Truncated));
        assert_eq!(decode(&[TAG_F32, 1, 0]), Err(CodecError::Truncated));
        assert_eq!(decode(&encode_f32(&[1.0])[..7]), Err(CodecError::Truncated));
        let mut bad_tag = encode_f32(&[1.0]);
        bad_tag[0] = 9;
        assert_eq!(decode(&bad_tag), Err(CodecError::BadTag(9)));
        // oversized count: rejected before any allocation
        let mut huge = encode_f32(&[]);
        huge[1..5].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(decode(&huge), Err(CodecError::TooLarge(u32::MAX)));
        // non-finite scale
        let mut bad_scale = encode_int8(&[1.0, 2.0]);
        bad_scale[5..9].copy_from_slice(&f32::NAN.to_le_bytes());
        assert_eq!(decode(&bad_scale), Err(CodecError::BadScale));
        // top-k index out of range
        let mut bad_idx = encode_topk(&[1.0, 2.0], 0.5);
        bad_idx[9..13].copy_from_slice(&7u32.to_le_bytes());
        assert_eq!(
            decode(&bad_idx),
            Err(CodecError::BadIndex { idx: 7, n: 2 })
        );
        // trailing garbage
        let mut long = encode_int8(&[1.0]);
        long.push(0);
        assert!(matches!(decode(&long), Err(CodecError::Malformed(_))));
        // negotiated-codec mismatch
        assert_eq!(
            decode_expect(&encode_f32(&[1.0]), TAG_INT8),
            Err(CodecError::WrongCodec { got: TAG_F32, want: TAG_INT8 })
        );
    }

    #[test]
    fn transcode_matches_encode_then_decode() {
        let orig: Vec<f32> = (0..100).map(|i| (i as f32).cos()).collect();
        let mut data = orig.clone();
        let enc = transcode(Codec::Int8, &mut data);
        assert_eq!(enc, encode_int8(&orig));
        assert_eq!(data, decode(&enc).unwrap());
        // f32 transcode is the identity
        let mut same = orig.clone();
        transcode(Codec::F32, &mut same);
        assert_eq!(same, orig);
    }
}
