//! Pluggable blocking transports for the framed SFL protocol.
//!
//! Two backends implement [`Transport`]:
//!
//! * [`loopback_pair`] — an in-memory duplex that still *serializes every
//!   frame* (encode on send, decode on recv), so loopback tests measure
//!   real wire bytes and exercise the codec end to end;
//! * [`TcpTransport`] — `std::net::TcpStream` with blocking framed I/O
//!   (`TCP_NODELAY`; no async runtime — tokio is not in the offline
//!   vendor set, and the protocol is request/response-shaped anyway).
//!
//! Every endpoint owns an [`WireCounters`] (atomic, shared with its split
//! halves) whose [`WireCounters::snapshot`] feeds the round driver's
//! measured-traffic reporting.

use crate::coordinator::eventsim::WireRoundStats;
use crate::net::wire::{self, Msg};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Cumulative per-endpoint traffic counters (frame bytes, including the
/// 12-byte frame overhead). Shared across split halves via `Arc`.
#[derive(Debug, Default)]
pub struct WireCounters {
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    frames_sent: AtomicU64,
    frames_recv: AtomicU64,
}

impl WireCounters {
    fn note_sent(&self, bytes: u64) {
        self.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.frames_sent.fetch_add(1, Ordering::Relaxed);
    }

    fn note_recv(&self, bytes: u64) {
        self.bytes_recv.fetch_add(bytes, Ordering::Relaxed);
        self.frames_recv.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> WireRoundStats {
        WireRoundStats {
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_recv: self.frames_recv.load(Ordering::Relaxed),
        }
    }
}

/// One endpoint of a bidirectional, blocking, framed message channel.
/// `split` hands the two directions to different threads (the server's
/// dispatcher reads every connection from a reader thread while replying
/// from the orchestrator thread).
pub trait Transport: Send {
    fn send(&mut self, msg: &Msg) -> Result<()>;
    /// Blocking receive. `Ok(None)` means the peer closed cleanly at a
    /// frame boundary.
    fn recv(&mut self) -> Result<Option<Msg>>;
    fn counters(&self) -> Arc<WireCounters>;
    fn peer(&self) -> String;
    fn split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn RxHalf>);
}

pub trait TxHalf: Send {
    fn send(&mut self, msg: &Msg) -> Result<()>;
}

pub trait RxHalf: Send {
    fn recv(&mut self) -> Result<Option<Msg>>;
}

// ---------------------------------------------------------------------------
// in-memory loopback
// ---------------------------------------------------------------------------

/// One direction of a loopback connection: a bounded-by-memory queue of
/// *encoded frames* plus a closed flag. Senders close it on drop.
struct Pipe {
    state: Mutex<PipeState>,
    cv: Condvar,
}

#[derive(Default)]
struct PipeState {
    frames: VecDeque<Vec<u8>>,
    closed: bool,
}

impl Pipe {
    fn new() -> Arc<Self> {
        Arc::new(Pipe { state: Mutex::new(PipeState::default()), cv: Condvar::new() })
    }

    fn push(&self, frame: Vec<u8>) -> Result<()> {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if g.closed {
            bail!("loopback: send on closed pipe");
        }
        g.frames.push_back(frame);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self) -> Option<Vec<u8>> {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(f) = g.frames.pop_front() {
                return Some(f);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn close(&self) {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        g.closed = true;
        self.cv.notify_all();
    }
}

pub struct LoopbackTx {
    pipe: Arc<Pipe>,
    counters: Arc<WireCounters>,
}

impl Drop for LoopbackTx {
    fn drop(&mut self) {
        self.pipe.close();
    }
}

impl TxHalf for LoopbackTx {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let frame = wire::encode_frame_checked(msg)
            .with_context(|| format!("loopback: encoding {}", msg.name()))?;
        let n = frame.len() as u64;
        self.pipe.push(frame)?;
        self.counters.note_sent(n);
        Ok(())
    }
}

pub struct LoopbackRx {
    pipe: Arc<Pipe>,
    counters: Arc<WireCounters>,
}

impl RxHalf for LoopbackRx {
    fn recv(&mut self) -> Result<Option<Msg>> {
        let Some(frame) = self.pipe.pop() else {
            return Ok(None);
        };
        let (msg, used) = wire::decode_frame(&frame)
            .with_context(|| "loopback: decoding frame")?;
        if used != frame.len() {
            bail!("loopback: frame has {} trailing bytes", frame.len() - used);
        }
        self.counters.note_recv(used as u64);
        Ok(Some(msg))
    }
}

/// In-memory transport endpoint; see [`loopback_pair`].
pub struct LoopbackTransport {
    tx: LoopbackTx,
    rx: LoopbackRx,
    counters: Arc<WireCounters>,
    peer: String,
}

impl Transport for LoopbackTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.tx.send(msg)
    }

    fn recv(&mut self) -> Result<Option<Msg>> {
        self.rx.recv()
    }

    fn counters(&self) -> Arc<WireCounters> {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn RxHalf>) {
        (Box::new(self.tx), Box::new(self.rx))
    }
}

/// A connected pair of in-memory endpoints `(a, b)`: everything `a`
/// sends, `b` receives, and vice versa. Frames are fully encoded and
/// decoded in flight, so byte counters measure the real wire format.
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let ab = Pipe::new();
    let ba = Pipe::new();
    let ca = Arc::new(WireCounters::default());
    let cb = Arc::new(WireCounters::default());
    let a = LoopbackTransport {
        tx: LoopbackTx { pipe: ab.clone(), counters: ca.clone() },
        rx: LoopbackRx { pipe: ba.clone(), counters: ca.clone() },
        counters: ca,
        peer: "loopback:b".into(),
    };
    let b = LoopbackTransport {
        tx: LoopbackTx { pipe: ba, counters: cb.clone() },
        rx: LoopbackRx { pipe: ab, counters: cb.clone() },
        counters: cb,
        peer: "loopback:a".into(),
    };
    (a, b)
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

pub struct TcpTx {
    writer: BufWriter<TcpStream>,
    counters: Arc<WireCounters>,
}

impl TxHalf for TcpTx {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let n = wire::write_frame(&mut self.writer, msg)
            .with_context(|| format!("tcp: sending {}", msg.name()))?;
        self.counters.note_sent(n);
        Ok(())
    }
}

pub struct TcpRx {
    reader: BufReader<TcpStream>,
    counters: Arc<WireCounters>,
}

impl RxHalf for TcpRx {
    fn recv(&mut self) -> Result<Option<Msg>> {
        match wire::read_frame(&mut self.reader).context("tcp: reading frame")? {
            Some((msg, n)) => {
                self.counters.note_recv(n);
                Ok(Some(msg))
            }
            None => Ok(None),
        }
    }
}

/// Blocking framed I/O over one `TcpStream`.
pub struct TcpTransport {
    tx: TcpTx,
    rx: TcpRx,
    counters: Arc<WireCounters>,
    peer: String,
}

impl TcpTransport {
    /// Wrap an accepted / connected stream. Enables `TCP_NODELAY` — the
    /// locked exchange is a per-step request/response ping-pong and must
    /// not sit in Nagle buffers.
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("tcp: set_nodelay")?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "tcp:unknown".into());
        let counters = Arc::new(WireCounters::default());
        let rd = stream.try_clone().context("tcp: cloning stream")?;
        Ok(TcpTransport {
            tx: TcpTx {
                writer: BufWriter::new(stream),
                counters: counters.clone(),
            },
            rx: TcpRx {
                reader: BufReader::new(rd),
                counters: counters.clone(),
            },
            counters,
            peer,
        })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("tcp: connecting to {addr}"))?;
        Self::from_stream(stream)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.tx.send(msg)
    }

    fn recv(&mut self) -> Result<Option<Msg>> {
        self.rx.recv()
    }

    fn counters(&self) -> Arc<WireCounters> {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn RxHalf>) {
        (Box::new(self.tx), Box::new(self.rx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_counters() {
        let (mut a, mut b) = loopback_pair();
        let msg = Msg::Hello { name: "x".into(), protocol: 1 };
        a.send(&msg).unwrap();
        let got = b.recv().unwrap().unwrap();
        assert_eq!(got, msg);
        let ca = a.counters().snapshot();
        let cb = b.counters().snapshot();
        assert_eq!(ca.frames_sent, 1);
        assert_eq!(cb.frames_recv, 1);
        assert_eq!(ca.bytes_sent, cb.bytes_recv);
        assert!(ca.bytes_sent > wire::FRAME_OVERHEAD);
    }

    #[test]
    fn loopback_close_yields_clean_eof() {
        let (a, mut b) = loopback_pair();
        drop(a);
        assert!(b.recv().unwrap().is_none());
    }

    #[test]
    fn loopback_split_crosses_threads() {
        let (a, b) = loopback_pair();
        let (mut atx, _arx) = Box::new(a).split();
        let (_btx, mut brx) = Box::new(b).split();
        let t = std::thread::spawn(move || brx.recv().unwrap().unwrap());
        atx.send(&Msg::Shutdown { reason: "bye".into() }).unwrap();
        assert_eq!(t.join().unwrap(), Msg::Shutdown { reason: "bye".into() });
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(s).unwrap();
            let m = t.recv().unwrap().unwrap();
            t.send(&m).unwrap(); // echo
            t.recv().unwrap() // observe close
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let msg = Msg::ZoUpdate {
            client: 0,
            round: 1,
            seeds: vec![42],
            scalars: vec![1.25],
            gscales: vec![0.5, -0.5],
        };
        c.send(&msg).unwrap();
        assert_eq!(c.recv().unwrap().unwrap(), msg);
        drop(c);
        assert!(server.join().unwrap().is_none());
    }
}
