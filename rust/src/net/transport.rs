//! Pluggable blocking transports for the framed SFL protocol.
//!
//! Two backends implement [`Transport`]:
//!
//! * [`loopback_pair`] — an in-memory duplex that still *serializes every
//!   frame* (encode on send, decode on recv), so loopback tests measure
//!   real wire bytes and exercise the codec end to end;
//! * [`TcpTransport`] — `std::net::TcpStream` with blocking framed I/O
//!   (`TCP_NODELAY`; no async runtime — tokio is not in the offline
//!   vendor set, and the protocol is request/response-shaped anyway).
//!
//! Every endpoint owns an [`WireCounters`] (atomic, shared with its split
//! halves) whose [`WireCounters::snapshot`] feeds the round driver's
//! measured-traffic reporting.

use crate::coordinator::eventsim::WireRoundStats;
use crate::net::poller::{Fill, PollSource};
use crate::net::wire::{self, Msg};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Cumulative per-endpoint traffic counters (frame bytes, including the
/// 12-byte frame overhead). Shared across split halves via `Arc`.
#[derive(Debug, Default)]
pub struct WireCounters {
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    frames_sent: AtomicU64,
    frames_recv: AtomicU64,
}

impl WireCounters {
    fn note_sent(&self, bytes: u64) {
        self.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.frames_sent.fetch_add(1, Ordering::Relaxed);
    }

    /// One received frame of `bytes` total size. `pub(crate)` so the
    /// event-driven poller (`net::poller`), which decodes frames out of
    /// raw reassembly buffers, can account them on the same counters as
    /// the blocking `RxHalf` path.
    pub(crate) fn note_recv(&self, bytes: u64) {
        self.bytes_recv.fetch_add(bytes, Ordering::Relaxed);
        self.frames_recv.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> WireRoundStats {
        WireRoundStats {
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_recv: self.frames_recv.load(Ordering::Relaxed),
        }
    }
}

/// One endpoint of a bidirectional, blocking, framed message channel.
/// `split` hands the two directions to different threads (the server's
/// dispatcher reads every connection from a reader thread while replying
/// from the orchestrator thread).
pub trait Transport: Send {
    fn send(&mut self, msg: &Msg) -> Result<()>;
    /// Blocking receive. `Ok(None)` means the peer closed cleanly at a
    /// frame boundary.
    fn recv(&mut self) -> Result<Option<Msg>>;
    fn counters(&self) -> Arc<WireCounters>;
    fn peer(&self) -> String;
    fn split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn RxHalf>);
    /// Split into a send half plus a **non-blocking byte source** for the
    /// event-driven server poller (`net::poller`). Unlike [`split`], the
    /// receive side stops being frame-granular: the poller reads raw
    /// bytes into per-connection reassembly buffers and decodes frames
    /// incrementally. Any bytes the blocking handshake path buffered but
    /// did not consume must carry over into the source.
    fn poll_split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn PollSource>);
}

pub trait TxHalf: Send {
    fn send(&mut self, msg: &Msg) -> Result<()>;
}

pub trait RxHalf: Send {
    fn recv(&mut self) -> Result<Option<Msg>>;
}

// ---------------------------------------------------------------------------
// in-memory loopback
// ---------------------------------------------------------------------------

/// One direction of a loopback connection: a bounded-by-memory queue of
/// *encoded frames* plus a closed flag. Senders close it on drop.
struct Pipe {
    state: Mutex<PipeState>,
    cv: Condvar,
}

#[derive(Default)]
struct PipeState {
    frames: VecDeque<Vec<u8>>,
    closed: bool,
}

impl Pipe {
    fn new() -> Arc<Self> {
        Arc::new(Pipe { state: Mutex::new(PipeState::default()), cv: Condvar::new() })
    }

    fn push(&self, frame: Vec<u8>) -> Result<()> {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if g.closed {
            bail!("loopback: send on closed pipe");
        }
        g.frames.push_back(frame);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self) -> Option<Vec<u8>> {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(f) = g.frames.pop_front() {
                return Some(f);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Non-blocking pop for the poller path: a frame if one is queued,
    /// otherwise whether the pipe is merely empty or closed for good.
    fn try_pop(&self) -> TryPop {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        match g.frames.pop_front() {
            Some(f) => TryPop::Frame(f),
            None if g.closed => TryPop::Closed,
            None => TryPop::Empty,
        }
    }

    fn close(&self) {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        g.closed = true;
        self.cv.notify_all();
    }
}

enum TryPop {
    Frame(Vec<u8>),
    Empty,
    Closed,
}

pub struct LoopbackTx {
    pipe: Arc<Pipe>,
    counters: Arc<WireCounters>,
}

impl Drop for LoopbackTx {
    fn drop(&mut self) {
        self.pipe.close();
    }
}

impl TxHalf for LoopbackTx {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let tag = msg.tag();
        let frame = wire::encode_frame_checked(msg)
            .with_context(|| format!("loopback: encoding {}", msg.name()))?;
        let n = frame.len() as u64;
        let _s = crate::span!("wire_send", tag = tag, bytes = n);
        self.pipe.push(frame)?;
        self.counters.note_sent(n);
        crate::telemetry::note_tx(tag, n);
        Ok(())
    }
}

pub struct LoopbackRx {
    pipe: Arc<Pipe>,
    counters: Arc<WireCounters>,
}

impl RxHalf for LoopbackRx {
    fn recv(&mut self) -> Result<Option<Msg>> {
        let Some(frame) = self.pipe.pop() else {
            return Ok(None);
        };
        let (msg, used) = wire::decode_frame(&frame)
            .with_context(|| "loopback: decoding frame")?;
        if used != frame.len() {
            bail!("loopback: frame has {} trailing bytes", frame.len() - used);
        }
        self.counters.note_recv(used as u64);
        crate::telemetry::note_rx(msg.tag(), used as u64);
        crate::telemetry::instant("wire_recv", "tag", msg.tag() as u64);
        Ok(Some(msg))
    }
}

/// Poller-side view of a loopback receive pipe: serves the queued
/// *encoded frame bytes* in arbitrary-size chunks, so the server's
/// reassembly path is exercised end to end even in-memory.
pub struct LoopbackSource {
    pipe: Arc<Pipe>,
    pending: Vec<u8>,
    off: usize,
}

impl PollSource for LoopbackSource {
    fn fill(&mut self, buf: &mut [u8]) -> std::io::Result<Fill> {
        if self.off == self.pending.len() {
            match self.pipe.try_pop() {
                TryPop::Frame(f) => {
                    self.pending = f;
                    self.off = 0;
                }
                TryPop::Empty => return Ok(Fill::WouldBlock),
                TryPop::Closed => return Ok(Fill::Eof),
            }
        }
        let n = buf.len().min(self.pending.len() - self.off);
        buf[..n].copy_from_slice(&self.pending[self.off..self.off + n]);
        self.off += n;
        Ok(Fill::Bytes(n))
    }
}

/// In-memory transport endpoint; see [`loopback_pair`].
pub struct LoopbackTransport {
    tx: LoopbackTx,
    rx: LoopbackRx,
    counters: Arc<WireCounters>,
    peer: String,
}

impl Transport for LoopbackTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.tx.send(msg)
    }

    fn recv(&mut self) -> Result<Option<Msg>> {
        self.rx.recv()
    }

    fn counters(&self) -> Arc<WireCounters> {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn RxHalf>) {
        (Box::new(self.tx), Box::new(self.rx))
    }

    fn poll_split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn PollSource>) {
        let src = LoopbackSource {
            pipe: self.rx.pipe.clone(),
            pending: Vec::new(),
            off: 0,
        };
        (Box::new(self.tx), Box::new(src))
    }
}

/// A connected pair of in-memory endpoints `(a, b)`: everything `a`
/// sends, `b` receives, and vice versa. Frames are fully encoded and
/// decoded in flight, so byte counters measure the real wire format.
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let ab = Pipe::new();
    let ba = Pipe::new();
    let ca = Arc::new(WireCounters::default());
    let cb = Arc::new(WireCounters::default());
    let a = LoopbackTransport {
        tx: LoopbackTx { pipe: ab.clone(), counters: ca.clone() },
        rx: LoopbackRx { pipe: ba.clone(), counters: ca.clone() },
        counters: ca,
        peer: "loopback:b".into(),
    };
    let b = LoopbackTransport {
        tx: LoopbackTx { pipe: ba, counters: cb.clone() },
        rx: LoopbackRx { pipe: ab, counters: cb.clone() },
        counters: cb,
        peer: "loopback:a".into(),
    };
    (a, b)
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

pub struct TcpTx {
    writer: BufWriter<TcpStream>,
    counters: Arc<WireCounters>,
}

impl TxHalf for TcpTx {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let _s = crate::span!("wire_send", tag = msg.tag());
        let n = wire::write_frame(&mut self.writer, msg)
            .with_context(|| format!("tcp: sending {}", msg.name()))?;
        self.counters.note_sent(n);
        crate::telemetry::note_tx(msg.tag(), n);
        Ok(())
    }
}

pub struct TcpRx {
    reader: BufReader<TcpStream>,
    counters: Arc<WireCounters>,
}

impl RxHalf for TcpRx {
    fn recv(&mut self) -> Result<Option<Msg>> {
        match wire::read_frame(&mut self.reader).context("tcp: reading frame")? {
            Some((msg, n)) => {
                self.counters.note_recv(n);
                crate::telemetry::note_rx(msg.tag(), n);
                crate::telemetry::instant("wire_recv", "tag", msg.tag() as u64);
                Ok(Some(msg))
            }
            None => Ok(None),
        }
    }
}

/// Write half used after `poll_split`. Because `poll_split` flips the
/// shared socket to non-blocking mode (`try_clone` duplicates the fd, so
/// `O_NONBLOCK` applies to both directions), sends here loop over raw
/// `write` calls and absorb `WouldBlock` with a short park instead of
/// relying on `write_all`.
pub struct NbTcpTx {
    stream: TcpStream,
    counters: Arc<WireCounters>,
}

impl TxHalf for NbTcpTx {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let frame = wire::encode_frame_checked(msg)
            .with_context(|| format!("tcp: encoding {}", msg.name()))?;
        let _s =
            crate::span!("wire_send", tag = msg.tag(), bytes = frame.len());
        let mut off = 0usize;
        while off < frame.len() {
            match self.stream.write(&frame[off..]) {
                Ok(0) => bail!("tcp: peer closed mid-write"),
                Ok(n) => off += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("tcp: sending {}", msg.name())
                    })
                }
            }
        }
        self.counters.note_sent(frame.len() as u64);
        crate::telemetry::note_tx(msg.tag(), frame.len() as u64);
        Ok(())
    }
}

/// Poller-side view of a TCP read half: non-blocking reads, preceded by
/// whatever the handshake-era `BufReader` had already buffered.
pub struct TcpSource {
    stream: TcpStream,
    carry: Vec<u8>,
    off: usize,
}

impl PollSource for TcpSource {
    fn fill(&mut self, buf: &mut [u8]) -> std::io::Result<Fill> {
        if self.off < self.carry.len() {
            let n = buf.len().min(self.carry.len() - self.off);
            buf[..n].copy_from_slice(&self.carry[self.off..self.off + n]);
            self.off += n;
            if self.off == self.carry.len() {
                self.carry.clear();
                self.off = 0;
            }
            return Ok(Fill::Bytes(n));
        }
        match self.stream.read(buf) {
            Ok(0) => Ok(Fill::Eof),
            Ok(n) => Ok(Fill::Bytes(n)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                Ok(Fill::WouldBlock)
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                Ok(Fill::WouldBlock)
            }
            Err(e) => Err(e),
        }
    }
}

/// Blocking framed I/O over one `TcpStream`.
pub struct TcpTransport {
    tx: TcpTx,
    rx: TcpRx,
    counters: Arc<WireCounters>,
    peer: String,
}

impl TcpTransport {
    /// Wrap an accepted / connected stream. Enables `TCP_NODELAY` — the
    /// locked exchange is a per-step request/response ping-pong and must
    /// not sit in Nagle buffers.
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("tcp: set_nodelay")?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "tcp:unknown".into());
        let counters = Arc::new(WireCounters::default());
        let rd = stream.try_clone().context("tcp: cloning stream")?;
        Ok(TcpTransport {
            tx: TcpTx {
                writer: BufWriter::new(stream),
                counters: counters.clone(),
            },
            rx: TcpRx {
                reader: BufReader::new(rd),
                counters: counters.clone(),
            },
            counters,
            peer,
        })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("tcp: connecting to {addr}"))?;
        Self::from_stream(stream)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.tx.send(msg)
    }

    fn recv(&mut self) -> Result<Option<Msg>> {
        self.rx.recv()
    }

    fn counters(&self) -> Arc<WireCounters> {
        self.counters.clone()
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn RxHalf>) {
        (Box::new(self.tx), Box::new(self.rx))
    }

    fn poll_split(self: Box<Self>) -> (Box<dyn TxHalf>, Box<dyn PollSource>) {
        // Carry over anything the handshake's BufReader consumed off the
        // socket but did not hand out yet — those bytes never reappear
        // on the raw fd.
        let mut reader = self.rx.reader;
        let carry = reader.buffer().to_vec();
        let stream = reader.into_inner();
        // Shared fd: this flips *both* directions to non-blocking, which
        // NbTcpTx is written for.
        let _ = stream.set_nonblocking(true);
        // write_frame flushes after every send, so the BufWriter holds
        // no unflushed handshake bytes here.
        let (tx_stream, _) = self.tx.writer.into_parts();
        let tx = NbTcpTx { stream: tx_stream, counters: self.counters.clone() };
        let src = TcpSource { stream, carry, off: 0 };
        (Box::new(tx), Box::new(src))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_counters() {
        let (mut a, mut b) = loopback_pair();
        let msg = Msg::Hello {
            name: "x".into(),
            protocol: 1,
            lanes: 1,
            codecs: vec![0],
        };
        a.send(&msg).unwrap();
        let got = b.recv().unwrap().unwrap();
        assert_eq!(got, msg);
        let ca = a.counters().snapshot();
        let cb = b.counters().snapshot();
        assert_eq!(ca.frames_sent, 1);
        assert_eq!(cb.frames_recv, 1);
        assert_eq!(ca.bytes_sent, cb.bytes_recv);
        assert!(ca.bytes_sent > wire::FRAME_OVERHEAD);
    }

    #[test]
    fn loopback_close_yields_clean_eof() {
        let (a, mut b) = loopback_pair();
        drop(a);
        assert!(b.recv().unwrap().is_none());
    }

    #[test]
    fn loopback_split_crosses_threads() {
        let (a, b) = loopback_pair();
        let (mut atx, _arx) = Box::new(a).split();
        let (_btx, mut brx) = Box::new(b).split();
        let t = std::thread::spawn(move || brx.recv().unwrap().unwrap());
        atx.send(&Msg::Shutdown { reason: "bye".into() }).unwrap();
        assert_eq!(t.join().unwrap(), Msg::Shutdown { reason: "bye".into() });
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(s).unwrap();
            let m = t.recv().unwrap().unwrap();
            t.send(&m).unwrap(); // echo
            t.recv().unwrap() // observe close
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let msg = Msg::ZoUpdate {
            lane: 0,
            client: 0,
            round: 1,
            seeds: vec![42],
            scalars: vec![1.25],
            gscales: vec![0.5, -0.5],
        };
        c.send(&msg).unwrap();
        assert_eq!(c.recv().unwrap().unwrap(), msg);
        drop(c);
        assert!(server.join().unwrap().is_none());
    }
}
