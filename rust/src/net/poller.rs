//! Event-driven server reception: a hand-rolled readiness poller over
//! `std` (no `mio`/`epoll` in the offline vendor set).
//!
//! The PR-3 dispatcher spawned one blocking reader thread per
//! connection — fine for a 2-client smoke run, a hard wall for the
//! 10k-client north star. This module replaces that with the
//! Autobahn-style split the ROADMAP cites: a **small sharded set of
//! poll loops** own the non-blocking read sides of many connections
//! each, parse frames incrementally into per-connection [`Reassembly`]
//! buffers, and feed one [`EventQueue`] consumed by the single-owner
//! orchestrator (`net::server::run_rounds`). Readiness is emulated by
//! sweeping sources and parking briefly when a full sweep makes no
//! progress — the honest `std`-only equivalent of an epoll wait.
//!
//! Decoding never trusts the peer: [`wire::decode_frame`] bounds every
//! length field before allocating, truncated buffers simply wait for
//! more bytes, and a connection that closes mid-frame or ships a
//! corrupt frame surfaces as a typed [`Event::Err`] instead of a panic.

use crate::net::transport::WireCounters;
use crate::net::wire::{self, Msg, WireError};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Result of one non-blocking read attempt on a [`PollSource`].
pub enum Fill {
    /// `n` bytes were copied into the front of the scratch buffer.
    Bytes(usize),
    /// Nothing available right now; try again next sweep.
    WouldBlock,
    /// Peer closed the stream.
    Eof,
}

/// A non-blocking byte stream the poller can sweep: the read half of a
/// transport after `Transport::poll_split`.
pub trait PollSource: Send {
    fn fill(&mut self, buf: &mut [u8]) -> std::io::Result<Fill>;
}

// ---------------------------------------------------------------------------
// per-connection frame reassembly
// ---------------------------------------------------------------------------

/// Incremental frame parser: bytes go in in arbitrary chunks (down to
/// one at a time), complete frames come out. A consumed prefix is
/// compacted away once it crosses a threshold so a long-lived
/// connection does not grow its buffer without bound.
pub struct Reassembly {
    buf: Vec<u8>,
    start: usize,
}

/// Compact the consumed prefix once it exceeds this many bytes.
const COMPACT_THRESHOLD: usize = 1 << 16;

impl Reassembly {
    pub fn new() -> Self {
        Reassembly { buf: Vec::new(), start: 0 }
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        if self.start >= COMPACT_THRESHOLD {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet decoded into a frame.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }

    /// Decode the next complete frame, if the buffer holds one.
    /// `Ok(None)` means "need more bytes"; a hard codec violation
    /// (bad magic/version/tag, checksum mismatch, oversized length)
    /// is a typed error — the connection is unrecoverable past it.
    pub fn next_frame(&mut self) -> Result<Option<(Msg, usize)>, WireError> {
        match wire::decode_frame(&self.buf[self.start..]) {
            Ok((msg, used)) => {
                self.start += used;
                if self.start == self.buf.len() {
                    self.buf.clear();
                    self.start = 0;
                }
                Ok(Some((msg, used)))
            }
            Err(WireError::Truncated) => Ok(None),
            Err(e) => Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// the orchestrator event queue
// ---------------------------------------------------------------------------

/// What a poll loop tells the orchestrator about connection `conn`.
pub enum Event {
    Msg(Msg),
    /// Peer closed cleanly at a frame boundary.
    Closed,
    /// Peer vanished — EOF mid-frame or a transport read error — the
    /// first-class churn signal. `mid_frame` distinguishes a kill in
    /// the middle of an upload from one between frames; `pending` is
    /// how many partial-frame bytes died with it. The dispatcher marks
    /// the connection's lanes dead, drops its clients from the open
    /// round, and NACKs nothing retroactively.
    PeerDisconnected {
        mid_frame: bool,
        pending: usize,
        detail: String,
    },
    /// Codec violation (bad magic/version/tag, checksum mismatch,
    /// oversized length): the peer is alive but speaking garbage.
    Err(String),
}

/// The single queue every poll shard feeds and the orchestrator drains —
/// the reception-threads-into-one-core-loop bridge.
pub struct EventQueue {
    q: Mutex<VecDeque<(usize, Event)>>,
    cv: Condvar,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    pub fn push(&self, conn: usize, ev: Event) {
        let mut g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        g.push_back((conn, ev));
        self.cv.notify_one();
    }

    /// Blocking pop (the orchestrator has nothing else to do mid-round).
    pub fn pop(&self) -> (usize, Event) {
        let mut g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(ev) = g.pop_front() {
                return ev;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Pop with a wait bound: `None` after `timeout` with no event. The
    /// dispatcher uses this when a round deadline or a shutdown flag
    /// needs periodic re-checking; with neither armed it stays on the
    /// plain [`Self::pop`], whose behavior is unchanged.
    pub fn pop_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Option<(usize, Event)> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(ev) = g.pop_front() {
                return Some(ev);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            g = guard;
            if res.timed_out() && g.is_empty() {
                return None;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// poll loops
// ---------------------------------------------------------------------------

/// One registered connection: its global index, non-blocking read side,
/// and the traffic counters shared with its send half.
pub struct PollConn {
    pub conn: usize,
    pub src: Box<dyn PollSource>,
    pub counters: Arc<WireCounters>,
}

/// Poll shards a `serve` run uses — a handful of reception loops no
/// matter how many sockets attach (each loop sweeps many connections).
pub const DEFAULT_SHARDS: usize = 4;

/// Scratch read size per `fill` call.
const SCRATCH: usize = 16 * 1024;

/// Cap on consecutive fills from one connection per sweep, so one
/// firehose peer cannot starve its shard-mates.
const MAX_FILLS_PER_SWEEP: usize = 8;

/// Park time when a full sweep over every live connection moved no
/// bytes (the emulated "wait for readiness").
const IDLE_PARK: std::time::Duration = std::time::Duration::from_micros(100);

/// Distribute connections round-robin over `shards` poll loops.
/// Returns only non-empty shards.
pub fn shard_conns(conns: Vec<PollConn>, shards: usize) -> Vec<Vec<PollConn>> {
    let shards = shards.max(1);
    let mut out: Vec<Vec<PollConn>> = (0..shards).map(|_| Vec::new()).collect();
    for (i, c) in conns.into_iter().enumerate() {
        out[i % shards].push(c);
    }
    out.retain(|s| !s.is_empty());
    out
}

/// Run one poll loop to completion: sweep every connection's source,
/// feed decoded frames into `events`, and exit once every connection
/// has reached EOF or a hard error. Frame bytes are accounted on each
/// connection's [`WireCounters`] exactly like the blocking receive
/// path, so measured-wire reporting is unchanged.
pub fn poll_shard(conns: Vec<PollConn>, events: &EventQueue) {
    let stop = std::sync::atomic::AtomicBool::new(false);
    poll_shard_adopt(conns, events, None, &stop);
}

/// [`poll_shard`] plus mid-run adoption: each sweep drains `inbox` —
/// connections the dispatcher re-accepted after a peer died and
/// re-handshook between rounds — into the live set. With an inbox the
/// loop does not exit when its last connection dies (a rejoiner may
/// be on the way); it parks until `stop` is raised at end of run.
pub fn poll_shard_adopt(
    mut conns: Vec<PollConn>,
    events: &EventQueue,
    inbox: Option<&Mutex<Vec<PollConn>>>,
    stop: &std::sync::atomic::AtomicBool,
) {
    use std::sync::atomic::Ordering;
    let mut reasm: Vec<Reassembly> =
        conns.iter().map(|_| Reassembly::new()).collect();
    let mut live = vec![true; conns.len()];
    let mut n_live = conns.len();
    let mut scratch = vec![0u8; SCRATCH];
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if let Some(ib) = inbox {
            let mut g = ib.lock().unwrap_or_else(|p| p.into_inner());
            for c in g.drain(..) {
                conns.push(c);
                reasm.push(Reassembly::new());
                live.push(true);
                n_live += 1;
            }
        }
        if n_live == 0 {
            if inbox.is_none() {
                return;
            }
            std::thread::sleep(IDLE_PARK);
            continue;
        }
        let mut progress = false;
        for i in 0..conns.len() {
            if !live[i] {
                continue;
            }
            let mut fills = 0;
            loop {
                match conns[i].src.fill(&mut scratch) {
                    Ok(Fill::Bytes(n)) => {
                        progress = true;
                        reasm[i].extend(&scratch[..n]);
                        if let Err(e) =
                            drain_frames(&mut reasm[i], &conns[i], events)
                        {
                            events.push(
                                conns[i].conn,
                                Event::Err(format!("wire error: {e}")),
                            );
                            live[i] = false;
                            n_live -= 1;
                            break;
                        }
                        fills += 1;
                        if fills >= MAX_FILLS_PER_SWEEP {
                            break;
                        }
                    }
                    Ok(Fill::WouldBlock) => break,
                    Ok(Fill::Eof) => {
                        if reasm[i].is_empty() {
                            events.push(conns[i].conn, Event::Closed);
                        } else {
                            let pending = reasm[i].pending();
                            events.push(
                                conns[i].conn,
                                Event::PeerDisconnected {
                                    mid_frame: true,
                                    pending,
                                    detail: format!(
                                        "connection closed mid-frame \
                                         ({pending} bytes of partial frame)"
                                    ),
                                },
                            );
                        }
                        live[i] = false;
                        n_live -= 1;
                        break;
                    }
                    Err(e) => {
                        let pending = reasm[i].pending();
                        events.push(
                            conns[i].conn,
                            Event::PeerDisconnected {
                                mid_frame: pending > 0,
                                pending,
                                detail: format!("read error: {e}"),
                            },
                        );
                        live[i] = false;
                        n_live -= 1;
                        break;
                    }
                }
            }
        }
        if n_live > 0 && !progress {
            std::thread::sleep(IDLE_PARK);
        }
    }
}

fn drain_frames(
    reasm: &mut Reassembly,
    conn: &PollConn,
    events: &EventQueue,
) -> Result<(), WireError> {
    while let Some((msg, used)) = reasm.next_frame()? {
        conn.counters.note_recv(used as u64);
        crate::telemetry::note_rx(msg.tag(), used as u64);
        crate::telemetry::instant("wire_recv", "tag", msg.tag() as u64);
        events.push(conn.conn, Event::Msg(msg));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::encode_frame;

    fn msgs() -> Vec<Msg> {
        vec![
            Msg::Hello {
                name: "edge".into(),
                protocol: 6,
                lanes: 2,
                codecs: crate::net::codec::SUPPORTED.to_vec(),
            },
            Msg::ZoUpdate {
                lane: 0,
                client: 0,
                round: 1,
                seeds: vec![7, -9],
                scalars: vec![0.5, 1.25],
                gscales: vec![0.125; 4],
            },
            Msg::SmashedSeq {
                lane: 1,
                client: 3,
                round: 1,
                step: 2,
                seq: 1,
                sent_at: 0.25,
                smashed: crate::net::codec::encode_f32(&[1.0; 16]),
                targets: vec![0, 2, 1],
            },
            Msg::Shutdown { reason: "bye".into() },
        ]
    }

    #[test]
    fn reassembly_decodes_one_byte_at_a_time() {
        let msgs = msgs();
        let stream: Vec<u8> =
            msgs.iter().flat_map(encode_frame).collect();
        let mut r = Reassembly::new();
        let mut got = Vec::new();
        for &b in &stream {
            r.extend(&[b]);
            while let Some((m, _)) = r.next_frame().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, msgs);
        assert!(r.is_empty());
    }

    #[test]
    fn reassembly_handles_frames_split_at_every_boundary() {
        let frame = encode_frame(&msgs()[2]);
        for cut in 1..frame.len() {
            let mut r = Reassembly::new();
            r.extend(&frame[..cut]);
            assert!(r.next_frame().unwrap().is_none(), "cut {cut}");
            r.extend(&frame[cut..]);
            let (m, used) = r.next_frame().unwrap().unwrap();
            assert_eq!(used, frame.len());
            assert_eq!(m, msgs()[2]);
            assert!(r.is_empty());
        }
    }

    #[test]
    fn reassembly_surfaces_corruption_as_typed_error() {
        let mut frame = encode_frame(&msgs()[1]);
        let last = frame.len() - 1;
        frame[last] ^= 0xFF; // flip a CRC byte
        let mut r = Reassembly::new();
        r.extend(&frame);
        assert!(matches!(
            r.next_frame(),
            Err(WireError::BadChecksum { .. })
        ));
    }

    /// Scripted byte source: hands out chunks in order, then EOF.
    struct Script {
        chunks: VecDeque<Vec<u8>>,
    }

    impl PollSource for Script {
        fn fill(&mut self, buf: &mut [u8]) -> std::io::Result<Fill> {
            match self.chunks.pop_front() {
                Some(c) => {
                    assert!(c.len() <= buf.len());
                    buf[..c.len()].copy_from_slice(&c);
                    Ok(Fill::Bytes(c.len()))
                }
                None => Ok(Fill::Eof),
            }
        }
    }

    fn one_byte_chunks(frames: &[Vec<u8>]) -> VecDeque<Vec<u8>> {
        frames
            .iter()
            .flat_map(|f| f.iter().map(|&b| vec![b]))
            .collect()
    }

    #[test]
    fn poll_shard_decodes_interleaved_lanes_and_reports_eof() {
        // Two lanes' uploads interleaved on one connection, written one
        // byte at a time; a second connection disconnects mid-frame.
        let m = msgs();
        let frames =
            vec![encode_frame(&m[1]), encode_frame(&m[2]), encode_frame(&m[1])];
        let good = Script { chunks: one_byte_chunks(&frames) };
        let partial = encode_frame(&m[2]);
        let bad = Script {
            chunks: one_byte_chunks(&[partial[..partial.len() / 2].to_vec()]),
        };
        let events = EventQueue::new();
        let conns = vec![
            PollConn {
                conn: 0,
                src: Box::new(good),
                counters: Arc::new(WireCounters::default()),
            },
            PollConn {
                conn: 7,
                src: Box::new(bad),
                counters: Arc::new(WireCounters::default()),
            },
        ];
        let c0 = conns[0].counters.clone();
        poll_shard(conns, &events);
        let mut got0 = Vec::new();
        let mut closed0 = false;
        let mut err7 = false;
        for _ in 0..5 {
            match events.pop() {
                (0, Event::Msg(msg)) => got0.push(msg),
                (0, Event::Closed) => closed0 = true,
                (
                    7,
                    Event::PeerDisconnected { mid_frame, pending, detail },
                ) => {
                    assert!(mid_frame, "half a frame was buffered");
                    assert!(pending > 0);
                    assert!(detail.contains("mid-frame"), "{detail}");
                    err7 = true;
                }
                (c, _) => panic!("unexpected event from conn {c}"),
            }
        }
        assert_eq!(got0, vec![m[1].clone(), m[2].clone(), m[1].clone()]);
        assert!(closed0 && err7);
        let snap = c0.snapshot();
        assert_eq!(snap.frames_recv, 3);
        assert_eq!(
            snap.bytes_recv as usize,
            frames.iter().map(Vec::len).sum::<usize>()
        );
    }

    #[test]
    fn poll_shard_rejects_garbage_without_panic() {
        let events = EventQueue::new();
        let conns = vec![PollConn {
            conn: 3,
            src: Box::new(Script {
                chunks: VecDeque::from([vec![0xDE, 0xAD, 0xBE, 0xEF,
                                             0xDE, 0xAD, 0xBE, 0xEF]]),
            }),
            counters: Arc::new(WireCounters::default()),
        }];
        poll_shard(conns, &events);
        match events.pop() {
            (3, Event::Err(_)) => {}
            _ => panic!("garbage must surface as a typed error"),
        }
    }

    #[test]
    fn pop_timeout_times_out_idle_and_delivers_queued() {
        let q = EventQueue::new();
        let t0 = std::time::Instant::now();
        assert!(q
            .pop_timeout(std::time::Duration::from_millis(20))
            .is_none());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        q.push(5, Event::Closed);
        match q.pop_timeout(std::time::Duration::from_millis(20)) {
            Some((5, Event::Closed)) => {}
            _ => panic!("queued event must come back before the timeout"),
        }
    }

    #[test]
    fn shard_conns_distributes_round_robin() {
        let mk = |i| PollConn {
            conn: i,
            src: Box::new(Script { chunks: VecDeque::new() })
                as Box<dyn PollSource>,
            counters: Arc::new(WireCounters::default()),
        };
        let shards = shard_conns((0..10).map(mk).collect(), 4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(Vec::len).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        // fewer conns than shards → no empty shards
        let shards = shard_conns((0..2).map(mk).collect(), 4);
        assert_eq!(shards.len(), 2);
    }
}
