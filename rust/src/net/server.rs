//! Networked Main/Fed-Server dispatcher: accepts N client connections —
//! each multiplexing any number of virtual-client *lanes* — and bridges
//! decoded wire messages into the *existing* round engine
//! (`ServerQueue` + `Driver::server_drain`/`finish_round`).
//!
//! ## Event-driven reception core (v4)
//!
//! Connections are no longer owned by one reader thread each. After the
//! blocking `Hello`/`Assign` handshake every transport is split into a
//! write half (owned by the orchestrator) and a non-blocking
//! [`crate::net::poller::PollSource`]; the sources are sharded
//! round-robin over a small fixed set of poll loops
//! ([`crate::net::poller::poll_shard`], `DEFAULT_SHARDS` threads total —
//! not per connection). Each loop sweeps its connections, reads whatever
//! bytes are available, reassembles frames incrementally in
//! per-connection buffers, and pushes decoded messages into the single
//! [`crate::net::poller::EventQueue`] the orchestrator consumes. A
//! thousand mostly-idle connections therefore cost a thousand reassembly
//! buffers, not a thousand parked threads.
//!
//! ## Client multiplexing and cohort scheduling
//!
//! `Hello{lanes}` declares how many virtual clients ride the connection;
//! the dispatcher derives one global lane index over all connections and
//! assigns logical client `i` to global lane `i % total_lanes` (which
//! degenerates to the classic `i % n_conns` round-robin when every
//! connection runs a single lane). One `Assign{lane, ..}` goes out per
//! lane, and every client→server upload is stamped with its lane, so
//! ownership is validated per `(conn, lane)` — a lane cannot speak for a
//! client that rides a different lane of the *same* socket.
//!
//! Per round the driver samples a cohort from the registered population
//! and holds round state only for it: the event-sim is cohort-scoped,
//! the driver's lazy client pool materializes per-client state only on
//! first touch (the networked server itself touches none), and SFLV1
//! server replicas exist only for the round's participants. Orchestrator
//! round state is O(cohort), not O(population).
//!
//! ## Fault tolerance (v5)
//!
//! Three failure modes are first-class, not run-killers:
//!
//! * **Straggler cutoff** — with `--round_deadline_ms` set, the
//!   decoupled collect loop waits at most that much *wall-clock* time
//!   per round (the in-process driver applies the same knob in
//!   *virtual* event-sim time); participants that have not delivered
//!   `LocalDone` by the deadline are cut: their queued uploads are
//!   discarded at the barrier ([`crate::coordinator::drain`], straggler
//!   cutoff), their θ is excluded from FedAvg, and their late traffic
//!   is tolerated — uploads get a typed NACK so the straggler is never
//!   wedged on an ack, everything else is dropped. With the flag unset
//!   the loop uses the plain blocking pop and behaves bit-identically
//!   to a deadline-free build.
//! * **Typed churn** — the poller surfaces a vanished peer as
//!   [`Event::PeerDisconnected`]; the dispatcher marks that
//!   connection's lanes dead, cuts its clients from the open round
//!   (finalizing early — possibly empty — if the cohort empties), and
//!   NACKs nothing retroactively. A connection that reconnects while
//!   the run is live (`serve` keeps accepting) is re-handshaken
//!   *between rounds*: it takes over a dead connection's lane block
//!   and its `Assign` carries `rejoin_round` plus per-client
//!   completed-phase counts, so it never replays a stale round and its
//!   data streams fast-forward to the exact batch an uninterrupted
//!   client would read next. Locked SFLV1/V2 keep the strict
//!   fail-stop behavior — the training lock is the baseline's defining
//!   property and churn-tolerance would change what is being measured.
//! * **Checkpoint/restore** — every `--checkpoint_every` rounds the
//!   driver state is serialized to a CRC-checksummed file
//!   ([`crate::coordinator::checkpoint`]); `serve --restore <path>`
//!   resumes at the checkpointed round and finishes **bit-identically**
//!   to an uninterrupted run for the stateless-optimizer variants
//!   (asserted in `rust/tests/chaos.rs` and kill-9'd for real by
//!   `scripts/chaos_smoke.sh`). On SIGINT/SIGTERM the server writes a
//!   final checkpoint at the last round boundary and broadcasts a clean
//!   `Shutdown`, so `^C` is a restorable exit, not a lost run.
//!
//! ## Orchestration
//!
//! 1. `RoundBarrier{round, participants}` to every connection, then the
//!    θ_l broadcast (`ModelSync{client: BROADCAST}`) to each connection
//!    that owns a participant (decoupled), or a per-client
//!    `ModelSync{client: ci}` kickoff processed *sequentially* in
//!    participant order (locked SFLV1/V2 — the training lock is the
//!    baseline's defining property). Under `--zo_wire seed_agg` (wire
//!    v7) the dense broadcast is replaced, from round 1 on, by a
//!    dimension-free `SeedSync` carrying the previous round's accepted
//!    ZO records and FedAvg weights; each client replays them against
//!    its cached θ and lands bit-identically on the server's aggregate.
//!    A connection without the previous round's θ — fresh run, restore,
//!    rejoiner, or one that sat a round out — gets a dense bootstrap
//!    `ModelSync` instead.
//! 2. Decoupled uploads (`Smashed`, or `SmashedSeq` in `--drain stream`
//!    runs) are pushed straight into the round's [`ServerQueue`]; a
//!    capacity drop is answered with a typed NACK
//!    (`UploadAck{accepted: false}`) and lands in `QueueStats::dropped`.
//!    In stream mode the orchestrator then immediately runs
//!    [`Driver::server_pump`] — uploads are consumed **between events,
//!    mid-round, in arrival order** instead of waiting for the barrier
//!    (the dispatcher also validates each upload `seq` is strictly
//!    increasing **per `(conn, lane)`** — keying by connection alone
//!    would let two lanes multiplexed on one socket trip each other's
//!    ordering check — and feeds the frame's `sent_at` into the
//!    event-sim's arrival-driven server-occupancy model). Locked
//!    uploads run [`Driver::locked_server_exchange`] and reply with a
//!    `CutGrad`.
//! 3. Once every participant's `ZoUpdate` + `ModelSync` + `LocalDone`
//!    arrived (or the participant was cut), outcomes are absorbed **in
//!    participant order** — the same barrier-merge the in-process
//!    fan-out performs — then the queue is drained in `(round, client,
//!    step)` order with cut clients' leftovers discarded, and FSL-SAGE
//!    feedback is relayed as `AlignGrad` round-trips. In `--zo_wire
//!    seeds` mode no `ModelSync` comes back up at all: the `ZoUpdate`
//!    carries the per-probe gradient scalars and the dispatcher
//!    *replays* each client's h ZO steps from the broadcast θ
//!    (`zo::replay_trajectory`), after pinning the record shape and the
//!    counter-derived step seeds — bit-identical to the uploaded θ by
//!    construction.
//! 4. `Driver::finish_round` aggregates (Eq. 8) exactly as in-process;
//!    the round closes with a `RoundSummary` carrying the train loss,
//!    the analytic comm bytes, and the measured wire bytes.
//!
//! Because every model-state mutation runs through the same `Driver`
//! methods with inputs in the same order, a networked run is bit-identical
//! to `Driver::run_round` regardless of how clients are multiplexed over
//! sockets (asserted for all five algorithms — per-connection *and*
//! lane-multiplexed — in `rust/tests/net_loopback.rs`).

use crate::coordinator::checkpoint::{self, Checkpoint};
use crate::coordinator::config::RunConfig;
use crate::coordinator::drain::DrainMode;
use crate::coordinator::eventsim::{
    ClientLane, DeviceProfile, RoundSim, WireRoundStats,
};
use crate::coordinator::local::{self, LocalOutcome};
use crate::coordinator::round::Driver;
use crate::coordinator::server_queue::SmashedBatch;
use crate::metrics::{RoundRecord, RunRecord};
use crate::net::codec;
use crate::net::poller::{
    poll_shard_adopt, shard_conns, Event, EventQueue, PollConn, DEFAULT_SHARDS,
};
use crate::net::transport::{Transport, TxHalf, WireCounters};
use crate::net::wire::{Msg, BROADCAST, VERSION};
use crate::runtime::Session;
use crate::util::signal;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Sanity cap on a single connection's declared lane count: a corrupt
/// or hostile `Hello` must not make the dispatcher allocate unbounded
/// per-lane state before the run even starts.
const MAX_LANES_PER_CONN: u32 = 1 << 20;

/// How long the late-join acceptor parks between polls of its
/// non-blocking listener, and how often an armed collect loop wakes to
/// re-check the shutdown flag.
const POLL_TICK: Duration = Duration::from_millis(50);

/// Fault-tolerance knobs for a `serve` run. `Default` turns every one
/// of them off, which pins the pre-v5 behavior bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Write a checkpoint every this many completed rounds (0 = never).
    pub checkpoint_every: usize,
    /// Where checkpoints go (required for any checkpoint to be written).
    pub checkpoint_path: Option<PathBuf>,
    /// Resume from this checkpoint instead of starting at round 0.
    pub restore: Option<PathBuf>,
    /// Fault-injection hook: checkpoint and abort the run with an error
    /// after this many completed rounds (0 = off) — the in-process
    /// chaos harness's stand-in for `kill -9`.
    pub halt_after: usize,
    /// Poll [`signal::requested`] and turn SIGINT/SIGTERM into a final
    /// checkpoint plus a clean `Shutdown` broadcast.
    pub watch_signals: bool,
    /// Keep accepting TCP connections after the run starts so a killed
    /// client can rejoin a dead connection's lane block between rounds.
    pub rejoin: bool,
    /// Log a one-line telemetry-registry snapshot every this many
    /// completed rounds (0 = never). Implies the metrics registry.
    pub stats_every: usize,
}

/// Parked transports from the late-join acceptor, awaiting their
/// between-rounds handshake.
type JoinInbox = Mutex<Vec<Box<dyn Transport>>>;

/// What a completed networked run hands back to the caller.
pub struct NetReport {
    pub record: RunRecord,
    pub final_theta_l: Vec<f32>,
    pub final_theta_s: Vec<f32>,
    /// total measured traffic, server-side view, including handshake and
    /// shutdown frames (per-round deltas live in `RoundTiming::wire`)
    pub wire: WireRoundStats,
    /// typed NACKs sent for queue-capacity drops
    pub nacks_sent: u64,
    /// connections served
    pub connections: usize,
    /// virtual-client lanes served, summed over all connections
    pub lanes: usize,
    /// connections lost mid-run (`Event::PeerDisconnected` or a failed
    /// send), and how many of those died mid-frame
    pub disconnects: u64,
    pub mid_frame_disconnects: u64,
    /// participant slots cut out of rounds (deadline or churn)
    pub clients_cut: u64,
}

/// Accept `n_conns` TCP client connections and run the configured
/// experiment over them.
pub fn serve_tcp(
    session: &Session,
    cfg: RunConfig,
    listener: std::net::TcpListener,
    n_conns: usize,
    record_name: &str,
) -> Result<NetReport> {
    serve_tcp_opts(
        session,
        cfg,
        listener,
        n_conns,
        record_name,
        ServeOptions::default(),
    )
}

/// [`serve_tcp`] with fault-tolerance options. With `opts.rejoin` the
/// listener stays open for the whole run: late connections are parked
/// by an acceptor thread and adopted by the dispatcher between rounds.
pub fn serve_tcp_opts(
    session: &Session,
    cfg: RunConfig,
    listener: std::net::TcpListener,
    n_conns: usize,
    record_name: &str,
    opts: ServeOptions,
) -> Result<NetReport> {
    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n_conns);
    for i in 0..n_conns {
        let (stream, addr) = listener.accept().context("accepting client")?;
        log::info!("connection {i}/{n_conns} from {addr}");
        transports
            .push(Box::new(super::transport::TcpTransport::from_stream(stream)?));
    }
    if !opts.rejoin {
        return serve_transports_inner(
            session, cfg, transports, record_name, &opts, None,
        );
    }
    listener
        .set_nonblocking(true)
        .context("setting listener non-blocking for the rejoin acceptor")?;
    let inbox: Arc<JoinInbox> = Arc::new(Mutex::new(Vec::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let acceptor = {
        let inbox = Arc::clone(&inbox);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, addr)) => {
                        match super::transport::TcpTransport::from_stream(stream)
                        {
                            Ok(t) => {
                                log::info!(
                                    "late connection from {addr} parked for \
                                     rejoin"
                                );
                                inbox
                                    .lock()
                                    .unwrap_or_else(|p| p.into_inner())
                                    .push(Box::new(t) as Box<dyn Transport>);
                            }
                            Err(e) => log::warn!(
                                "late connection from {addr}: {e:#}"
                            ),
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(POLL_TICK);
                    }
                    Err(e) => {
                        log::warn!("rejoin accept failed: {e:#}");
                        std::thread::sleep(POLL_TICK);
                    }
                }
            }
        })
    };
    let out = serve_transports_inner(
        session,
        cfg,
        transports,
        record_name,
        &opts,
        Some(&inbox),
    );
    stop.store(true, Ordering::SeqCst);
    let _ = acceptor.join();
    out
}

/// Which connection — and which virtual lane on it — owns a logical
/// client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LaneAddr {
    conn: usize,
    lane: u32,
}

/// Pop the next *message* event, turning closes/errors into errors —
/// the fail-stop view the locked SFLV1/V2 path keeps (churn tolerance
/// would change what the locked baselines measure).
fn next_msg(events: &EventQueue) -> Result<(usize, Msg)> {
    match events.pop() {
        (conn, Event::Msg(m)) => Ok((conn, m)),
        (conn, Event::Closed) => {
            bail!("connection {conn} closed mid-protocol")
        }
        (conn, Event::PeerDisconnected { detail, .. }) => {
            bail!("connection {conn} dropped mid-protocol: {detail}")
        }
        (conn, Event::Err(e)) => bail!("connection {conn} failed: {e}"),
    }
}

fn sum_counters(counters: &[Arc<WireCounters>]) -> WireRoundStats {
    let mut total = WireRoundStats::default();
    for c in counters {
        let s = c.snapshot();
        total.bytes_sent += s.bytes_sent;
        total.bytes_recv += s.bytes_recv;
        total.frames_sent += s.frames_sent;
        total.frames_recv += s.frames_recv;
    }
    total
}

/// Run the full experiment over already-connected transports (the TCP
/// path lands here after `accept`; loopback tests call it directly).
/// Logical client `i` belongs to global lane `i % total_lanes` — a
/// round-robin over every lane of every connection, which collapses to
/// the classic `i % n_conns` when each connection declares one lane.
pub fn serve_transports(
    session: &Session,
    cfg: RunConfig,
    transports: Vec<Box<dyn Transport>>,
    record_name: &str,
) -> Result<NetReport> {
    serve_transports_inner(
        session,
        cfg,
        transports,
        record_name,
        &ServeOptions::default(),
        None,
    )
}

/// [`serve_transports`] with fault-tolerance options (the in-process
/// chaos harness drives halt/restore/deadline through this).
pub fn serve_transports_opts(
    session: &Session,
    cfg: RunConfig,
    transports: Vec<Box<dyn Transport>>,
    record_name: &str,
    opts: &ServeOptions,
) -> Result<NetReport> {
    serve_transports_inner(session, cfg, transports, record_name, opts, None)
}

fn serve_transports_inner(
    session: &Session,
    cfg: RunConfig,
    mut transports: Vec<Box<dyn Transport>>,
    record_name: &str,
    opts: &ServeOptions,
    joiners: Option<&JoinInbox>,
) -> Result<NetReport> {
    if transports.is_empty() {
        bail!("serve: need at least one client connection");
    }
    cfg.validate()?;
    let n_conns = transports.len();
    let cfg_json = cfg.to_json().to_string();

    // ---- restore: the checkpoint is loaded BEFORE the handshake — the
    // Assign frames carry the restart round and the per-client phase
    // counts the fresh clients fast-forward by.
    let restored: Option<Checkpoint> = match &opts.restore {
        None => None,
        Some(path) => {
            let ck = checkpoint::load(path)?;
            if ck.cfg_json != cfg_json {
                bail!(
                    "checkpoint at {} was taken under a different config \
                     (byte-for-byte mismatch); a restored run must continue \
                     the exact experiment it checkpointed",
                    path.display()
                );
            }
            log::info!(
                "restoring from {} at round {}",
                path.display(),
                ck.state.round_idx
            );
            Some(ck)
        }
    };
    let start_round =
        restored.as_ref().map_or(0, |c| c.state.round_idx as usize);
    let phase_counts: BTreeMap<usize, u64> =
        restored.as_ref().map(|c| c.phases.clone()).unwrap_or_default();
    let prior_rounds: Vec<RoundRecord> =
        restored.as_ref().map(|c| c.rounds.clone()).unwrap_or_default();

    // ---- handshake pass 1: every Hello, for the lane declarations.
    // Lane→client assignment needs the GLOBAL lane count, so no Assign
    // can go out before every connection has said hello.
    let mut lanes_per_conn: Vec<u32> = Vec::with_capacity(n_conns);
    for (j, t) in transports.iter_mut().enumerate() {
        match t.recv()? {
            Some(Msg::Hello { name, protocol, lanes, codecs }) => {
                if protocol != VERSION as u32 {
                    let m = Msg::Shutdown {
                        reason: format!(
                            "protocol {protocol} unsupported (speak {VERSION})"
                        ),
                    };
                    let _ = t.send(&m);
                    bail!("conn {j} ({name}): protocol {protocol} unsupported");
                }
                if lanes == 0 || lanes > MAX_LANES_PER_CONN {
                    let m = Msg::Shutdown {
                        reason: format!("lane count {lanes} out of range"),
                    };
                    let _ = t.send(&m);
                    bail!("conn {j} ({name}): lane count {lanes} out of range");
                }
                // capability negotiation (v6): the run's codec picks must
                // be in this client's advertised set — refusing here
                // beats a mid-round decode failure
                for want in [cfg.codec.id(), cfg.grad_codec.id()] {
                    if !codecs.contains(&want) {
                        let m = Msg::Shutdown {
                            reason: format!(
                                "run requires codec id {want}, client \
                                 supports {codecs:?}"
                            ),
                        };
                        let _ = t.send(&m);
                        bail!(
                            "conn {j} ({name}): does not support codec id \
                             {want} (advertised {codecs:?})"
                        );
                    }
                }
                log::info!(
                    "conn {j}: hello from {name} ({}), {lanes} lane(s)",
                    t.peer()
                );
                lanes_per_conn.push(lanes);
            }
            other => bail!("conn {j}: expected Hello, got {other:?}"),
        }
    }

    // global lane index: conn j's lanes occupy [off_j, off_j + lanes_j)
    let total_lanes: usize = lanes_per_conn.iter().map(|&l| l as usize).sum();
    let mut lane_addr: Vec<LaneAddr> = Vec::with_capacity(total_lanes);
    for (j, &l) in lanes_per_conn.iter().enumerate() {
        for k in 0..l {
            lane_addr.push(LaneAddr { conn: j, lane: k });
        }
    }
    let owner: Vec<LaneAddr> =
        (0..cfg.n_clients).map(|i| lane_addr[i % total_lanes]).collect();

    // ---- handshake pass 2: one Assign per lane, in local lane order ----
    let mut next_global = 0usize;
    for (j, t) in transports.iter_mut().enumerate() {
        for k in 0..lanes_per_conn[j] {
            let ids: Vec<u32> = (0..cfg.n_clients)
                .filter(|&i| i % total_lanes == next_global)
                .map(|i| i as u32)
                .collect();
            let phases = phase_vec(&ids, &phase_counts);
            t.send(&Msg::Assign {
                lane: k,
                client_ids: ids,
                config: cfg_json.clone(),
                rejoin_round: start_round as u32,
                phases,
            })?;
            next_global += 1;
        }
    }

    let mut counters: Vec<Arc<WireCounters>> =
        transports.iter().map(|t| t.counters()).collect();

    // ---- split: write halves stay with the orchestrator, read sides
    // become non-blocking poll sources sharded over a few poll loops ----
    let mut txs: Vec<Box<dyn TxHalf>> = Vec::with_capacity(n_conns);
    let mut pconns: Vec<PollConn> = Vec::with_capacity(n_conns);
    for (j, t) in transports.into_iter().enumerate() {
        let (tx, src) = t.poll_split();
        txs.push(tx);
        pconns.push(PollConn { conn: j, src, counters: counters[j].clone() });
    }
    let events = EventQueue::new();
    // rejoined connections are parked here for a running poll shard to
    // adopt; the flag releases shards parked on an empty inbox at exit
    let shard_inbox: Mutex<Vec<PollConn>> = Mutex::new(Vec::new());
    let shard_stop = AtomicBool::new(false);

    let mut driver = Driver::new(session, cfg)?;
    driver.warmup()?;
    if let Some(ck) = restored {
        driver.import_state(ck.state)?;
    }

    let mut outcome: Option<RoundsOutcome> = None;
    let mut run_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        for (si, shard) in
            shard_conns(pconns, DEFAULT_SHARDS).into_iter().enumerate()
        {
            let events = &events;
            let inbox = joiners.map(|_| &shard_inbox);
            let stop = &shard_stop;
            scope.spawn(move || {
                crate::telemetry::trace::set_thread_label(&format!(
                    "poll-shard-{si}"
                ));
                poll_shard_adopt(shard, events, inbox, stop)
            });
        }

        let mut ctx = RoundsCtx {
            txs: &mut txs,
            events: &events,
            owner: &owner,
            lanes_per_conn: &lanes_per_conn,
            total_lanes,
            counters: &mut counters,
            opts,
            cfg_json: &cfg_json,
            codec_ids: [driver.cfg.codec.id(), driver.cfg.grad_codec.id()],
            joiners,
            shard_inbox: &shard_inbox,
        };
        match run_rounds(
            &mut driver,
            &mut ctx,
            start_round,
            prior_rounds,
            phase_counts,
            record_name,
        ) {
            Ok(o) => outcome = Some(o),
            Err(e) => run_err = Some(e),
        }

        // End of run (or abort): tell every client to go home — this is
        // also what unblocks the poll loops, since clients close their
        // sockets once they see the Shutdown.
        let reason = match (&run_err, &outcome) {
            (Some(e), _) => format!("server error: {e:#}"),
            (None, Some(o)) => o
                .stop_reason
                .clone()
                .unwrap_or_else(|| "run complete".to_string()),
            (None, None) => "run complete".to_string(),
        };
        for tx in txs.iter_mut() {
            let _ = tx.send(&Msg::Shutdown { reason: reason.clone() });
        }
        drop(txs); // loopback: closes the server→client pipes
        shard_stop.store(true, Ordering::SeqCst);
    });
    if let Some(e) = run_err {
        return Err(e);
    }
    let o = outcome.expect("run produced no report");

    Ok(NetReport {
        record: o.rec,
        final_theta_l: driver.theta_l.clone(),
        final_theta_s: driver.theta_s.clone(),
        wire: sum_counters(&counters),
        nacks_sent: o.nacks_sent,
        connections: n_conns,
        lanes: total_lanes,
        disconnects: o.churn.disconnects,
        mid_frame_disconnects: o.churn.mid_frame,
        clients_cut: o.churn.clients_cut,
    })
}

/// The `Assign.phases` vector for a lane's client list: completed local
/// phases per client, for the loader fast-forward after restore/rejoin.
fn phase_vec(ids: &[u32], phase_counts: &BTreeMap<usize, u64>) -> Vec<u32> {
    ids.iter()
        .map(|&i| {
            phase_counts
                .get(&(i as usize))
                .copied()
                .unwrap_or(0)
                .min(u32::MAX as u64) as u32
        })
        .collect()
}

/// Per-participant collection state for one decoupled round.
#[derive(Default)]
struct Collected {
    losses: Option<Vec<f64>>,
    seeds: Vec<i32>,
    /// flattened h × n_p per-probe gradient scalars (seeds wire mode)
    gscales: Vec<f32>,
    theta: Option<Vec<f32>>,
    done: Option<(u64, u64, f64, f64)>, // comm, flops, lane_time, lane_idle
}

/// Churn accounting for one run, surfaced as summary keys
/// (`net_disconnects`, `net_mid_frame`, `clients_cut`) and
/// [`NetReport`] fields.
#[derive(Default)]
struct Churn {
    disconnects: u64,
    mid_frame: u64,
    clients_cut: u64,
}

/// Everything `run_rounds` borrows from the serve setup.
struct RoundsCtx<'a> {
    txs: &'a mut Vec<Box<dyn TxHalf>>,
    events: &'a EventQueue,
    owner: &'a [LaneAddr],
    lanes_per_conn: &'a [u32],
    total_lanes: usize,
    counters: &'a mut Vec<Arc<WireCounters>>,
    opts: &'a ServeOptions,
    cfg_json: &'a str,
    /// the run's negotiated codec ids `[codec, grad_codec]` — what a
    /// rejoining client's `Hello.codecs` must advertise
    codec_ids: [u8; 2],
    joiners: Option<&'a JoinInbox>,
    shard_inbox: &'a Mutex<Vec<PollConn>>,
}

struct RoundsOutcome {
    rec: RunRecord,
    nacks_sent: u64,
    churn: Churn,
    /// set when the run ended early but cleanly (signal shutdown);
    /// becomes the `Shutdown` reason instead of "run complete"
    stop_reason: Option<String>,
}

/// Validate one client's lean ZO replay record: the shape must match the
/// run config, and every step seed must equal the counter derivation the
/// client was required to use (a client cannot steer a replay off the
/// deterministic trajectory). Shared by the `seeds`-mode per-client
/// replay and the `seed_agg` ingest, which defers the replay to the
/// streaming aggregation in `finish_round`.
fn check_zo_record(
    cfg: &RunConfig,
    round: usize,
    ci: usize,
    c: &Collected,
) -> Result<()> {
    let h = cfg.local_steps;
    let np = cfg.n_pert.max(1);
    if c.seeds.len() != h {
        bail!(
            "client {ci}: lean-wire record has {} seeds, expected {h}",
            c.seeds.len()
        );
    }
    if c.gscales.len() != h * np {
        bail!(
            "client {ci}: lean-wire record has {} gscales, expected {}",
            c.gscales.len(),
            h * np
        );
    }
    for (s, &seed) in c.seeds.iter().enumerate() {
        let want = local::step_seed(cfg, round, ci, s + 1);
        if seed != want {
            bail!(
                "client {ci}: step {} seed {seed} != derived {want}",
                s + 1
            );
        }
    }
    Ok(())
}

/// Reconstruct one client's end-of-phase θ from its lean wire record
/// (`--zo_wire seeds`): validate the record ([`check_zo_record`]), then
/// replay h ZO updates from the round's broadcast θ. Bit-identical to
/// the θ the client would have uploaded in `theta` mode.
fn replay_theta(
    cfg: &RunConfig,
    round: usize,
    ci: usize,
    theta0: &[f32],
    c: &Collected,
) -> Result<Vec<f32>> {
    check_zo_record(cfg, round, ci, c)?;
    crate::zo::replay_trajectory(
        theta0,
        &c.seeds,
        cfg.n_pert.max(1),
        &c.gscales,
    )
    .context("replaying seeds-mode update")
}

/// An older round stamp is late traffic from a straggler that was cut
/// at a deadline and is only now finishing its phase — tolerated so one
/// slow client cannot wedge the protocol. A *future* round is always a
/// violation. Returns whether the message is late (caller drops it).
fn late_round(got: u32, now: u32, what: &str) -> Result<bool> {
    if got > now {
        bail!("{what}: round {got} is ahead of the open round {now}");
    }
    Ok(got < now)
}

/// NACK an upload that arrived past its round's deadline — the uploader
/// blocks on its ack, so dropping it silently would wedge the client.
fn late_nack(
    tx: &mut Box<dyn TxHalf>,
    ci: usize,
    round: u32,
    step: u32,
) -> Result<()> {
    tx.send(&Msg::UploadAck {
        client: ci as u32,
        round,
        step,
        accepted: false,
        reason: "arrived after the round deadline".into(),
    })
}

/// Mark `conn` dead and cut every participant it owns out of the open
/// round. Participants that already finished are dropped too: their
/// alignment round-trip and summary can no longer reach the peer, so
/// their θ must not enter this round's aggregate. Idempotent.
#[allow(clippy::too_many_arguments)]
fn cut_conn(
    conn: usize,
    why: &str,
    mid_frame: bool,
    round: usize,
    participants: &[usize],
    owner: &[LaneAddr],
    dead: &mut [bool],
    done: &mut BTreeSet<usize>,
    cut: &mut BTreeSet<usize>,
    sim: &mut RoundSim,
    churn: &mut Churn,
) {
    if dead[conn] {
        return;
    }
    dead[conn] = true;
    churn.disconnects += 1;
    if mid_frame {
        churn.mid_frame += 1;
    }
    log::warn!("conn {conn} lost in round {round} ({why}); cutting its clients");
    for &ci in participants {
        if owner[ci].conn != conn {
            continue;
        }
        done.remove(&ci);
        if cut.insert(ci) {
            sim.record_cutoff(ci);
            churn.clients_cut += 1;
        }
    }
}

/// Write a checkpoint of the driver's current (round-boundary) state.
/// A no-op without a configured checkpoint path.
fn write_checkpoint(
    driver: &Driver,
    opts: &ServeOptions,
    cfg_json: &str,
    rec: &RunRecord,
    phase_counts: &BTreeMap<usize, u64>,
) -> Result<()> {
    let Some(path) = &opts.checkpoint_path else {
        return Ok(());
    };
    let ck = Checkpoint {
        cfg_json: cfg_json.to_string(),
        state: driver.export_state(),
        rounds: rec.rounds.clone(),
        phases: phase_counts.clone(),
    };
    checkpoint::save(&ck, path)?;
    log::info!(
        "checkpoint at round {} -> {}",
        driver.round_index(),
        path.display()
    );
    Ok(())
}

/// Between rounds, hand every transport the late-join acceptor parked
/// its handshake and a dead connection's lane block. A rejoiner must
/// declare the same lane count as the slot it takes over; its `Assign`
/// carries the next round index (`rejoin_round`) and per-client phase
/// counts so it never replays a stale round. The connection index is
/// reused — the poller emitted the old peer's disconnect as its *last*
/// event, so no stale event can be misattributed to the adoptee.
#[allow(clippy::too_many_arguments)]
fn adopt_joiners(
    ctx: &mut RoundsCtx,
    dead: &mut [bool],
    synced_round: &mut [Option<usize>],
    round: usize,
    phase_counts: &BTreeMap<usize, u64>,
) -> Result<()> {
    let Some(inbox) = ctx.joiners else {
        return Ok(());
    };
    let pending: Vec<Box<dyn Transport>> = {
        let mut g = inbox.lock().unwrap_or_else(|p| p.into_inner());
        std::mem::take(&mut *g)
    };
    'next: for mut t in pending {
        let (name, protocol, lanes, codecs) = match t.recv() {
            Ok(Some(Msg::Hello { name, protocol, lanes, codecs })) => {
                (name, protocol, lanes, codecs)
            }
            Ok(other) => {
                log::warn!("rejoin: expected Hello, got {other:?}; dropping");
                continue;
            }
            Err(e) => {
                log::warn!("rejoin handshake failed: {e:#}");
                continue;
            }
        };
        if protocol != VERSION as u32 {
            let _ = t.send(&Msg::Shutdown {
                reason: format!(
                    "protocol {protocol} unsupported (speak {VERSION})"
                ),
            });
            continue;
        }
        if let Some(&want) =
            ctx.codec_ids.iter().find(|id| !codecs.contains(id))
        {
            let _ = t.send(&Msg::Shutdown {
                reason: format!(
                    "run requires codec id {want}, client supports {codecs:?}"
                ),
            });
            log::warn!(
                "rejoin from {name}: missing codec id {want} \
                 (advertised {codecs:?})"
            );
            continue;
        }
        let Some(j) = (0..dead.len())
            .find(|&j| dead[j] && ctx.lanes_per_conn[j] == lanes)
        else {
            let _ = t.send(&Msg::Shutdown {
                reason: format!("no dead {lanes}-lane slot to rejoin"),
            });
            log::warn!("rejoin from {name}: no dead {lanes}-lane slot");
            continue;
        };
        let off: usize =
            ctx.lanes_per_conn[..j].iter().map(|&l| l as usize).sum();
        for k in 0..lanes {
            let g = off + k as usize;
            let ids: Vec<u32> = (0..ctx.owner.len())
                .filter(|&i| i % ctx.total_lanes == g)
                .map(|i| i as u32)
                .collect();
            let phases = phase_vec(&ids, phase_counts);
            if let Err(e) = t.send(&Msg::Assign {
                lane: k,
                client_ids: ids,
                config: ctx.cfg_json.to_string(),
                rejoin_round: round as u32,
                phases,
            }) {
                log::warn!("rejoin assign to {name} failed: {e:#}");
                continue 'next;
            }
        }
        let c = t.counters();
        let (tx, src) = t.poll_split();
        ctx.txs[j] = tx;
        // the dead peer's counter Arc stays in the vec, frozen — so the
        // cumulative wire sums (and per-round `since` deltas) stay
        // monotone across the swap
        ctx.counters.push(c.clone());
        dead[j] = false;
        // the adoptee holds no broadcast θ: its first sync must be the
        // dense bootstrap, never a seed-space delta off a stale model
        synced_round[j] = None;
        ctx.shard_inbox
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(PollConn { conn: j, src, counters: c });
        log::info!(
            "conn {j}: {name} rejoined at round {round} ({lanes} lane(s))"
        );
    }
    Ok(())
}

fn run_rounds(
    driver: &mut Driver,
    ctx: &mut RoundsCtx,
    start_round: usize,
    prior_rounds: Vec<RoundRecord>,
    mut phase_counts: BTreeMap<usize, u64>,
    record_name: &str,
) -> Result<RoundsOutcome> {
    let n_conns = ctx.txs.len();
    let mut rec = RunRecord::new(record_name);
    rec.rounds = prior_rounds;
    let t0 = Instant::now();
    let mut nacks_sent = 0u64;
    let profile = DeviceProfile::edge_default();
    let mut dead = vec![false; n_conns];
    // seed_agg bootstrap tracking: the round whose broadcast θ a
    // connection last received (dense or seed-reconstructed). A conn is
    // eligible for the lean `SeedSync` delta only if it holds the
    // *previous* round's θ — anything else (fresh run, restore,
    // rejoiner, a round it sat out) gets one dense `ModelSync` instead.
    let mut synced_round: Vec<Option<usize>> = vec![None; n_conns];
    let mut churn = Churn::default();
    let mut stop_reason: Option<String> = None;

    let stream = driver.cfg.drain == DrainMode::Stream;
    let wall_deadline = driver.cfg.wall_deadline();

    crate::telemetry::trace::set_thread_label("orchestrator");
    'rounds: for round in start_round..driver.cfg.rounds {
        let _round_span = crate::span!("round", round = round);
        // graceful shutdown between rounds: the driver sits exactly at a
        // round boundary, so this state is the restorable one
        if ctx.opts.watch_signals && signal::requested() {
            write_checkpoint(driver, ctx.opts, ctx.cfg_json, &rec, &phase_counts)?;
            log::info!("signal: final checkpoint written, shutting down");
            stop_reason = Some(format!(
                "server shutting down on signal before round {round} \
                 (checkpointed)"
            ));
            rec.set("interrupted", 1.0);
            break 'rounds;
        }
        adopt_joiners(ctx, &mut dead, &mut synced_round, round, &phase_counts)?;

        let wire_before = sum_counters(ctx.counters);
        let participants = driver.sample_participants();
        let parts_u32: Vec<u32> =
            participants.iter().map(|&c| c as u32).collect();
        let mut sim = driver.new_sim(&participants);
        let queue = driver.round_queue(participants.len());
        let mut losses: Vec<f64> = Vec::new();
        let mut updated: Vec<(usize, Vec<f32>)> = Vec::new();
        // feedback consumed mid-round by the stream drain policy; the
        // barrier leftovers from `server_drain` are appended below
        let mut feedback: Vec<(usize, Vec<f32>)> = Vec::new();
        // next expected upload seq for this round, per (conn, lane) —
        // two lanes multiplexed on one socket interleave their uploads
        // arbitrarily, so keying by connection alone would reject valid
        // traffic while accepting cross-lane reordering
        let mut next_seq: BTreeMap<(usize, u32), u32> = BTreeMap::new();
        let r32 = round as u32;
        // participants cut from this round (deadline or churn): their
        // queued uploads are discarded at the barrier, their θ never
        // enters FedAvg, and their late traffic is tolerated
        let mut cut: BTreeSet<usize> = BTreeSet::new();

        // broadcasts are built once and serialized per connection —
        // never clone model-sized payloads per receiver
        let barrier_msg =
            Msg::RoundBarrier { round: r32, participants: parts_u32.clone() };
        let mut send_failed: Vec<usize> = Vec::new();
        for (j, tx) in ctx.txs.iter_mut().enumerate() {
            if dead[j] {
                continue;
            }
            if let Err(e) = tx.send(&barrier_msg) {
                log::warn!("conn {j}: barrier send failed: {e:#}");
                send_failed.push(j);
            }
        }
        if !driver.cfg.algorithm.is_decoupled() && !send_failed.is_empty() {
            bail!(
                "connection {} lost at the round {round} barrier (locked \
                 baselines run fail-stop)",
                send_failed[0]
            );
        }

        if driver.cfg.algorithm.is_decoupled() {
            // clients of dead (or just-lost) connections can never
            // answer this round — cut them up front
            for j in 0..n_conns {
                if send_failed.contains(&j) && !dead[j] {
                    dead[j] = true;
                    churn.disconnects += 1;
                }
                if !dead[j] {
                    continue;
                }
                for &ci in &participants {
                    if ctx.owner[ci].conn == j && cut.insert(ci) {
                        sim.record_cutoff(ci);
                        churn.clients_cut += 1;
                    }
                }
            }

            // The real parallelism width is the client-process count.
            sim.set_workers(n_conns.min(participants.len()).max(1));
            let lean = driver.cfg.zo_wire.lean_uplink();
            let seed_agg = driver.cfg.zo_wire.lean_downlink();
            // seeds mode: keep the broadcast θ — it is the replay origin
            // (seed_agg never replays per-client server-side, so it
            // skips the copy)
            let theta0: Vec<f32> = if lean && !seed_agg {
                driver.theta_l.clone()
            } else {
                Vec::new()
            };
            let active: Vec<usize> = (0..n_conns)
                .filter(|&j| {
                    !dead[j]
                        && participants.iter().any(|&c| ctx.owner[c].conn == j)
                })
                .collect();
            // seed_agg (wire v7): the previous round's accepted records
            // + FedAvg weights replace the dense θ broadcast; each
            // client replays them against its cached θ and lands on the
            // exact aggregate `finish_round` computed. `None` (fresh
            // start, restore, or a fully-cut previous round) falls back
            // to the dense bootstrap below.
            let seed_msg = if seed_agg {
                driver.seed_sync_record().map(
                    |(clients, weights, seeds, gscales)| Msg::SeedSync {
                        round: r32,
                        clients,
                        weights,
                        seeds,
                        gscales,
                    },
                )
            } else {
                None
            };
            let fo = crate::net::wire::FRAME_OVERHEAD as u64;
            let dense_frame_bytes =
                fo + 16 + 4 * driver.theta_l.len() as u64;
            let seed_frame_bytes = seed_msg.as_ref().map(|m| match m {
                Msg::SeedSync { clients, seeds, gscales, .. } => {
                    fo + 20
                        + 12 * clients.len() as u64
                        + 4 * seeds.len() as u64
                        + 4 * gscales.len() as u64
                }
                _ => unreachable!("seed_msg is always SeedSync"),
            });
            let _sync_span = seed_msg.as_ref().map(|_| {
                crate::span!("seed_sync_broadcast", round = round)
            });
            let dense_msg = Msg::ModelSync {
                lane: BROADCAST,
                round: r32,
                client: BROADCAST,
                theta: driver.theta_l.clone(),
            };
            for &j in &active {
                // a conn holding the previous round's θ can take the
                // seed-space delta; anyone else needs the dense model
                let take_seed = seed_msg.is_some()
                    && synced_round[j].map_or(false, |r| r + 1 == round);
                let msg = if take_seed {
                    seed_msg.as_ref().expect("checked above")
                } else {
                    &dense_msg
                };
                if crate::telemetry::metrics_enabled() {
                    use crate::telemetry::registry::counter;
                    let b = if take_seed {
                        seed_frame_bytes.expect("take_seed implies seed_msg")
                    } else {
                        dense_frame_bytes
                    };
                    counter("net.downlink.bytes").add(b);
                    if take_seed {
                        counter("net.downlink.bytes_saved")
                            .add(dense_frame_bytes.saturating_sub(b));
                    }
                }
                match ctx.txs[j].send(msg) {
                    Ok(()) => synced_round[j] = Some(round),
                    Err(e) => {
                        synced_round[j] = None;
                        cut_conn(
                            j,
                            &format!("model sync send failed: {e:#}"),
                            false,
                            round,
                            &participants,
                            ctx.owner,
                            &mut dead,
                            &mut BTreeSet::new(),
                            &mut cut,
                            &mut sim,
                            &mut churn,
                        );
                    }
                }
            }
            // the broadcast above consumed the previous round's roster;
            // from here the buffer accumulates this round's records
            driver.begin_round_records();

            // ---- collect the fan-out: acks flow back per upload ----
            // The straggler cutoff clock starts at the barrier; with no
            // deadline and no signal watching the loop uses the plain
            // blocking pop — behavior bit-identical to a deadline-free
            // build.
            let deadline_at = wall_deadline.map(|d| Instant::now() + d);
            let needs_poll =
                deadline_at.is_some() || ctx.opts.watch_signals;
            let mut got: BTreeMap<usize, Collected> = BTreeMap::new();
            let mut done: BTreeSet<usize> = BTreeSet::new();
            while done.len() + cut.len() < participants.len() {
                if ctx.opts.watch_signals && signal::requested() {
                    // abandon the open round; the newest on-disk
                    // checkpoint (a round boundary) is the restore point
                    log::info!("signal: abandoning open round {round}");
                    stop_reason = Some(format!(
                        "server shutting down on signal during round {round} \
                         (restore from the last checkpoint)"
                    ));
                    rec.set("interrupted", 1.0);
                    break 'rounds;
                }
                let ev = if needs_poll {
                    let wait = deadline_at
                        .map(|t| t.saturating_duration_since(Instant::now()))
                        .unwrap_or(POLL_TICK)
                        .min(POLL_TICK)
                        .max(Duration::from_millis(1));
                    match ctx.events.pop_timeout(wait) {
                        Some(ev) => ev,
                        None => {
                            if let Some(t) = deadline_at {
                                if Instant::now() >= t {
                                    // straggler cutoff: finalize with
                                    // the uploads we have
                                    for &ci in &participants {
                                        if !done.contains(&ci)
                                            && cut.insert(ci)
                                        {
                                            sim.record_cutoff(ci);
                                            churn.clients_cut += 1;
                                            log::warn!(
                                                "round {round}: client {ci} \
                                                 cut at the deadline"
                                            );
                                        }
                                    }
                                }
                            }
                            continue;
                        }
                    }
                } else {
                    ctx.events.pop()
                };
                let (conn, msg) = match ev {
                    (conn, Event::Msg(m)) => (conn, m),
                    (conn, Event::Closed) => {
                        cut_conn(
                            conn,
                            "closed",
                            false,
                            round,
                            &participants,
                            ctx.owner,
                            &mut dead,
                            &mut done,
                            &mut cut,
                            &mut sim,
                            &mut churn,
                        );
                        continue;
                    }
                    (
                        conn,
                        Event::PeerDisconnected { mid_frame, detail, .. },
                    ) => {
                        cut_conn(
                            conn,
                            &detail,
                            mid_frame,
                            round,
                            &participants,
                            ctx.owner,
                            &mut dead,
                            &mut done,
                            &mut cut,
                            &mut sim,
                            &mut churn,
                        );
                        continue;
                    }
                    (conn, Event::Err(e)) => {
                        bail!("connection {conn} failed: {e}")
                    }
                };
                match msg {
                    Msg::Smashed {
                        lane,
                        client,
                        round: r,
                        step,
                        smashed,
                        targets,
                    } => {
                        if stream {
                            bail!(
                                "conn {conn}: plain Smashed in a --drain \
                                 stream run (expected SmashedSeq)"
                            );
                        }
                        let ci =
                            check_owned(ctx.owner, conn, lane, client, "Smashed")?;
                        if late_round(r, r32, "Smashed")? || cut.contains(&ci) {
                            if late_nack(&mut ctx.txs[conn], ci, r, step)
                                .is_err()
                            {
                                cut_conn(
                                    conn,
                                    "late-ack send failed",
                                    false,
                                    round,
                                    &participants,
                                    ctx.owner,
                                    &mut dead,
                                    &mut done,
                                    &mut cut,
                                    &mut sim,
                                    &mut churn,
                                );
                            }
                            continue;
                        }
                        // decode the codec envelope before anything
                        // consumes it — a malformed payload is a protocol
                        // violation, same as a bad frame
                        let smashed = codec::decode_expect(
                            &smashed,
                            driver.cfg.codec.id(),
                        )
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "conn {conn}: client {ci} smashed payload: {e}"
                            )
                        })?;
                        if let Err(e) = push_and_ack(
                            &queue,
                            &mut ctx.txs[conn],
                            &mut nacks_sent,
                            (ci, r32, step),
                            smashed,
                            targets,
                        ) {
                            cut_conn(
                                conn,
                                &format!("ack send failed: {e:#}"),
                                false,
                                round,
                                &participants,
                                ctx.owner,
                                &mut dead,
                                &mut done,
                                &mut cut,
                                &mut sim,
                                &mut churn,
                            );
                        }
                    }
                    Msg::SmashedSeq {
                        lane,
                        client,
                        round: r,
                        step,
                        seq,
                        sent_at,
                        smashed,
                        targets,
                    } => {
                        if !stream {
                            bail!(
                                "conn {conn}: SmashedSeq outside a --drain \
                                 stream run"
                            );
                        }
                        let ci = check_owned(
                            ctx.owner, conn, lane, client, "SmashedSeq",
                        )?;
                        let late = late_round(r, r32, "SmashedSeq")?;
                        if !late {
                            // current-round frames consume the lane's seq
                            // slot whether or not the client was cut —
                            // the stream interleaves cut and live clients
                            // multiplexed on one lane, so skipping a cut
                            // client's slot would trip the next live
                            // client's ordering check
                            let next =
                                next_seq.entry((conn, lane)).or_insert(1);
                            if seq != *next {
                                bail!(
                                    "conn {conn} lane {lane}: upload seq \
                                     {seq} for client {ci}, expected {next} \
                                     (reordered, duplicated or dropped frame)"
                                );
                            }
                            *next += 1;
                            // the sent_at timestamp feeds arithmetic (sort,
                            // schedule folds) — reject non-finite garbage at
                            // the ingress, like every other field check
                            if !sent_at.is_finite() || sent_at < 0.0 {
                                bail!(
                                    "conn {conn}: client {ci} upload sent_at \
                                     {sent_at} is not a finite non-negative \
                                     time"
                                );
                            }
                        }
                        if late || cut.contains(&ci) {
                            if late_nack(&mut ctx.txs[conn], ci, r, step)
                                .is_err()
                            {
                                cut_conn(
                                    conn,
                                    "late-ack send failed",
                                    false,
                                    round,
                                    &participants,
                                    ctx.owner,
                                    &mut dead,
                                    &mut done,
                                    &mut cut,
                                    &mut sim,
                                    &mut churn,
                                );
                            }
                            continue;
                        }
                        let smashed = codec::decode_expect(
                            &smashed,
                            driver.cfg.codec.id(),
                        )
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "conn {conn}: client {ci} smashed payload: {e}"
                            )
                        })?;
                        let accepted = match push_and_ack(
                            &queue,
                            &mut ctx.txs[conn],
                            &mut nacks_sent,
                            (ci, r32, step),
                            smashed,
                            targets,
                        ) {
                            Ok(a) => a,
                            Err(e) => {
                                cut_conn(
                                    conn,
                                    &format!("ack send failed: {e:#}"),
                                    false,
                                    round,
                                    &participants,
                                    ctx.owner,
                                    &mut dead,
                                    &mut done,
                                    &mut cut,
                                    &mut sim,
                                    &mut churn,
                                );
                                continue;
                            }
                        };
                        // arrival-driven server occupancy: only accepted
                        // uploads become server work — a dropped batch is
                        // never serviced, so it must not enter the
                        // schedule
                        if accepted {
                            sim.upload_arrival(sent_at);
                        }
                        // pipelined mid-round consumption: drain in
                        // arrival order between events instead of
                        // holding everything to the round barrier
                        driver.server_pump(&queue, &mut sim, &mut feedback)?;
                    }
                    Msg::ZoUpdate {
                        lane,
                        client,
                        round: r,
                        seeds,
                        scalars,
                        gscales,
                    } => {
                        let ci =
                            check_owned(ctx.owner, conn, lane, client, "ZoUpdate")?;
                        if late_round(r, r32, "ZoUpdate")? || cut.contains(&ci)
                        {
                            continue;
                        }
                        let e = got.entry(ci).or_default();
                        e.losses =
                            Some(scalars.iter().map(|&l| l as f64).collect());
                        e.seeds = seeds;
                        e.gscales = gscales;
                    }
                    Msg::ModelSync { lane, client, round: r, theta } => {
                        let ci =
                            check_owned(ctx.owner, conn, lane, client, "ModelSync")?;
                        if late_round(r, r32, "ModelSync")?
                            || cut.contains(&ci)
                        {
                            continue;
                        }
                        got.entry(ci).or_default().theta = Some(theta);
                    }
                    Msg::LocalDone {
                        lane,
                        client,
                        round: r,
                        comm_bytes,
                        flops,
                        lane_time,
                        lane_idle,
                    } => {
                        let ci =
                            check_owned(ctx.owner, conn, lane, client, "LocalDone")?;
                        if late_round(r, r32, "LocalDone")?
                            || cut.contains(&ci)
                        {
                            continue;
                        }
                        let e = got.entry(ci).or_default();
                        if e.done.is_some() {
                            bail!("conn {conn}: duplicate LocalDone for {ci}");
                        }
                        e.done =
                            Some((comm_bytes, flops, lane_time, lane_idle));
                        done.insert(ci);
                    }
                    other => bail!(
                        "conn {conn}: unexpected {} during fan-out",
                        other.name()
                    ),
                }
            }

            // ---- barrier merge, in participant order (as in-process);
            // cut participants contribute nothing ----
            for &ci in &participants {
                if cut.contains(&ci) {
                    continue;
                }
                let mut c = got.remove(&ci).with_context(|| {
                    format!("client {ci} sent LocalDone data out of band")
                })?;
                let (comm_bytes, flops, lane_time, lane_idle) = c
                    .done
                    .with_context(|| format!("client {ci}: missing LocalDone"))?;
                let mut lane = ClientLane::new(&profile);
                lane.time = lane_time;
                lane.idle = lane_idle;
                // theta mode: the client uploaded θ. seeds mode: no θ
                // ever crossed the wire — replay it from the record.
                // seed_agg: validate the record now and hand it through
                // empty-θ; `finish_round` replays all records inside
                // one streaming FedAvg, so no per-client θ is ever
                // materialized server-side.
                let theta = match (c.theta.take(), lean) {
                    (Some(_), true) => bail!(
                        "client {ci}: unexpected θ upload in lean wire mode"
                    ),
                    (Some(t), false) => t,
                    (None, true) if seed_agg => {
                        check_zo_record(&driver.cfg, round, ci, &c)?;
                        Vec::new()
                    }
                    (None, true) => {
                        replay_theta(&driver.cfg, round, ci, &theta0, &c)?
                    }
                    (None, false) => {
                        bail!("client {ci}: missing θ")
                    }
                };
                let outcome = LocalOutcome {
                    ci,
                    theta,
                    losses: c
                        .losses
                        .with_context(|| format!("client {ci}: missing losses"))?,
                    seeds: c.seeds,
                    gscales: c.gscales,
                    comm_bytes,
                    flops,
                    lane,
                };
                driver.absorb_outcome(outcome, &mut sim, &mut losses, &mut updated);
            }
        } else {
            // ---- locked SFLV1/V2: strictly sequential per participant ----
            sim.set_workers(1);
            for &ci in &participants {
                if ctx.opts.watch_signals && signal::requested() {
                    log::info!("signal: abandoning open round {round}");
                    stop_reason = Some(format!(
                        "server shutting down on signal during round {round} \
                         (restore from the last checkpoint)"
                    ));
                    rec.set("interrupted", 1.0);
                    break 'rounds;
                }
                let addr = ctx.owner[ci];
                ctx.txs[addr.conn].send(&Msg::ModelSync {
                    lane: addr.lane,
                    round: r32,
                    client: ci as u32,
                    theta: driver.theta_l.clone(),
                })?;
                let theta_end = loop {
                    let (conn, msg) = next_msg(ctx.events)?;
                    if conn != addr.conn {
                        bail!(
                            "conn {conn}: traffic during client {ci}'s locked phase"
                        );
                    }
                    match msg {
                        Msg::Smashed {
                            lane,
                            client,
                            round: r,
                            step,
                            smashed,
                            targets,
                        } => {
                            check_round(r, r32, "Smashed")?;
                            check_owned(ctx.owner, conn, lane, client, "Smashed")?;
                            check_client(client, ci, "Smashed")?;
                            // the client encoded once; this decode is the
                            // server's only view of the activations
                            let smashed = codec::decode_expect(
                                &smashed,
                                driver.cfg.codec.id(),
                            )
                            .map_err(|e| {
                                anyhow::anyhow!(
                                    "conn {conn}: client {ci} smashed \
                                     payload: {e}"
                                )
                            })?;
                            let (loss, g) = driver.locked_server_exchange(
                                ci, smashed, targets, &mut sim,
                            )?;
                            losses.push(loss);
                            // the gradient codec's single encode happens
                            // here; the client decodes this envelope
                            let g = codec::encode_grad(
                                driver.cfg.grad_codec,
                                &g,
                            );
                            ctx.txs[conn].send(&Msg::CutGrad {
                                client,
                                round: r,
                                step,
                                loss: loss as f32,
                                g,
                            })?;
                        }
                        Msg::ModelSync { lane, client, round: r, theta } => {
                            check_round(r, r32, "ModelSync")?;
                            check_owned(ctx.owner, conn, lane, client, "ModelSync")?;
                            check_client(client, ci, "ModelSync")?;
                            break theta;
                        }
                        other => bail!(
                            "conn {conn}: unexpected {} during locked phase",
                            other.name()
                        ),
                    }
                };
                driver.comm_bytes +=
                    driver.book.comm_per_round_sync_at(round as u64);
                sim.sync_split(
                    driver.book.downlink_per_round_sync(round as u64),
                    driver.book.uplink_per_round_sync(),
                );
                updated.push((ci, theta_end));
            }
        }

        // ---- server phase: barrier drain (everything, Eq. 7 order) or
        // stream-mode stragglers (arrival order); cut clients' queued
        // batches are discarded, and their mid-round feedback (stream)
        // is dropped — exactly the in-process cutoff semantics ----
        feedback.extend(driver.server_drain_cut(&queue, &cut, &mut sim)?);
        if !cut.is_empty() {
            feedback.retain(|(c, _)| !cut.contains(c));
        }
        for (ci, g) in feedback {
            driver.note_alignment_accounting(ci, &mut sim);
            let Some(pos) = updated.iter().position(|(c, _)| *c == ci) else {
                continue;
            };
            let addr = ctx.owner[ci];
            if dead[addr.conn] {
                continue;
            }
            if let Err(e) = ctx.txs[addr.conn].send(&Msg::AlignGrad {
                client: ci as u32,
                round: r32,
                g,
            }) {
                log::warn!(
                    "conn {}: align send failed: {e:#}; alignment for \
                     client {ci} lost",
                    addr.conn
                );
                dead[addr.conn] = true;
                churn.disconnects += 1;
                continue;
            }
            loop {
                let (conn, ev) = ctx.events.pop();
                let msg = match ev {
                    Event::Msg(m) => m,
                    Event::Closed
                    | Event::PeerDisconnected { .. } => {
                        if !dead[conn] {
                            dead[conn] = true;
                            churn.disconnects += 1;
                        }
                        if conn == addr.conn {
                            // peer died mid-alignment: its merged θ
                            // stands un-aligned
                            log::warn!(
                                "conn {conn} lost during client {ci}'s \
                                 alignment"
                            );
                            break;
                        }
                        continue;
                    }
                    Event::Err(e) => bail!("connection {conn} failed: {e}"),
                };
                match msg {
                    Msg::ModelSync { lane, client, round: r, theta }
                        if conn == addr.conn && client as usize == ci =>
                    {
                        if late_round(r, r32, "align ModelSync")? {
                            continue;
                        }
                        if lane != addr.lane {
                            bail!(
                                "conn {conn}: align ModelSync for client {ci} \
                                 on lane {lane}, owned by lane {}",
                                addr.lane
                            );
                        }
                        updated[pos].1 = theta;
                        break;
                    }
                    // every live participant is done once alignment
                    // starts, so an upload arriving now — even one
                    // stamped with the open round — is a cut straggler's
                    // traffic: NACK it (the uploader blocks on its ack),
                    // drop the rest
                    Msg::Smashed { client, round: r, step, .. }
                    | Msg::SmashedSeq { client, round: r, step, .. }
                        if r <= r32 =>
                    {
                        let cc = client as usize;
                        if !dead[conn]
                            && late_nack(&mut ctx.txs[conn], cc, r, step)
                                .is_err()
                        {
                            dead[conn] = true;
                            churn.disconnects += 1;
                        }
                    }
                    Msg::ZoUpdate { round: r, .. }
                    | Msg::ModelSync { round: r, .. }
                    | Msg::LocalDone { round: r, .. }
                        if r <= r32 => {}
                    other => bail!(
                        "conn {conn}: unexpected {} during alignment",
                        other.name()
                    ),
                }
            }
        }

        // ---- close the round: summary out, then aggregate ----
        let loss_preview =
            losses.iter().sum::<f64>() / losses.len().max(1) as f64;
        let cum = sum_counters(ctx.counters);
        let summary_msg = Msg::RoundSummary {
            round: r32,
            train_loss: loss_preview,
            comm_bytes: driver.comm_bytes,
            wire_bytes: cum.bytes_sent + cum.bytes_recv,
        };
        for (j, tx) in ctx.txs.iter_mut().enumerate() {
            if dead[j] {
                continue;
            }
            if let Err(e) = tx.send(&summary_msg) {
                log::warn!("conn {j}: summary send failed: {e:#}");
                dead[j] = true;
                churn.disconnects += 1;
            }
        }
        sim.record_wire(sum_counters(ctx.counters).since(&wire_before));
        let loss = driver.finish_round(&participants, updated, sim, &losses);
        driver.record_round(&mut rec, round, loss, t0)?;
        // phase accounting: every sampled participant was told to run a
        // local phase, so every one advanced its data stream by
        // `local_steps` batches — cut or not (the cut happens server
        // side; the client still consumes its batches). This is what
        // `Assign.phases` hands to restored/rejoined clients.
        for &ci in &participants {
            *phase_counts.entry(ci).or_insert(0) += 1;
        }
        let completed = round + 1;
        if ctx.opts.stats_every > 0 && completed % ctx.opts.stats_every == 0 {
            // refresh the gauges the registry only mirrors at finalize,
            // then log the whole registry as one k=v line
            driver.session.stats().publish_registry();
            crate::coordinator::eventsim::publish_timings_registry(
                &driver.timings,
            );
            log::info!(
                "[stats] round {round}: {}",
                crate::telemetry::registry::snapshot_line()
            );
        }
        let due = ctx.opts.checkpoint_every > 0
            && completed % ctx.opts.checkpoint_every == 0;
        let halting =
            ctx.opts.halt_after > 0 && completed >= ctx.opts.halt_after;
        if due || halting {
            write_checkpoint(driver, ctx.opts, ctx.cfg_json, &rec, &phase_counts)?;
        }
        if halting {
            bail!(
                "halted by fault-injection hook after round {round} \
                 (state checkpointed)"
            );
        }
    }

    // server-side totals into the registry BEFORE finalize_record folds
    // the registry into the summary (gated inside: metrics off = no-op
    // lookups never happen)
    if crate::telemetry::metrics_enabled() {
        use crate::telemetry::registry::gauge;
        let cum = sum_counters(ctx.counters);
        gauge("net.total.bytes_sent").set(cum.bytes_sent as f64);
        gauge("net.total.bytes_recv").set(cum.bytes_recv as f64);
        gauge("net.total.frames_sent").set(cum.frames_sent as f64);
        gauge("net.total.frames_recv").set(cum.frames_recv as f64);
        gauge("net.conns").set(n_conns as f64);
        gauge("net.lanes").set(ctx.total_lanes as f64);
        gauge("net.nacks_sent").set(nacks_sent as f64);
        gauge("net.disconnects").set(churn.disconnects as f64);
        gauge("net.clients_cut").set(churn.clients_cut as f64);
    }
    driver.finalize_record(&mut rec);
    // multiplexing topology, for tooling that diffs a networked run
    // against an in-process one (`scripts/diff_net_metrics.py --virtual`)
    rec.set("net_conns", n_conns as f64);
    rec.set("net_lanes", ctx.total_lanes as f64);
    // churn accounting: all zero on a healthy run, so these keys never
    // perturb a bit-identity diff
    rec.set("net_disconnects", churn.disconnects as f64);
    rec.set("net_mid_frame", churn.mid_frame as f64);
    rec.set("clients_cut", churn.clients_cut as f64);
    Ok(RoundsOutcome { rec, nacks_sent, churn, stop_reason })
}

/// Push one decoded upload into the round queue and ack it over the
/// owning connection (typed NACK on a capacity drop, counted in
/// `nacks_sent`). Shared by the barrier (`Smashed`) and stream
/// (`SmashedSeq`) arms so the drop/ack contract cannot diverge between
/// drain modes. `ids` is `(client, round, step)`. Returns acceptance.
fn push_and_ack(
    queue: &crate::coordinator::server_queue::ServerQueue,
    tx: &mut Box<dyn TxHalf>,
    nacks_sent: &mut u64,
    ids: (usize, u32, u32),
    smashed: Vec<f32>,
    targets: Vec<i32>,
) -> Result<bool> {
    let (ci, round, step) = ids;
    let accepted = queue.push(SmashedBatch {
        client: ci,
        round: round as usize,
        step: step as usize,
        smashed,
        targets,
    });
    if !accepted {
        *nacks_sent += 1;
    }
    tx.send(&Msg::UploadAck {
        client: ci as u32,
        round,
        step,
        accepted,
        reason: if accepted {
            String::new()
        } else {
            "server queue at capacity".into()
        },
    })?;
    Ok(accepted)
}

fn check_round(got: u32, want: u32, what: &str) -> Result<()> {
    if got != want {
        bail!("{what}: round {got}, expected {want}");
    }
    Ok(())
}

/// Every client message is validated the same way: a bad round would
/// silently change the drain order / collection slots, and an
/// out-of-range or stolen client id would corrupt the merge (or panic
/// the sim). Ownership is per `(conn, lane)` — the stamped lane must be
/// the one the client was assigned to, so lanes multiplexed on the same
/// socket cannot speak for each other. Returns the validated client
/// index.
fn check_owned(
    owner: &[LaneAddr],
    conn: usize,
    lane: u32,
    client: u32,
    what: &str,
) -> Result<usize> {
    let ci = client as usize;
    if ci >= owner.len() || owner[ci].conn != conn {
        bail!("conn {conn}: {what} for client {ci} it does not own");
    }
    if owner[ci].lane != lane {
        bail!(
            "conn {conn}: {what} for client {ci} stamped lane {lane}, \
             owned by lane {}",
            owner[ci].lane
        );
    }
    Ok(ci)
}

fn check_client(got: u32, want: usize, what: &str) -> Result<()> {
    if got as usize != want {
        bail!("{what}: client {got}, expected {want}");
    }
    Ok(())
}
