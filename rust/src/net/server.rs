//! Networked Main/Fed-Server dispatcher: accepts N client connections —
//! each multiplexing any number of virtual-client *lanes* — and bridges
//! decoded wire messages into the *existing* round engine
//! (`ServerQueue` + `Driver::server_drain`/`finish_round`).
//!
//! ## Event-driven reception core (v4)
//!
//! Connections are no longer owned by one reader thread each. After the
//! blocking `Hello`/`Assign` handshake every transport is split into a
//! write half (owned by the orchestrator) and a non-blocking
//! [`crate::net::poller::PollSource`]; the sources are sharded
//! round-robin over a small fixed set of poll loops
//! ([`crate::net::poller::poll_shard`], `DEFAULT_SHARDS` threads total —
//! not per connection). Each loop sweeps its connections, reads whatever
//! bytes are available, reassembles frames incrementally in
//! per-connection buffers, and pushes decoded messages into the single
//! [`crate::net::poller::EventQueue`] the orchestrator consumes. A
//! thousand mostly-idle connections therefore cost a thousand reassembly
//! buffers, not a thousand parked threads.
//!
//! ## Client multiplexing and cohort scheduling
//!
//! `Hello{lanes}` declares how many virtual clients ride the connection;
//! the dispatcher derives one global lane index over all connections and
//! assigns logical client `i` to global lane `i % total_lanes` (which
//! degenerates to the classic `i % n_conns` round-robin when every
//! connection runs a single lane). One `Assign{lane, ..}` goes out per
//! lane, and every client→server upload is stamped with its lane, so
//! ownership is validated per `(conn, lane)` — a lane cannot speak for a
//! client that rides a different lane of the *same* socket.
//!
//! Per round the driver samples a cohort from the registered population
//! and holds round state only for it: the event-sim is cohort-scoped,
//! the driver's lazy client pool materializes per-client state only on
//! first touch (the networked server itself touches none), and SFLV1
//! server replicas exist only for the round's participants. Orchestrator
//! round state is O(cohort), not O(population).
//!
//! ## Orchestration
//!
//! 1. `RoundBarrier{round, participants}` to every connection, then the
//!    θ_l broadcast (`ModelSync{client: BROADCAST}`) to each connection
//!    that owns a participant (decoupled), or a per-client
//!    `ModelSync{client: ci}` kickoff processed *sequentially* in
//!    participant order (locked SFLV1/V2 — the training lock is the
//!    baseline's defining property).
//! 2. Decoupled uploads (`Smashed`, or `SmashedSeq` in `--drain stream`
//!    runs) are pushed straight into the round's [`ServerQueue`]; a
//!    capacity drop is answered with a typed NACK
//!    (`UploadAck{accepted: false}`) and lands in `QueueStats::dropped`.
//!    In stream mode the orchestrator then immediately runs
//!    [`Driver::server_pump`] — uploads are consumed **between events,
//!    mid-round, in arrival order** instead of waiting for the barrier
//!    (the dispatcher also validates each upload `seq` is strictly
//!    increasing **per `(conn, lane)`** — keying by connection alone
//!    would let two lanes multiplexed on one socket trip each other's
//!    ordering check — and feeds the frame's `sent_at` into the
//!    event-sim's arrival-driven server-occupancy model). Locked
//!    uploads run [`Driver::locked_server_exchange`] and reply with a
//!    `CutGrad`.
//! 3. Once every participant's `ZoUpdate` + `ModelSync` + `LocalDone`
//!    arrived, outcomes are absorbed **in participant order** — the same
//!    barrier-merge the in-process fan-out performs — then the queue is
//!    drained in `(round, client, step)` order and FSL-SAGE feedback is
//!    relayed as `AlignGrad` round-trips. In `--zo_wire seeds` mode no
//!    `ModelSync` comes back up at all: the `ZoUpdate` carries the
//!    per-probe gradient scalars and the dispatcher *replays* each
//!    client's h ZO steps from the broadcast θ
//!    (`zo::replay_trajectory`), after pinning the record shape and the
//!    counter-derived step seeds — bit-identical to the uploaded θ by
//!    construction.
//! 4. `Driver::finish_round` aggregates (Eq. 8) exactly as in-process;
//!    the round closes with a `RoundSummary` carrying the train loss,
//!    the analytic comm bytes, and the measured wire bytes.
//!
//! Because every model-state mutation runs through the same `Driver`
//! methods with inputs in the same order, a networked run is bit-identical
//! to `Driver::run_round` regardless of how clients are multiplexed over
//! sockets (asserted for all five algorithms — per-connection *and*
//! lane-multiplexed — in `rust/tests/net_loopback.rs`).

use crate::coordinator::config::{RunConfig, ZoWireMode};
use crate::coordinator::drain::DrainMode;
use crate::coordinator::eventsim::{ClientLane, DeviceProfile, WireRoundStats};
use crate::coordinator::local::{self, LocalOutcome};
use crate::coordinator::round::Driver;
use crate::coordinator::server_queue::SmashedBatch;
use crate::metrics::RunRecord;
use crate::net::poller::{
    poll_shard, shard_conns, Event, EventQueue, PollConn, DEFAULT_SHARDS,
};
use crate::net::transport::{Transport, TxHalf, WireCounters};
use crate::net::wire::{Msg, BROADCAST, VERSION};
use crate::runtime::Session;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Sanity cap on a single connection's declared lane count: a corrupt
/// or hostile `Hello` must not make the dispatcher allocate unbounded
/// per-lane state before the run even starts.
const MAX_LANES_PER_CONN: u32 = 1 << 20;

/// What a completed networked run hands back to the caller.
pub struct NetReport {
    pub record: RunRecord,
    pub final_theta_l: Vec<f32>,
    pub final_theta_s: Vec<f32>,
    /// total measured traffic, server-side view, including handshake and
    /// shutdown frames (per-round deltas live in `RoundTiming::wire`)
    pub wire: WireRoundStats,
    /// typed NACKs sent for queue-capacity drops
    pub nacks_sent: u64,
    /// connections served
    pub connections: usize,
    /// virtual-client lanes served, summed over all connections
    pub lanes: usize,
}

/// Accept `n_conns` TCP client connections and run the configured
/// experiment over them.
pub fn serve_tcp(
    session: &Session,
    cfg: RunConfig,
    listener: std::net::TcpListener,
    n_conns: usize,
    record_name: &str,
) -> Result<NetReport> {
    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n_conns);
    for i in 0..n_conns {
        let (stream, addr) = listener.accept().context("accepting client")?;
        log::info!("connection {i}/{n_conns} from {addr}");
        transports
            .push(Box::new(super::transport::TcpTransport::from_stream(stream)?));
    }
    serve_transports(session, cfg, transports, record_name)
}

/// Which connection — and which virtual lane on it — owns a logical
/// client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LaneAddr {
    conn: usize,
    lane: u32,
}

/// Pop the next *message* event, turning closes/errors into errors.
fn next_msg(events: &EventQueue) -> Result<(usize, Msg)> {
    match events.pop() {
        (conn, Event::Msg(m)) => Ok((conn, m)),
        (conn, Event::Closed) => {
            bail!("connection {conn} closed mid-protocol")
        }
        (conn, Event::Err(e)) => bail!("connection {conn} failed: {e}"),
    }
}

fn sum_counters(counters: &[Arc<WireCounters>]) -> WireRoundStats {
    let mut total = WireRoundStats::default();
    for c in counters {
        let s = c.snapshot();
        total.bytes_sent += s.bytes_sent;
        total.bytes_recv += s.bytes_recv;
        total.frames_sent += s.frames_sent;
        total.frames_recv += s.frames_recv;
    }
    total
}

/// Run the full experiment over already-connected transports (the TCP
/// path lands here after `accept`; loopback tests call it directly).
/// Logical client `i` belongs to global lane `i % total_lanes` — a
/// round-robin over every lane of every connection, which collapses to
/// the classic `i % n_conns` when each connection declares one lane.
pub fn serve_transports(
    session: &Session,
    cfg: RunConfig,
    mut transports: Vec<Box<dyn Transport>>,
    record_name: &str,
) -> Result<NetReport> {
    if transports.is_empty() {
        bail!("serve: need at least one client connection");
    }
    cfg.validate()?;
    let n_conns = transports.len();
    let cfg_json = cfg.to_json().to_string();

    // ---- handshake pass 1: every Hello, for the lane declarations.
    // Lane→client assignment needs the GLOBAL lane count, so no Assign
    // can go out before every connection has said hello.
    let mut lanes_per_conn: Vec<u32> = Vec::with_capacity(n_conns);
    for (j, t) in transports.iter_mut().enumerate() {
        match t.recv()? {
            Some(Msg::Hello { name, protocol, lanes }) => {
                if protocol != VERSION as u32 {
                    let m = Msg::Shutdown {
                        reason: format!(
                            "protocol {protocol} unsupported (speak {VERSION})"
                        ),
                    };
                    let _ = t.send(&m);
                    bail!("conn {j} ({name}): protocol {protocol} unsupported");
                }
                if lanes == 0 || lanes > MAX_LANES_PER_CONN {
                    let m = Msg::Shutdown {
                        reason: format!("lane count {lanes} out of range"),
                    };
                    let _ = t.send(&m);
                    bail!("conn {j} ({name}): lane count {lanes} out of range");
                }
                log::info!(
                    "conn {j}: hello from {name} ({}), {lanes} lane(s)",
                    t.peer()
                );
                lanes_per_conn.push(lanes);
            }
            other => bail!("conn {j}: expected Hello, got {other:?}"),
        }
    }

    // global lane index: conn j's lanes occupy [off_j, off_j + lanes_j)
    let total_lanes: usize = lanes_per_conn.iter().map(|&l| l as usize).sum();
    let mut lane_addr: Vec<LaneAddr> = Vec::with_capacity(total_lanes);
    for (j, &l) in lanes_per_conn.iter().enumerate() {
        for k in 0..l {
            lane_addr.push(LaneAddr { conn: j, lane: k });
        }
    }
    let owner: Vec<LaneAddr> =
        (0..cfg.n_clients).map(|i| lane_addr[i % total_lanes]).collect();

    // ---- handshake pass 2: one Assign per lane, in local lane order ----
    let mut next_global = 0usize;
    for (j, t) in transports.iter_mut().enumerate() {
        for k in 0..lanes_per_conn[j] {
            let ids: Vec<u32> = (0..cfg.n_clients)
                .filter(|&i| i % total_lanes == next_global)
                .map(|i| i as u32)
                .collect();
            t.send(&Msg::Assign {
                lane: k,
                client_ids: ids,
                config: cfg_json.clone(),
            })?;
            next_global += 1;
        }
    }

    let counters: Vec<Arc<WireCounters>> =
        transports.iter().map(|t| t.counters()).collect();

    // ---- split: write halves stay with the orchestrator, read sides
    // become non-blocking poll sources sharded over a few poll loops ----
    let mut txs: Vec<Box<dyn TxHalf>> = Vec::with_capacity(n_conns);
    let mut pconns: Vec<PollConn> = Vec::with_capacity(n_conns);
    for (j, t) in transports.into_iter().enumerate() {
        let (tx, src) = t.poll_split();
        txs.push(tx);
        pconns.push(PollConn { conn: j, src, counters: counters[j].clone() });
    }
    let events = EventQueue::new();

    let mut driver = Driver::new(session, cfg)?;
    driver.warmup()?;

    let mut report: Option<(RunRecord, u64)> = None;
    let mut run_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        for shard in shard_conns(pconns, DEFAULT_SHARDS) {
            let events = &events;
            scope.spawn(move || poll_shard(shard, events));
        }

        match run_rounds(
            &mut driver,
            &mut txs,
            &events,
            &owner,
            total_lanes,
            &counters,
            record_name,
        ) {
            Ok(r) => report = Some(r),
            Err(e) => run_err = Some(e),
        }

        // End of run (or abort): tell every client to go home — this is
        // also what unblocks the poll loops, since clients close their
        // sockets once they see the Shutdown.
        let reason = match &run_err {
            None => "run complete".to_string(),
            Some(e) => format!("server error: {e:#}"),
        };
        for tx in &mut txs {
            let _ = tx.send(&Msg::Shutdown { reason: reason.clone() });
        }
        drop(txs); // loopback: closes the server→client pipes
    });
    if let Some(e) = run_err {
        return Err(e);
    }
    let (record, nacks_sent) = report.expect("run produced no report");

    Ok(NetReport {
        record,
        final_theta_l: driver.theta_l.clone(),
        final_theta_s: driver.theta_s.clone(),
        wire: sum_counters(&counters),
        nacks_sent,
        connections: n_conns,
        lanes: total_lanes,
    })
}

/// Per-participant collection state for one decoupled round.
#[derive(Default)]
struct Collected {
    losses: Option<Vec<f64>>,
    seeds: Vec<i32>,
    /// flattened h × n_p per-probe gradient scalars (seeds wire mode)
    gscales: Vec<f32>,
    theta: Option<Vec<f32>>,
    done: Option<(u64, u64, f64, f64)>, // comm, flops, lane_time, lane_idle
}

/// Reconstruct one client's end-of-phase θ from its lean wire record
/// (`--zo_wire seeds`): validate the record shape, check every step seed
/// against the counter derivation the client must have used (a client
/// cannot steer the replay off the deterministic trajectory), then
/// replay h ZO updates from the round's broadcast θ. Bit-identical to
/// the θ the client would have uploaded in `theta` mode.
fn replay_theta(
    cfg: &RunConfig,
    round: usize,
    ci: usize,
    theta0: &[f32],
    c: &Collected,
) -> Result<Vec<f32>> {
    let h = cfg.local_steps;
    let np = cfg.n_pert.max(1);
    if c.seeds.len() != h {
        bail!(
            "client {ci}: seeds-mode record has {} seeds, expected {h}",
            c.seeds.len()
        );
    }
    if c.gscales.len() != h * np {
        bail!(
            "client {ci}: seeds-mode record has {} gscales, expected {}",
            c.gscales.len(),
            h * np
        );
    }
    for (s, &seed) in c.seeds.iter().enumerate() {
        let want = local::step_seed(cfg, round, ci, s + 1);
        if seed != want {
            bail!(
                "client {ci}: step {} seed {seed} != derived {want}",
                s + 1
            );
        }
    }
    crate::zo::replay_trajectory(theta0, &c.seeds, np, &c.gscales)
        .context("replaying seeds-mode update")
}

fn run_rounds(
    driver: &mut Driver,
    txs: &mut [Box<dyn TxHalf>],
    events: &EventQueue,
    owner: &[LaneAddr],
    total_lanes: usize,
    counters: &[Arc<WireCounters>],
    record_name: &str,
) -> Result<(RunRecord, u64)> {
    let n_conns = txs.len();
    let mut rec = RunRecord::new(record_name);
    let t0 = std::time::Instant::now();
    let mut nacks_sent = 0u64;
    let profile = DeviceProfile::edge_default();

    let stream = driver.cfg.drain == DrainMode::Stream;

    for round in 0..driver.cfg.rounds {
        let wire_before = sum_counters(counters);
        let participants = driver.sample_participants();
        let parts_u32: Vec<u32> =
            participants.iter().map(|&c| c as u32).collect();
        let mut sim = driver.new_sim(&participants);
        let queue = driver.round_queue(participants.len());
        let mut losses: Vec<f64> = Vec::new();
        let mut updated: Vec<(usize, Vec<f32>)> = Vec::new();
        // feedback consumed mid-round by the stream drain policy; the
        // barrier leftovers from `server_drain` are appended below
        let mut feedback: Vec<(usize, Vec<f32>)> = Vec::new();
        // next expected upload seq for this round, per (conn, lane) —
        // two lanes multiplexed on one socket interleave their uploads
        // arbitrarily, so keying by connection alone would reject valid
        // traffic while accepting cross-lane reordering
        let mut next_seq: BTreeMap<(usize, u32), u32> = BTreeMap::new();
        let r32 = round as u32;

        // broadcasts are built once and serialized per connection —
        // never clone model-sized payloads per receiver
        let barrier_msg =
            Msg::RoundBarrier { round: r32, participants: parts_u32.clone() };
        for tx in txs.iter_mut() {
            tx.send(&barrier_msg)?;
        }

        if driver.cfg.algorithm.is_decoupled() {
            // The real parallelism width is the client-process count.
            sim.set_workers(n_conns.min(participants.len()).max(1));
            let lean = driver.cfg.zo_wire == ZoWireMode::Seeds;
            // seeds mode: keep the broadcast θ — it is the replay origin
            let theta0: Vec<f32> =
                if lean { driver.theta_l.clone() } else { Vec::new() };
            let active: Vec<usize> = (0..n_conns)
                .filter(|&j| participants.iter().any(|&c| owner[c].conn == j))
                .collect();
            let sync_msg = Msg::ModelSync {
                lane: BROADCAST,
                round: r32,
                client: BROADCAST,
                theta: driver.theta_l.clone(),
            };
            for &j in &active {
                txs[j].send(&sync_msg)?;
            }

            // ---- collect the fan-out: acks flow back per upload ----
            let mut got: BTreeMap<usize, Collected> = BTreeMap::new();
            let mut done_count = 0usize;
            while done_count < participants.len() {
                let (conn, msg) = next_msg(events)?;
                match msg {
                    Msg::Smashed {
                        lane,
                        client,
                        round: r,
                        step,
                        smashed,
                        targets,
                    } => {
                        if stream {
                            bail!(
                                "conn {conn}: plain Smashed in a --drain \
                                 stream run (expected SmashedSeq)"
                            );
                        }
                        check_round(r, r32, "Smashed")?;
                        let ci =
                            check_owned(owner, conn, lane, client, "Smashed")?;
                        push_and_ack(
                            &queue,
                            &mut txs[conn],
                            &mut nacks_sent,
                            (ci, r32, step),
                            smashed,
                            targets,
                        )?;
                    }
                    Msg::SmashedSeq {
                        lane,
                        client,
                        round: r,
                        step,
                        seq,
                        sent_at,
                        smashed,
                        targets,
                    } => {
                        if !stream {
                            bail!(
                                "conn {conn}: SmashedSeq outside a --drain \
                                 stream run"
                            );
                        }
                        check_round(r, r32, "SmashedSeq")?;
                        let ci = check_owned(
                            owner, conn, lane, client, "SmashedSeq",
                        )?;
                        let next = next_seq.entry((conn, lane)).or_insert(1);
                        if seq != *next {
                            bail!(
                                "conn {conn} lane {lane}: upload seq {seq} \
                                 for client {ci}, expected {next} (reordered, \
                                 duplicated or dropped frame)"
                            );
                        }
                        *next += 1;
                        // the sent_at timestamp feeds arithmetic (sort,
                        // schedule folds) — reject non-finite garbage at
                        // the ingress, like every other field check
                        if !sent_at.is_finite() || sent_at < 0.0 {
                            bail!(
                                "conn {conn}: client {ci} upload sent_at \
                                 {sent_at} is not a finite non-negative time"
                            );
                        }
                        let accepted = push_and_ack(
                            &queue,
                            &mut txs[conn],
                            &mut nacks_sent,
                            (ci, r32, step),
                            smashed,
                            targets,
                        )?;
                        // arrival-driven server occupancy: only accepted
                        // uploads become server work — a dropped batch is
                        // never serviced, so it must not enter the
                        // schedule
                        if accepted {
                            sim.upload_arrival(sent_at);
                        }
                        // pipelined mid-round consumption: drain in
                        // arrival order between events instead of
                        // holding everything to the round barrier
                        driver.server_pump(&queue, &mut sim, &mut feedback)?;
                    }
                    Msg::ZoUpdate {
                        lane,
                        client,
                        round: r,
                        seeds,
                        scalars,
                        gscales,
                    } => {
                        check_round(r, r32, "ZoUpdate")?;
                        let ci =
                            check_owned(owner, conn, lane, client, "ZoUpdate")?;
                        let e = got.entry(ci).or_default();
                        e.losses =
                            Some(scalars.iter().map(|&l| l as f64).collect());
                        e.seeds = seeds;
                        e.gscales = gscales;
                    }
                    Msg::ModelSync { lane, client, round: r, theta } => {
                        check_round(r, r32, "ModelSync")?;
                        let ci =
                            check_owned(owner, conn, lane, client, "ModelSync")?;
                        got.entry(ci).or_default().theta = Some(theta);
                    }
                    Msg::LocalDone {
                        lane,
                        client,
                        round: r,
                        comm_bytes,
                        flops,
                        lane_time,
                        lane_idle,
                    } => {
                        check_round(r, r32, "LocalDone")?;
                        let ci =
                            check_owned(owner, conn, lane, client, "LocalDone")?;
                        let e = got.entry(ci).or_default();
                        if e.done.is_some() {
                            bail!("conn {conn}: duplicate LocalDone for {ci}");
                        }
                        e.done =
                            Some((comm_bytes, flops, lane_time, lane_idle));
                        done_count += 1;
                    }
                    other => bail!(
                        "conn {conn}: unexpected {} during fan-out",
                        other.name()
                    ),
                }
            }

            // ---- barrier merge, in participant order (as in-process) ----
            for &ci in &participants {
                let mut c = got.remove(&ci).with_context(|| {
                    format!("client {ci} sent LocalDone data out of band")
                })?;
                let (comm_bytes, flops, lane_time, lane_idle) = c
                    .done
                    .with_context(|| format!("client {ci}: missing LocalDone"))?;
                let mut lane = ClientLane::new(&profile);
                lane.time = lane_time;
                lane.idle = lane_idle;
                // theta mode: the client uploaded θ. seeds mode: no θ
                // ever crossed the wire — replay it from the record.
                let theta = match (c.theta.take(), lean) {
                    (Some(_), true) => bail!(
                        "client {ci}: unexpected θ upload in seeds wire mode"
                    ),
                    (Some(t), false) => t,
                    (None, true) => {
                        replay_theta(&driver.cfg, round, ci, &theta0, &c)?
                    }
                    (None, false) => {
                        bail!("client {ci}: missing θ")
                    }
                };
                let outcome = LocalOutcome {
                    ci,
                    theta,
                    losses: c
                        .losses
                        .with_context(|| format!("client {ci}: missing losses"))?,
                    seeds: c.seeds,
                    gscales: c.gscales,
                    comm_bytes,
                    flops,
                    lane,
                };
                driver.absorb_outcome(outcome, &mut sim, &mut losses, &mut updated);
            }
        } else {
            // ---- locked SFLV1/V2: strictly sequential per participant ----
            sim.set_workers(1);
            for &ci in &participants {
                let addr = owner[ci];
                txs[addr.conn].send(&Msg::ModelSync {
                    lane: addr.lane,
                    round: r32,
                    client: ci as u32,
                    theta: driver.theta_l.clone(),
                })?;
                let theta_end = loop {
                    let (conn, msg) = next_msg(events)?;
                    if conn != addr.conn {
                        bail!(
                            "conn {conn}: traffic during client {ci}'s locked phase"
                        );
                    }
                    match msg {
                        Msg::Smashed {
                            lane,
                            client,
                            round: r,
                            step,
                            smashed,
                            targets,
                        } => {
                            check_round(r, r32, "Smashed")?;
                            check_owned(owner, conn, lane, client, "Smashed")?;
                            check_client(client, ci, "Smashed")?;
                            let (loss, g) = driver.locked_server_exchange(
                                ci, smashed, targets, &mut sim,
                            )?;
                            losses.push(loss);
                            txs[conn].send(&Msg::CutGrad {
                                client,
                                round: r,
                                step,
                                loss: loss as f32,
                                g,
                            })?;
                        }
                        Msg::ModelSync { lane, client, round: r, theta } => {
                            check_round(r, r32, "ModelSync")?;
                            check_owned(owner, conn, lane, client, "ModelSync")?;
                            check_client(client, ci, "ModelSync")?;
                            break theta;
                        }
                        other => bail!(
                            "conn {conn}: unexpected {} during locked phase",
                            other.name()
                        ),
                    }
                };
                driver.comm_bytes += driver.book.comm_per_round_sync();
                sim.sync(driver.book.comm_per_round_sync());
                updated.push((ci, theta_end));
            }
        }

        // ---- server phase: barrier drain (everything, Eq. 7 order) or
        // stream-mode stragglers (arrival order) ----
        let leftovers = driver.server_drain(&queue, &mut sim)?;
        feedback.extend(leftovers);
        for (ci, g) in feedback {
            driver.note_alignment_accounting(ci, &mut sim);
            let Some(pos) = updated.iter().position(|(c, _)| *c == ci) else {
                continue;
            };
            let addr = owner[ci];
            txs[addr.conn].send(&Msg::AlignGrad {
                client: ci as u32,
                round: r32,
                g,
            })?;
            loop {
                let (conn, msg) = next_msg(events)?;
                match msg {
                    Msg::ModelSync { lane, client, round: r, theta }
                        if conn == addr.conn && client as usize == ci =>
                    {
                        if lane != addr.lane {
                            bail!(
                                "conn {conn}: align ModelSync for client {ci} \
                                 on lane {lane}, owned by lane {}",
                                addr.lane
                            );
                        }
                        check_round(r, r32, "align ModelSync")?;
                        updated[pos].1 = theta;
                        break;
                    }
                    other => bail!(
                        "conn {conn}: unexpected {} during alignment",
                        other.name()
                    ),
                }
            }
        }

        // ---- close the round: summary out, then aggregate ----
        let loss_preview =
            losses.iter().sum::<f64>() / losses.len().max(1) as f64;
        let cum = sum_counters(counters);
        let summary_msg = Msg::RoundSummary {
            round: r32,
            train_loss: loss_preview,
            comm_bytes: driver.comm_bytes,
            wire_bytes: cum.bytes_sent + cum.bytes_recv,
        };
        for tx in txs.iter_mut() {
            tx.send(&summary_msg)?;
        }
        sim.record_wire(sum_counters(counters).since(&wire_before));
        let loss = driver.finish_round(&participants, updated, sim, &losses);
        driver.record_round(&mut rec, round, loss, t0)?;
    }

    driver.finalize_record(&mut rec);
    // multiplexing topology, for tooling that diffs a networked run
    // against an in-process one (`scripts/diff_net_metrics.py --virtual`)
    rec.set("net_conns", n_conns as f64);
    rec.set("net_lanes", total_lanes as f64);
    Ok((rec, nacks_sent))
}

/// Push one decoded upload into the round queue and ack it over the
/// owning connection (typed NACK on a capacity drop, counted in
/// `nacks_sent`). Shared by the barrier (`Smashed`) and stream
/// (`SmashedSeq`) arms so the drop/ack contract cannot diverge between
/// drain modes. `ids` is `(client, round, step)`. Returns acceptance.
fn push_and_ack(
    queue: &crate::coordinator::server_queue::ServerQueue,
    tx: &mut Box<dyn TxHalf>,
    nacks_sent: &mut u64,
    ids: (usize, u32, u32),
    smashed: Vec<f32>,
    targets: Vec<i32>,
) -> Result<bool> {
    let (ci, round, step) = ids;
    let accepted = queue.push(SmashedBatch {
        client: ci,
        round: round as usize,
        step: step as usize,
        smashed,
        targets,
    });
    if !accepted {
        *nacks_sent += 1;
    }
    tx.send(&Msg::UploadAck {
        client: ci as u32,
        round,
        step,
        accepted,
        reason: if accepted {
            String::new()
        } else {
            "server queue at capacity".into()
        },
    })?;
    Ok(accepted)
}

fn check_round(got: u32, want: u32, what: &str) -> Result<()> {
    if got != want {
        bail!("{what}: round {got}, expected {want}");
    }
    Ok(())
}

/// Every client message is validated the same way: a bad round would
/// silently change the drain order / collection slots, and an
/// out-of-range or stolen client id would corrupt the merge (or panic
/// the sim). Ownership is per `(conn, lane)` — the stamped lane must be
/// the one the client was assigned to, so lanes multiplexed on the same
/// socket cannot speak for each other. Returns the validated client
/// index.
fn check_owned(
    owner: &[LaneAddr],
    conn: usize,
    lane: u32,
    client: u32,
    what: &str,
) -> Result<usize> {
    let ci = client as usize;
    if ci >= owner.len() || owner[ci].conn != conn {
        bail!("conn {conn}: {what} for client {ci} it does not own");
    }
    if owner[ci].lane != lane {
        bail!(
            "conn {conn}: {what} for client {ci} stamped lane {lane}, \
             owned by lane {}",
            owner[ci].lane
        );
    }
    Ok(ci)
}

fn check_client(got: u32, want: usize, what: &str) -> Result<()> {
    if got as usize != want {
        bail!("{what}: client {got}, expected {want}");
    }
    Ok(())
}
