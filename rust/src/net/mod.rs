//! `heron-net` (substrate S20): a real wire protocol + pluggable
//! transport layer for the SFL client↔server path.
//!
//! Until this subsystem existed, `comm_bytes` was a purely analytic
//! counter (`coordinator::accounting`) — the reproduction never
//! serialized a byte. `net` turns the byte accounting into a
//! measurement:
//!
//! * [`wire`] — versioned, length-prefixed, CRC-32-checksummed binary
//!   codec with typed messages for the full SFL protocol (`Hello/Assign`,
//!   `ZoUpdate{seeds, scalars, gscales}`, `SmashedBatch`, `CutGradient`,
//!   `ModelSync`, `RoundBarrier`/`RoundSummary`, typed `UploadAck`
//!   NACKs). Hand-rolled little-endian layout, like `util::json` — the
//!   crate is vendored-offline, so no serde.
//! * [`transport`] — a blocking [`transport::Transport`] trait with an
//!   in-memory loopback backend (still encodes/decodes every frame, so
//!   tests measure real bytes) and a `std::net::TcpStream` backend.
//! * [`poller`] — the event-driven reception core (v4): a small sharded
//!   set of poll loops own the non-blocking read sides of every
//!   connection, parse frames incrementally into per-connection
//!   reassembly buffers, and feed one event queue owned by the
//!   orchestrator.
//! * [`server`] — the orchestrator: accepts N client connections (each
//!   multiplexing any number of virtual-client *lanes*) and bridges
//!   poller events into the existing `ServerQueue` + `Driver` round
//!   engine (`heron-sfl serve`).
//! * [`client`] — the remote client endpoint driving the local ZO/FO
//!   phase (`heron-sfl connect`); `connect --virtual N` drives N
//!   simulated edge devices through one socket.
//! * [`storm`] — the serve-storm load generator (`bench serve-storm` +
//!   CI's `serve-storm-smoke`): real TCP dispatcher + multiplexed
//!   clients, measuring rounds/sec and p99 round latency vs the
//!   virtual-client count.
//!
//! The contract (pinned by `rust/tests/net_loopback.rs`): for every
//! algorithm, a networked run is **bit-identical** to the in-process
//! `Driver::run_round` trajectory — same per-round losses, metrics,
//! analytic comm bytes, and final parameters — while the run summary
//! additionally reports the *measured* wire traffic next to the analytic
//! `CostBook` numbers.
//!
//! Fault tolerance (v5): the dispatcher survives the real world —
//! `--round_deadline_ms` cuts stragglers at a wall-clock round deadline,
//! a vanished peer is a typed [`poller::Event::PeerDisconnected`] whose
//! clients are cut from the open round (and whose lane block a
//! reconnecting client can take over between rounds, with
//! `Assign{rejoin_round, phases}` fast-forwarding its data streams),
//! and [`ServeOptions`] adds CRC-checksummed checkpoint/restore
//! (`coordinator::checkpoint`) plus a SIGINT/SIGTERM → final checkpoint
//! + clean `Shutdown` path. Pinned by `rust/tests/chaos.rs` and
//! `scripts/chaos_smoke.sh`.
//!
//! Payload codecs (v6): [`codec`] defines self-describing envelopes for
//! the smashed-activation and cut-gradient payloads — identity `f32`
//! (the default, bit-exact), `int8`/`int4` per-tensor affine
//! quantization, and `topk` gradient sparsification. The choice is a
//! negotiated capability: clients advertise supported codec ids in
//! `Hello.codecs`, the dispatcher picks per `RunConfig`
//! (`--codec`/`--grad_codec`, shipped to clients inside `Assign`'s
//! config JSON) and validates the pick against each client's
//! advertisement. Only the payload envelope changed in v6 — frame
//! framing/CRC and all v5 control messages are untouched.
//!
//! The lean `--zo_wire seeds` mode (HERON only) is the subsystem's
//! headline: clients upload `ZoUpdate{seeds, gscales}` — one i32 seed
//! plus n_p gradient scalars per local step — instead of the full θ_l,
//! and the dispatcher replays the ZO update server-side
//! (`zo::replay_trajectory`). The trajectory stays bit-identical to
//! `theta` mode while the measured client→server bytes drop *below* the
//! analytic `2(|θc|+|θa|)` ModelSync cost of Table I.

pub mod client;
pub mod codec;
pub mod poller;
pub mod server;
pub mod storm;
pub mod transport;
pub mod wire;

pub use client::{run_client, run_client_virtual, ClientReport};
pub use server::{
    serve_tcp, serve_tcp_opts, serve_transports, serve_transports_opts,
    NetReport, ServeOptions,
};
pub use storm::{run_storm, storm_config, StormPoint};
pub use transport::{loopback_pair, TcpTransport, Transport};
pub use wire::{Msg, WireError, VERSION};
