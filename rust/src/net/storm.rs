//! serve-storm: the event-core load generator behind `bench serve-storm`
//! and the CI `serve-storm-smoke` job.
//!
//! One storm point boots a real TCP dispatcher (`serve_tcp` on an
//! ephemeral localhost port), attaches `conns` client processes each
//! multiplexing `lanes_per_conn` virtual clients
//! (`run_client_virtual`), runs the configured experiment to completion,
//! and reports round throughput (rounds/sec) plus the p99 per-round
//! latency (nearest-rank over the per-round wall-clock deltas the run
//! record already carries). Sweeping `lanes_per_conn` with `conns`
//! fixed is how the bench shows the tentpole property: a thousand
//! virtual clients ride ≤16 sockets through the sharded poll loops
//! without a thousand reader threads.
//!
//! The storm workload is deliberately the *real* protocol — the same
//! `Driver`, the same wire codec, the same bit-identity contract — not
//! a synthetic echo loop, so a regression here is a regression users
//! would feel in `serve`/`connect`.

use crate::coordinator::config::{RunConfig, ZoWireMode};
use crate::net::client::{run_client_virtual, ClientReport};
use crate::net::server::{serve_tcp, NetReport};
use crate::net::transport::TcpTransport;
use crate::runtime::Session;
use anyhow::{Context, Result};

/// The workload `configs/serve_storm.json` encodes (kept in sync by
/// `repo_presets_load_and_validate` + the storm preset test): a large
/// registered population, a small sampled cohort, one lean local step —
/// round orchestration dominates, model math stays light enough for CI.
pub fn storm_config() -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        n_clients: 1024,
        participation: 0.0625, // cohort of 64 per round
        rounds: 3,
        local_steps: 1,
        upload_every: 1,
        // no eval inside the timed loop — the bench measures protocol
        // round throughput, not the eval entry
        eval_every: 0,
        // lean uploads: seeds + per-probe scalars instead of full θ_l
        zo_wire: ZoWireMode::Seeds,
        ..RunConfig::default()
    }
}

/// One measured storm point.
#[derive(Debug, Clone)]
pub struct StormPoint {
    pub conns: usize,
    pub lanes_per_conn: usize,
    /// total virtual clients = conns × lanes_per_conn
    pub total_lanes: usize,
    pub rounds: usize,
    pub wall_seconds: f64,
    pub rounds_per_sec: f64,
    pub mean_round_seconds: f64,
    /// nearest-rank p99 over the per-round wall-clock deltas
    pub p99_round_seconds: f64,
    /// lanes that either ran a local phase or owned no clients at all
    pub lanes_complete: usize,
    pub nacks: u64,
    /// total measured wire traffic, server-side view
    pub wire_bytes: u64,
}

/// Nearest-rank percentile (`p` in [0, 1]) over an ascending-sorted
/// slice. Returns 0 for an empty slice.
pub fn percentile_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// A lane is complete when it ran at least one local phase, or never
/// owned a client in the first place (population < total lanes).
pub fn lanes_complete(rep: &ClientReport) -> usize {
    (0..rep.lanes)
        .filter(|&k| rep.lane_clients[k] == 0 || rep.lane_phases[k] > 0)
        .count()
}

/// Run one storm point: serve `cfg` over TCP on an ephemeral localhost
/// port and drive it with `conns` clients × `lanes_per_conn` virtual
/// lanes each.
pub fn run_storm(
    session: &Session,
    cfg: RunConfig,
    conns: usize,
    lanes_per_conn: usize,
) -> Result<StormPoint> {
    cfg.validate()?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")
        .context("binding storm listener")?;
    let addr = listener.local_addr()?.to_string();
    let rounds = cfg.rounds;

    let mut server_out: Option<Result<NetReport>> = None;
    let mut client_out: Vec<Result<ClientReport>> = Vec::new();
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_tcp(session, cfg.clone(), listener, conns, "storm")
        });
        let clients: Vec<_> = (0..conns)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let t = TcpTransport::connect(&addr)?;
                    run_client_virtual(
                        session,
                        Box::new(t),
                        &format!("storm-{i}"),
                        lanes_per_conn,
                    )
                })
            })
            .collect();
        server_out = Some(server.join().expect("storm server panicked"));
        client_out = clients
            .into_iter()
            .map(|h| h.join().expect("storm client panicked"))
            .collect();
    });
    let report = server_out.expect("storm server never ran")?;
    let reports: Vec<ClientReport> =
        client_out.into_iter().collect::<Result<_>>()?;

    let rec = &report.record;
    let wall = rec.rounds.last().map(|r| r.wall_seconds).unwrap_or(0.0);
    let mut lat: Vec<f64> = Vec::with_capacity(rec.rounds.len());
    let mut prev = 0.0;
    for r in &rec.rounds {
        lat.push((r.wall_seconds - prev).max(0.0));
        prev = r.wall_seconds;
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = lat.iter().sum::<f64>() / lat.len().max(1) as f64;

    if crate::telemetry::metrics_enabled() {
        use crate::telemetry::registry::gauge;
        gauge("storm.conns").set(conns as f64);
        gauge("storm.lanes_per_conn").set(lanes_per_conn as f64);
        gauge("storm.rounds_per_sec").set(rounds as f64 / wall.max(1e-12));
        gauge("storm.p99_round_seconds")
            .set(percentile_nearest_rank(&lat, 0.99));
        gauge("storm.nacks").set(report.nacks_sent as f64);
        gauge("storm.wire_bytes")
            .set((report.wire.bytes_sent + report.wire.bytes_recv) as f64);
    }

    Ok(StormPoint {
        conns,
        lanes_per_conn,
        total_lanes: report.lanes,
        rounds,
        wall_seconds: wall,
        rounds_per_sec: rounds as f64 / wall.max(1e-12),
        mean_round_seconds: mean,
        p99_round_seconds: percentile_nearest_rank(&lat, 0.99),
        lanes_complete: reports.iter().map(lanes_complete).sum(),
        nacks: report.nacks_sent,
        wire_bytes: report.wire.bytes_sent + report.wire.bytes_recv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentile() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&v, 0.99), 99.0);
        assert_eq!(percentile_nearest_rank(&v, 1.0), 100.0);
        assert_eq!(percentile_nearest_rank(&v, 0.5), 50.0);
        assert_eq!(percentile_nearest_rank(&[2.5], 0.99), 2.5);
        assert_eq!(percentile_nearest_rank(&[], 0.99), 0.0);
    }

    #[test]
    fn storm_config_is_valid_and_cohort_sized() {
        let cfg = storm_config();
        cfg.validate().unwrap();
        assert_eq!(cfg.n_clients, 1024);
        assert_eq!(cfg.participants_per_round(), 64);
        assert_eq!(cfg.eval_every, 0, "no eval inside the timed loop");
    }

    /// `configs/serve_storm.json` must stay the on-disk spelling of
    /// `storm_config()` — `bench serve-storm --config` and the in-code
    /// default may not drift apart.
    #[test]
    fn storm_preset_matches_storm_config() {
        let mut dir = std::env::current_dir().unwrap();
        loop {
            if dir.join("configs").exists() {
                break;
            }
            assert!(dir.pop(), "configs/ not found above cwd");
        }
        let loaded =
            RunConfig::load(&dir.join("configs/serve_storm.json")).unwrap();
        let code = storm_config();
        assert_eq!(loaded.variant, code.variant);
        assert_eq!(loaded.n_clients, code.n_clients);
        assert_eq!(loaded.participation, code.participation);
        assert_eq!(loaded.rounds, code.rounds);
        assert_eq!(loaded.local_steps, code.local_steps);
        assert_eq!(loaded.upload_every, code.upload_every);
        assert_eq!(loaded.eval_every, code.eval_every);
        assert_eq!(loaded.zo_wire, code.zo_wire);
        assert_eq!(loaded.algorithm.name(), code.algorithm.name());
    }

    #[test]
    fn lanes_complete_counts_idle_unowned_lanes() {
        let rep = ClientReport {
            name: "t".into(),
            assigned: vec![0, 1],
            lanes: 3,
            lane_clients: vec![1, 1, 0],
            rounds: 1,
            phases: 1,
            lane_phases: vec![1, 0, 0],
            nacks: 0,
            lane_nacks: vec![0, 0, 0],
            wire: Default::default(),
            shutdown_reason: "run complete".into(),
        };
        // lane 0 worked, lane 2 owns nobody — lane 1 owned a client but
        // never ran a phase, so it is NOT complete
        assert_eq!(lanes_complete(&rep), 2);
    }
}
