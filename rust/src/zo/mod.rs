//! Zeroth-order machinery on the Rust side (substrate S16).
//!
//! The in-graph ZO update lives in the HLO `zo_step` entry; this module
//! provides (a) the bit-identical perturbation stream for analysis and the
//! Remark-4 O(1)-memory demonstration, and (b) a pure-Rust ZO-SGD reference
//! on analytic objectives used by property tests and the theory benches.

pub mod stream;

use stream::{for_each_chunk, PerturbStream};

/// Replay a full local phase from its lean wire record (`--zo_wire
/// seeds`): starting at the round's broadcast `theta0`, apply each
/// step's [`stream::replay_update`] in order — `seeds[s]` with the
/// per-step slice `gscales[s·n_p .. (s+1)·n_p]`. Returns `None` when the
/// record is inconsistent (`gscales.len() != seeds.len() · max(1, n_p)`)
/// so a malformed client upload is a typed server error, never a panic.
/// The result is bit-identical to the client's own h-step trajectory
/// (pinned end-to-end in `rust/tests/net_loopback.rs`).
pub fn replay_trajectory(
    theta0: &[f32],
    seeds: &[i32],
    n_pert: usize,
    gscales: &[f32],
) -> Option<Vec<f32>> {
    let np = n_pert.max(1);
    if gscales.len() != seeds.len() * np {
        return None;
    }
    let mut cur = theta0.to_vec();
    let mut next = Vec::with_capacity(theta0.len());
    for (s, &seed) in seeds.iter().enumerate() {
        stream::replay_update(
            &cur,
            seed,
            &gscales[s * np..(s + 1) * np],
            &mut next,
        );
        std::mem::swap(&mut cur, &mut next);
    }
    Some(cur)
}

/// Seed-space FedAvg (`--zo_wire seed_agg`, HO-SFL's dimension-free
/// aggregation): replay every participant's `(seeds, gscales)` record
/// from the shared round-start `theta0` and accumulate the weighted
/// average *one trajectory at a time* — never holding per-client θ_l
/// copies. The per-element operation sequence (`out = 0`, then
/// `out += (wᵢ/Σw) as f32 · θᵢ` in participant order) is exactly
/// [`crate::coordinator::aggregator::fedavg_into`]'s, so the result is
/// bit-identical to dense FedAvg over the same replayed trajectories —
/// whether it runs on the server (aggregating uploads) or on a client
/// (reconstructing the `SeedSync` broadcast).
///
/// Returns `None` — a typed caller error, never a panic — when the
/// roster is empty, `records`/`weights` disagree in length, any record
/// fails [`replay_trajectory`]'s shape check, or the weight total is
/// non-positive/non-finite (wire input is untrusted).
pub fn aggregate_trajectories(
    theta0: &[f32],
    records: &[(&[i32], &[f32])],
    weights: &[f64],
    n_pert: usize,
) -> Option<Vec<f32>> {
    if records.is_empty() || records.len() != weights.len() {
        return None;
    }
    let total: f64 = weights.iter().sum();
    if !(total > 0.0) || !total.is_finite() {
        return None;
    }
    let mut out = vec![0.0f32; theta0.len()];
    for ((seeds, gscales), &w) in records.iter().zip(weights) {
        let replayed = replay_trajectory(theta0, seeds, n_pert, gscales)?;
        let wf = (w / total) as f32;
        for (o, &x) in out.iter_mut().zip(replayed.iter()) {
            *o += wf * x;
        }
    }
    Some(out)
}

/// Two-point ZO-SGD on an analytic objective f: R^d -> R.
///
/// Mirrors the paper's Eq. (2) estimator with Gaussian directions:
///   g_hat = (f(θ + μu) - f(θ)) / μ * u.
/// `alloc_free_step` regenerates u from the seed in fixed-size chunks, so
/// peak extra memory is O(chunk), not O(d) — the Remark-4 trick.
/// `step_materialized` keeps its u/pert buffers as optimizer-held scratch
/// reused across steps (so the theory benches measure the estimator, not
/// the allocator), which is why it takes `&mut self`; threads sharing one
/// objective each hold their own optimizer and step their own θ.
pub struct ZoSgd<F: Fn(&[f32]) -> f32 + Sync> {
    pub f: F,
    pub mu: f32,
    pub lr: f32,
    pub chunk: usize,
    /// scratch for `step_materialized`'s u vector, reused across steps
    scratch_u: Vec<f32>,
    /// scratch for `step_materialized`'s perturbed θ, reused across steps
    scratch_pert: Vec<f32>,
}

impl<F: Fn(&[f32]) -> f32 + Sync> ZoSgd<F> {
    pub fn new(f: F, mu: f32, lr: f32) -> Self {
        Self {
            f,
            mu,
            lr,
            chunk: 4096,
            scratch_u: Vec::new(),
            scratch_pert: Vec::new(),
        }
    }

    /// One ZO step, materializing u into optimizer-held scratch (baseline
    /// implementation; allocation-free after the first call).
    pub fn step_materialized(&mut self, theta: &mut [f32], seed: u32) -> f32 {
        let d = theta.len();
        self.scratch_u.clear();
        self.scratch_u.resize(d, 0.0);
        PerturbStream::new(seed).fill(&mut self.scratch_u);
        self.scratch_pert.clear();
        self.scratch_pert.extend_from_slice(theta);
        for i in 0..d {
            self.scratch_pert[i] += self.mu * self.scratch_u[i];
        }
        let lp = (self.f)(&self.scratch_pert);
        let lb = (self.f)(theta);
        let scale = (lp - lb) / self.mu * self.lr;
        for i in 0..d {
            theta[i] -= scale * self.scratch_u[i];
        }
        lb
    }

    /// One ZO step with chunked perturbation regeneration
    /// ([`stream::for_each_chunk`]): u is produced twice from the seed
    /// (perturb pass, update pass) and never stored beyond `chunk`
    /// elements. Numerically identical to `step_materialized` because the
    /// stream is counter-based.
    pub fn alloc_free_step(&self, theta: &mut [f32], seed: u32) -> f32 {
        let lb = (self.f)(theta);
        let mut buf = vec![0.0f32; self.chunk.max(1)];
        // pass 1: perturb in place
        for_each_chunk(seed, theta.len(), &mut buf, |off, u| {
            for i in 0..u.len() {
                theta[off + i] += self.mu * u[i];
            }
        });
        let lp = (self.f)(theta);
        // pass 2: un-perturb and apply the update in one sweep
        let g_scale = (lp - lb) / self.mu;
        let step = self.lr * g_scale;
        for_each_chunk(seed, theta.len(), &mut buf, |off, u| {
            for i in 0..u.len() {
                theta[off + i] -= (self.mu + step) * u[i];
                // -mu*u undoes the probe perturbation; -step*u is the update
            }
        });
        lb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic(x: &[f32]) -> f32 {
        x.iter().map(|v| v * v).sum::<f32>() * 0.5
    }

    #[test]
    fn zo_sgd_converges_on_quadratic() {
        // ZO-SGD stability needs lr < ~2/d (the estimator's variance is
        // d-amplified); d=64 here, so lr=0.005 sits inside the region.
        let mut opt = ZoSgd::new(quadratic, 1e-3, 0.005);
        let mut theta: Vec<f32> =
            (0..64).map(|i| (i as f32 / 32.0) - 1.0).collect();
        let f0 = quadratic(&theta);
        for s in 0..2000 {
            opt.step_materialized(&mut theta, s);
        }
        let f1 = quadratic(&theta);
        assert!(f1 < f0 * 0.05, "f0 {f0} f1 {f1}");
    }

    #[test]
    fn alloc_free_matches_materialized() {
        // the streamed path reconstructs theta as (θ+μu)-(μ+step)u, whose
        // f32 rounding differs from θ-step·u by ulps; with a stable lr the
        // trajectories stay within loose tolerance
        let mut opt = ZoSgd::new(quadratic, 1e-3, 1e-3);
        let mut a: Vec<f32> = (0..500).map(|i| (i as f32).sin()).collect();
        let mut b = a.clone();
        for s in 0..20 {
            let la = opt.step_materialized(&mut a, s);
            let lb = opt.alloc_free_step(&mut b, s);
            assert!(
                (la - lb).abs() < 1e-3 * la.abs().max(1.0),
                "step {s}: {la} vs {lb}"
            );
        }
        let num: f64 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = a
            .iter()
            .map(|x| (*x as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            num / den < 1e-3,
            "relative L2 divergence {} between materialized and streamed \
             paths",
            num / den
        );
    }

    #[test]
    fn materialized_scratch_reuse_does_not_change_results() {
        // the optimizer-held scratch must be invisible: every step matches
        // a reference that allocates u/pert fresh
        let mu = 1e-3f32;
        let lr = 1e-3f32;
        let mut opt = ZoSgd::new(quadratic, mu, lr);
        let mut a: Vec<f32> =
            (0..100).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut b = a.clone();
        for s in 0..5 {
            let d = b.len();
            let u = PerturbStream::new(s).take_vec(d);
            let mut pert = b.clone();
            for i in 0..d {
                pert[i] += mu * u[i];
            }
            let lp = quadratic(&pert);
            let lb = quadratic(&b);
            let scale = (lp - lb) / mu * lr;
            for i in 0..d {
                b[i] -= scale * u[i];
            }
            let got = opt.step_materialized(&mut a, s);
            assert_eq!(got.to_bits(), lb.to_bits(), "loss at step {s}");
        }
        assert_eq!(a, b);
    }

    #[test]
    fn replay_trajectory_validates_record_shape() {
        let theta0 = vec![0.5f32; 32];
        // consistent record: 2 steps x 3 probes
        let gs = vec![0.01f32; 6];
        let out = replay_trajectory(&theta0, &[1, 2], 3, &gs).unwrap();
        assert_eq!(out.len(), 32);
        assert_ne!(out, theta0);
        // step-by-step equivalence with the single-step primitive
        let mut s1 = Vec::new();
        stream::replay_update(&theta0, 1, &gs[0..3], &mut s1);
        let mut s2 = Vec::new();
        stream::replay_update(&s1, 2, &gs[3..6], &mut s2);
        assert_eq!(out, s2);
        // inconsistent record: rejected, not panicked
        assert!(replay_trajectory(&theta0, &[1, 2], 3, &gs[..5]).is_none());
        assert!(replay_trajectory(&theta0, &[1], 3, &gs).is_none());
        // n_pert = 0 clamps to 1 like the estimator does
        assert!(replay_trajectory(&theta0, &[1, 2], 0, &gs[..2]).is_some());
    }

    #[test]
    fn aggregate_trajectories_is_bitwise_fedavg_of_replays() {
        let theta0: Vec<f32> =
            (0..64).map(|i| ((i as f32) * 0.17).sin()).collect();
        let np = 2;
        // 3 participants x 2 steps x 2 probes, distinct seeds/scalars
        let recs: Vec<(Vec<i32>, Vec<f32>)> = (0..3)
            .map(|c| {
                let seeds = vec![100 + c, 200 + c];
                let gs: Vec<f32> = (0..4)
                    .map(|s| 0.01 * (c as f32 + 1.0) * (s as f32 - 1.5))
                    .collect();
                (seeds, gs)
            })
            .collect();
        let weights = [3.0f64, 1.0, 2.0];
        let borrowed: Vec<(&[i32], &[f32])> = recs
            .iter()
            .map(|(s, g)| (s.as_slice(), g.as_slice()))
            .collect();
        let got =
            aggregate_trajectories(&theta0, &borrowed, &weights, np).unwrap();
        // reference: materialize every replay, then dense FedAvg
        let replayed: Vec<Vec<f32>> = recs
            .iter()
            .map(|(s, g)| replay_trajectory(&theta0, s, np, g).unwrap())
            .collect();
        let refs: Vec<&[f32]> =
            replayed.iter().map(|t| t.as_slice()).collect();
        let want =
            crate::coordinator::aggregator::fedavg(&refs, &weights);
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "streamed seed-space aggregation must be bit-identical to \
             dense FedAvg over the replayed trajectories"
        );
        // single participant with any positive weight is the pure replay
        let solo =
            aggregate_trajectories(&theta0, &borrowed[..1], &[5.0], np)
                .unwrap();
        assert_eq!(solo, replayed[0]);
    }

    #[test]
    fn aggregate_trajectories_rejects_malformed_input() {
        let theta0 = vec![0.25f32; 16];
        let seeds = vec![7, 8];
        let gs = vec![0.01f32; 4];
        let rec: Vec<(&[i32], &[f32])> = vec![(&seeds, &gs)];
        assert!(
            aggregate_trajectories(&theta0, &rec, &[1.0], 2).is_some()
        );
        // empty roster / length mismatch / bad record shape / bad weights
        assert!(aggregate_trajectories(&theta0, &[], &[], 2).is_none());
        assert!(
            aggregate_trajectories(&theta0, &rec, &[1.0, 1.0], 2).is_none()
        );
        let short: Vec<(&[i32], &[f32])> = vec![(&seeds, &gs[..3])];
        assert!(
            aggregate_trajectories(&theta0, &short, &[1.0], 2).is_none()
        );
        assert!(aggregate_trajectories(&theta0, &rec, &[0.0], 2).is_none());
        assert!(
            aggregate_trajectories(&theta0, &rec, &[f64::NAN], 2).is_none()
        );
    }

    #[test]
    fn chunk_size_does_not_change_result() {
        let mut o1 = ZoSgd::new(quadratic, 1e-3, 1e-3);
        o1.chunk = 7;
        let mut o2 = ZoSgd::new(quadratic, 1e-3, 1e-3);
        o2.chunk = 4096;
        let mut a: Vec<f32> = (0..300).map(|i| (i as f32).cos()).collect();
        let mut b = a.clone();
        for s in 0..10 {
            o1.alloc_free_step(&mut a, s);
            o2.alloc_free_step(&mut b, s);
        }
        assert_eq!(a, b);
    }
}
