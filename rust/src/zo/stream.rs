//! Counter-based perturbation stream — bit-identical mirror of
//! `python/compile/kernels/perturb.py`.
//!
//! Element i of the stream for seed s is `gauss(s, i)`: a murmur3-finalizer
//! hash expanded to four uniforms and combined Irwin-Hall(4) style,
//! `(sum - 2) * sqrt(3)`. Only +,*,- on f32, so jnp (oracle + Pallas kernel)
//! and this Rust implementation produce the same bits.

const C1: u32 = 0x9E37_79B9;
const C2: u32 = 0x21F0_AAAD;
const C3: u32 = 0x735A_2D97;
const SQRT3: f32 = 1.732_050_8;
const INV32: f32 = 2.328_306_4e-10; // 2^-32

/// murmur3-style avalanche of (seed, idx) — mirrors perturb.hash_u32.
#[inline]
pub fn hash_u32(seed: u32, idx: u32) -> u32 {
    let mut x = seed.wrapping_add(idx.wrapping_mul(C1));
    x ^= x >> 16;
    x = x.wrapping_mul(C2);
    x ^= x >> 15;
    x = x.wrapping_mul(C3);
    x ^ (x >> 15)
}

/// Approximate N(0,1) draw at stream position idx — mirrors perturb.gauss.
#[inline]
pub fn gauss(seed: u32, idx: u32) -> f32 {
    let idx4 = idx.wrapping_mul(4);
    let mut acc = 0.0f32;
    for k in 0..4u32 {
        acc += hash_u32(seed, idx4.wrapping_add(k)) as f32 * INV32;
    }
    (acc - 2.0) * SQRT3
}

/// Sub-seed derivation — mirrors perturb.fold_seed.
#[inline]
pub fn fold_seed(seed: u32, k: u32) -> u32 {
    hash_u32(seed, k.wrapping_add(0x517C_C1B7))
}

/// Default chunk size (elements) for streamed regeneration loops — the
/// value only sizes the scratch buffer; it never changes results.
pub const ZO_CHUNK: usize = 1024;

/// Drive `apply(offset, values)` over the seed's stream positions
/// `[0, d)` in chunk-sized pieces, regenerating values into `chunk`
/// instead of materializing the full vector — the Remark-4 O(chunk)
/// pattern shared by `ZoSgd::alloc_free_step` and the native models'
/// `zo_step` probes. The visit order equals a single `take_vec(d)`.
pub fn for_each_chunk(
    seed: u32,
    d: usize,
    chunk: &mut [f32],
    mut apply: impl FnMut(usize, &[f32]),
) {
    assert!(d == 0 || !chunk.is_empty(), "empty chunk buffer");
    let mut stream = PerturbStream::new(seed);
    let mut off = 0;
    while off < d {
        let n = chunk.len().min(d - off);
        stream.fill(&mut chunk[..n]);
        apply(off, &chunk[..n]);
        off += n;
    }
}

/// The update pass of one probe: accumulate `out[i] -= gscale · u_k[i]`
/// over probe k's regenerated stream. Shared verbatim by
/// [`two_point_zo_into`] (live training) and [`replay_update`]
/// (server-side seeds-mode replay), which is what makes the replay
/// bit-identical by construction — both paths run this exact loop with
/// the same `(sub_seed, gscale)` pairs in the same order.
#[inline]
fn accumulate_probe(
    sub_seed: u32,
    gscale: f32,
    d: usize,
    chunk: &mut [f32],
    out: &mut [f32],
) {
    for_each_chunk(sub_seed, d, chunk, |off, u| {
        for i in 0..u.len() {
            out[off + i] -= gscale * u[i];
        }
    });
}

/// The `θ + delta` finalization sweep shared by [`two_point_zo_into`]
/// and [`replay_update`] — like [`accumulate_probe`], shared so the
/// replay's bit-identity is structural, not by-convention.
#[inline]
fn finalize_update(theta: &[f32], out: &mut [f32]) {
    for i in 0..theta.len() {
        out[i] = theta[i] + out[i];
    }
}

/// Two-point ZO update with chunked probe regeneration — the exact
/// choreography shared by the native models' `zo_step` entries. `out` is
/// cleared and doubles as the delta accumulator until the final
/// `θ + delta` sweep; each probe's `u` is regenerated twice (perturb
/// pass, update pass) via [`for_each_chunk`], so no per-probe vector is
/// materialized and temporary memory is O(d + chunk) regardless of
/// `n_pert`. Every value stream and accumulation order matches the
/// materialized-u formulation bit for bit (pinned by the models'
/// `chunked_zo_matches_materialized_reference` tests).
///
/// `record_gscale` observes each probe's gradient scalar
/// `(l⁺_k − l)/μ · (lr/n_p)` as it is computed — the lean `ZoUpdate`
/// wire record (Remark 4). Pass `|_| {}` to discard; recording changes
/// no arithmetic and allocates nothing here.
#[allow(clippy::too_many_arguments)]
pub fn two_point_zo_into(
    theta: &[f32],
    seed: i32,
    mu: f32,
    lr: f32,
    n_pert: i32,
    base_loss: f32,
    mut probe_loss: impl FnMut(&[f32]) -> f32,
    out: &mut Vec<f32>,
    mut record_gscale: impl FnMut(f32),
) {
    let d = theta.len();
    let n_pert = n_pert.max(1) as usize;
    out.clear();
    out.resize(d, 0.0);
    let mut pert = vec![0.0f32; d];
    let mut chunk = vec![0.0f32; ZO_CHUNK.min(d.max(1))];
    for k in 0..n_pert {
        let sub = fold_seed(seed as u32, k as u32);
        // pass 1: perturb in chunks
        for_each_chunk(sub, d, &mut chunk, |off, u| {
            for i in 0..u.len() {
                pert[off + i] = theta[off + i] + mu * u[i];
            }
        });
        let lp = probe_loss(&pert);
        let gscale = (lp - base_loss) / mu * (lr / n_pert as f32);
        record_gscale(gscale);
        // pass 2: regenerate the same stream and accumulate the update
        accumulate_probe(sub, gscale, d, &mut chunk, out);
    }
    finalize_update(theta, out);
}

/// Server-side replay of a recorded two-point ZO step (the
/// `--zo_wire seeds` lean protocol, HERON-SFL §IV): reconstruct `θ'`
/// from `(seed, per-probe gscales)` without evaluating a single loss.
/// The probe count is `gscales.len()`; each direction `u_k` is
/// regenerated from `fold_seed(seed, k)` and applied through the same
/// [`accumulate_probe`] loop [`two_point_zo_into`] uses, followed by the
/// same `θ + delta` sweep — so a replay from a faithfully transmitted
/// record (f32 bit patterns preserved, which the wire codec guarantees)
/// is bit-identical to the client's own update.
pub fn replay_update(
    theta: &[f32],
    seed: i32,
    gscales: &[f32],
    out: &mut Vec<f32>,
) {
    let d = theta.len();
    out.clear();
    out.resize(d, 0.0);
    let mut chunk = vec![0.0f32; ZO_CHUNK.min(d.max(1))];
    for (k, &gscale) in gscales.iter().enumerate() {
        let sub = fold_seed(seed as u32, k as u32);
        accumulate_probe(sub, gscale, d, &mut chunk, out);
    }
    finalize_update(theta, out);
}

/// Sequential reader over the stream.
pub struct PerturbStream {
    seed: u32,
    pos: u32,
}

impl PerturbStream {
    pub fn new(seed: u32) -> Self {
        Self { seed, pos: 0 }
    }

    #[inline]
    pub fn next(&mut self) -> f32 {
        let v = gauss(self.seed, self.pos);
        self.pos += 1;
        v
    }

    pub fn fill(&mut self, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = self.next();
        }
    }

    pub fn take_vec(mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_position_addressable() {
        let a = PerturbStream::new(9).take_vec(128);
        let b = PerturbStream::new(9).take_vec(128);
        assert_eq!(a, b);
        assert_eq!(a[17], gauss(9, 17));
    }

    #[test]
    fn moments_near_standard_normal() {
        let n = 1 << 16;
        let xs = PerturbStream::new(7).take_vec(n);
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs
            .iter()
            .map(|&x| (x as f64 - mean).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        // bounded support of Irwin-Hall(4)
        assert!(xs.iter().all(|x| x.abs() <= 2.0 * 3f32.sqrt() + 1e-5));
    }

    #[test]
    fn chunked_visit_matches_take_vec() {
        let want = PerturbStream::new(33).take_vec(100);
        let mut got = vec![0.0f32; 100];
        let mut chunk = vec![0.0f32; 7]; // deliberately non-divisor
        for_each_chunk(33, 100, &mut chunk, |off, u| {
            got[off..off + u.len()].copy_from_slice(u);
        });
        assert_eq!(got, want);
        for_each_chunk(34, 0, &mut [], |_, _| panic!("d=0 must not visit"));
    }

    #[test]
    fn replay_reproduces_two_point_update_bitwise() {
        // objective: smooth deterministic function of θ
        let f = |t: &[f32]| {
            t.iter()
                .enumerate()
                .map(|(i, &v)| v * v * (1.0 + (i as f32) * 1e-3))
                .sum::<f32>()
        };
        let theta: Vec<f32> =
            (0..777).map(|i| ((i as f32) * 0.37).sin()).collect();
        let (seed, mu, lr, n_pert) = (0x5EED, 1e-2f32, 3e-3f32, 3i32);
        let base = f(&theta);
        let mut live = Vec::new();
        let mut gscales = Vec::new();
        two_point_zo_into(
            &theta,
            seed,
            mu,
            lr,
            n_pert,
            base,
            |p| f(p),
            &mut live,
            |g| gscales.push(g),
        );
        assert_eq!(gscales.len(), n_pert as usize);
        // replay from the record alone — no objective in sight
        let mut replayed = Vec::new();
        replay_update(&theta, seed, &gscales, &mut replayed);
        assert_eq!(live.len(), replayed.len());
        for i in 0..live.len() {
            assert_eq!(
                live[i].to_bits(),
                replayed[i].to_bits(),
                "elem {i}"
            );
        }
        // dirty output buffer must not leak into a second replay
        let mut again = vec![9.0f32; 3];
        replay_update(&theta, seed, &gscales, &mut again);
        assert_eq!(again, replayed);
    }

    #[test]
    fn recording_does_not_change_the_update() {
        let f = |t: &[f32]| t.iter().map(|v| v * v).sum::<f32>();
        let theta: Vec<f32> =
            (0..200).map(|i| ((i as f32) * 0.11).cos()).collect();
        let base = f(&theta);
        let mut plain = Vec::new();
        two_point_zo_into(
            &theta, 7, 1e-2, 1e-3, 2, base, |p| f(p), &mut plain, |_| {},
        );
        let mut recorded = Vec::new();
        let mut gs = Vec::new();
        two_point_zo_into(
            &theta,
            7,
            1e-2,
            1e-3,
            2,
            base,
            |p| f(p),
            &mut recorded,
            |g| gs.push(g),
        );
        assert_eq!(plain, recorded);
        assert_eq!(gs.len(), 2);
    }

    #[test]
    fn seeds_decorrelated() {
        let a = PerturbStream::new(1).take_vec(4096);
        let b = PerturbStream::new(fold_seed(1, 0)).take_vec(4096);
        let dot: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum::<f64>()
            / 4096.0;
        assert!(dot.abs() < 0.05, "corr {dot}");
    }

    #[test]
    fn matches_python_reference_values() {
        // Pinned from python: perturb.gauss(jnp.uint32(42), arange(4))
        // (verified in tests/golden.rs against the manifest too; these are
        // unit-level spot checks of the scalar pipeline)
        let vals: Vec<f32> = (0..4).map(|i| gauss(42, i)).collect();
        // hash determinism check rather than golden floats here: recompute
        // through an independent expansion of the same definition
        for (i, &v) in vals.iter().enumerate() {
            let idx4 = (i as u32) * 4;
            let mut acc = 0.0f32;
            for k in 0..4 {
                acc += hash_u32(42, idx4 + k) as f32 * INV32;
            }
            assert_eq!(v, (acc - 2.0) * SQRT3);
        }
    }
}
