//! Typed client-runtime API (substrate S22): the object-safe trait the
//! coordinator's hot path drives instead of stringly-typed entry
//! invocation.
//!
//! Until this layer existed, every training step funneled through
//! `Session::invoke_into(variant, "zo_step", &[...])`: entry names were
//! free strings, arguments were positional `TensorRef`s bound by name at
//! runtime, and outputs came back as dynamically-typed slots the caller
//! had to down-cast. That surface could not expose what the lean wire
//! mode needs — the per-probe `(l⁺ − l)/μ · (lr/n_p)` gradient scalars
//! that `zo::stream::two_point_zo_into`'s second pass computes — because
//! the `zo_step` entry only declares `(theta_l, loss)` outputs.
//!
//! [`ClientRuntime`] is the typed replacement: one method per protocol
//! step, fixed argument lists, concrete return types. It is implemented
//! by both native models (`VisionModel`, `LmModel`) and resolved per
//! variant via [`crate::runtime::Session::client_runtime`]. `zo_step`
//! returns a [`ZoStepRecord`] carrying the base loss *and* the per-probe
//! gradient scalars, which is exactly the `ZoUpdate{seeds, gscales}`
//! payload of the `--zo_wire seeds` replay mode (HERON-SFL §IV, Remark
//! 4): the server reproduces `θ'` bit-identically from `(seed, gscales)`
//! via [`crate::zo::stream::replay_update`] without the client ever
//! uploading parameters.
//!
//! The trait is also the single source of truth for what each manifest
//! entry looks like: [`ENTRY_SIGS`] lists the canonical input/output
//! names per entry, derived from the trait's method signatures, and
//! [`check_entry_spec`] validates every manifest entry against it at
//! `Session::new` — a drifted manifest (stale slot count, renamed
//! output, unknown entry) fails at session construction instead of at
//! first invoke. The engine's per-invoke arity guard is derived from the
//! same table, so the two can never disagree.
//!
//! `Session` (and its `invoke`/`invoke_into`/`Call` surface) remains the
//! artifact/golden loader and the cross-language validation path; the
//! trait is the training hot path.

use crate::runtime::manifest::EntrySpec;
use crate::runtime::tensor::TensorRef;
use anyhow::{anyhow, bail, Result};

/// Scalar arguments of one two-point ZO step (paper Eq. 6).
#[derive(Debug, Clone, Copy)]
pub struct ZoArgs {
    /// counter-derived step seed (`coordinator::local::step_seed`)
    pub seed: i32,
    /// perturbation step size μ
    pub mu: f32,
    /// client learning rate
    pub lr: f32,
    /// probes per step (n_p); clamped to ≥ 1
    pub n_pert: i32,
}

/// What one ZO step produces besides the updated θ: the lean wire record
/// (paper Remark 4). `(seed, gscales)` is sufficient for any holder of
/// the pre-step θ to replay the update bit-identically —
/// `zo::stream::replay_update` regenerates each probe's direction `u_k`
/// from `fold_seed(seed, k)` and applies `θ' = θ − Σ_k gscales[k]·u_k`.
#[derive(Debug, Clone, Default)]
pub struct ZoStepRecord {
    /// loss at the pre-update point (the shared base evaluation)
    pub loss: f32,
    /// the step's perturbation seed
    pub seed: i32,
    /// per-probe gradient scalars `(l⁺_k − l)/μ · (lr/n_p)`, length
    /// `max(1, n_pert)`; the buffer is reused across steps
    pub gscales: Vec<f32>,
}

/// Flat-parameter layout of a split model variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ThetaLayout {
    /// client partition |θ_c|
    pub nc: usize,
    /// auxiliary head |θ_a|
    pub na: usize,
    /// server partition |θ_s|
    pub ns: usize,
    /// frozen base size (0 when the variant has none)
    pub nb: usize,
}

impl ThetaLayout {
    /// |θ_l| = |θ_c| + |θ_a| — the client-held trainable vector.
    pub fn nl(&self) -> usize {
        self.nc + self.na
    }
}

/// The typed, object-safe runtime surface one model variant exposes to
/// the coordinator. Batch tensors cross as [`TensorRef`] views (vision
/// batches are f32 pixels, LM batches are i32 tokens); parameters are
/// plain `&[f32]` slices; outputs land in caller-owned reused `Vec`s.
/// Every method is bit-identical to the corresponding manifest entry —
/// both dispatch to the same model code.
pub trait ClientRuntime: Sync {
    /// Parameter layout (sizes agree with the manifest's size contract).
    fn layout(&self) -> ThetaLayout;

    /// One two-point ZO step on θ_l (Eq. 6): writes θ' into `out` and
    /// fills `rec` with the base loss + per-probe gradient scalars.
    #[allow(clippy::too_many_arguments)]
    fn zo_step(
        &self,
        base: Option<&[f32]>,
        theta_l: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
        zo: ZoArgs,
        out: &mut Vec<f32>,
        rec: &mut ZoStepRecord,
    ) -> Result<()>;

    /// One FO step on θ_l; writes θ' into `out`, returns the pre-update
    /// loss.
    fn fo_step(
        &self,
        base: Option<&[f32]>,
        theta_l: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<f32>;

    /// Client forward to the cut layer; writes the smashed activations
    /// into `out`.
    fn client_fwd(
        &self,
        base: Option<&[f32]>,
        theta_c: &[f32],
        x: TensorRef<'_>,
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Server FO update on an uploaded smashed batch (Eq. 7); writes θ_s'
    /// into `out`, fills `cut` with ∂L/∂smashed when given, returns the
    /// loss.
    #[allow(clippy::too_many_arguments)]
    fn server_step(
        &self,
        base: Option<&[f32]>,
        theta_s: &[f32],
        smashed: &[f32],
        y: &[i32],
        lr: f32,
        cut: Option<&mut Vec<f32>>,
        out: &mut Vec<f32>,
    ) -> Result<f32>;

    /// Client backprop step from a relayed cut gradient (SFLV1/V2).
    #[allow(clippy::too_many_arguments)]
    fn client_bp_step(
        &self,
        base: Option<&[f32]>,
        theta_c: &[f32],
        x: TensorRef<'_>,
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// FSL-SAGE aux alignment against the server's cut gradient.
    #[allow(clippy::too_many_arguments)]
    fn aux_align(
        &self,
        base: Option<&[f32]>,
        theta_l: &[f32],
        smashed: &[f32],
        y: &[i32],
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Assembled-model evaluation: `(stat1, stat2)` — vision
    /// (correct, total), LM (NLL sum, token count).
    fn eval_full(
        &self,
        base: Option<&[f32]>,
        theta_c: &[f32],
        theta_s: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
    ) -> Result<(f32, f32)>;
}

// ---------------------------------------------------------------------------
// canonical entry signatures
// ---------------------------------------------------------------------------

/// The canonical manifest shape of one entry: input names (after the
/// optional leading `base` blob) and output names, both in declaration
/// order. Derived from the [`ClientRuntime`] method signatures (plus the
/// cold `local_loss`/`hvp` analysis entries), and consumed by
/// [`check_entry_spec`] and the engine's output-arity guard.
#[derive(Debug, Clone, Copy)]
pub struct EntrySig {
    pub name: &'static str,
    /// required inputs, in order, excluding the optional leading `base`
    pub inputs: &'static [&'static str],
    /// outputs, in order
    pub outputs: &'static [&'static str],
}

/// Every entry the native runtime knows how to execute.
pub const ENTRY_SIGS: &[EntrySig] = &[
    EntrySig {
        name: "local_loss",
        inputs: &["theta_l", "x", "y"],
        outputs: &["loss"],
    },
    EntrySig {
        name: "zo_step",
        inputs: &["theta_l", "x", "y", "seed", "mu", "lr", "n_pert"],
        outputs: &["theta_l", "loss"],
    },
    EntrySig {
        name: "fo_step",
        inputs: &["theta_l", "x", "y", "lr"],
        outputs: &["theta_l", "loss"],
    },
    EntrySig {
        name: "client_fwd",
        inputs: &["theta_c", "x"],
        outputs: &["smashed"],
    },
    EntrySig {
        name: "server_step",
        inputs: &["theta_s", "smashed", "y", "lr"],
        outputs: &["theta_s", "loss"],
    },
    EntrySig {
        name: "server_step_cutgrad",
        inputs: &["theta_s", "smashed", "y", "lr"],
        outputs: &["theta_s", "loss", "g_smashed"],
    },
    EntrySig {
        name: "client_bp_step",
        inputs: &["theta_c", "x", "g_smashed", "lr"],
        outputs: &["theta_c"],
    },
    EntrySig {
        name: "aux_align",
        inputs: &["theta_l", "smashed", "y", "g_smashed", "lr"],
        outputs: &["theta_l"],
    },
    EntrySig {
        name: "eval_full",
        inputs: &["theta_c", "theta_s", "x", "y"],
        outputs: &["stat1", "stat2"],
    },
    EntrySig {
        name: "hvp",
        inputs: &["theta_l", "x", "y", "v"],
        outputs: &["hv"],
    },
];

/// The canonical signature of a named entry, if the typed API knows it.
pub fn entry_sig(name: &str) -> Option<&'static EntrySig> {
    ENTRY_SIGS.iter().find(|s| s.name == name)
}

/// Validate one manifest entry against its canonical signature. Called
/// for every entry of every variant at `Session::new`, so a drifted
/// manifest — an entry the runtime does not implement, a stale output
/// slot, a renamed or reordered tensor — fails at session construction
/// with a precise message instead of producing placeholder slots (or a
/// late bail) at first invoke.
pub fn check_entry_spec(variant: &str, espec: &EntrySpec) -> Result<()> {
    let sig = entry_sig(&espec.name).ok_or_else(|| {
        anyhow!(
            "{variant}/{}: entry is unknown to the typed runtime API \
             (manifest drift?)",
            espec.name
        )
    })?;
    let outs: Vec<&str> =
        espec.outputs.iter().map(|s| s.name.as_str()).collect();
    if outs != sig.outputs {
        bail!(
            "{variant}/{}: manifest outputs {outs:?} do not match the \
             typed signature {:?}",
            espec.name,
            sig.outputs
        );
    }
    let mut ins: Vec<&str> =
        espec.inputs.iter().map(|s| s.name.as_str()).collect();
    if ins.first() == Some(&"base") {
        ins.remove(0);
    }
    if ins != sig.inputs {
        bail!(
            "{variant}/{}: manifest inputs {ins:?} do not match the typed \
             signature {:?} (+ optional leading `base`)",
            espec.name,
            sig.inputs
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{DType, TensorSpec};
    use std::path::PathBuf;

    fn spec(name: &str) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: vec![2],
            dtype: DType::F32,
        }
    }

    fn espec(name: &str, ins: &[&str], outs: &[&str]) -> EntrySpec {
        EntrySpec {
            name: name.into(),
            file: PathBuf::new(),
            inputs: ins.iter().map(|n| spec(n)).collect(),
            outputs: outs.iter().map(|n| spec(n)).collect(),
        }
    }

    #[test]
    fn sigs_are_unique_and_resolvable() {
        let mut seen = std::collections::BTreeSet::new();
        for s in ENTRY_SIGS {
            assert!(seen.insert(s.name), "duplicate sig {}", s.name);
            assert!(!s.outputs.is_empty(), "{}: no outputs", s.name);
            assert!(std::ptr::eq(entry_sig(s.name).unwrap(), s));
        }
        assert!(entry_sig("zo_step_v2").is_none());
    }

    #[test]
    fn check_accepts_canonical_with_and_without_base() {
        let ok = espec(
            "zo_step",
            &["theta_l", "x", "y", "seed", "mu", "lr", "n_pert"],
            &["theta_l", "loss"],
        );
        check_entry_spec("v", &ok).unwrap();
        let ok_base = espec(
            "zo_step",
            &["base", "theta_l", "x", "y", "seed", "mu", "lr", "n_pert"],
            &["theta_l", "loss"],
        );
        check_entry_spec("v", &ok_base).unwrap();
    }

    #[test]
    fn check_rejects_every_drift_class() {
        // unknown entry
        let e = espec("zo_step_v2", &["theta_l"], &["theta_l"]);
        assert!(check_entry_spec("v", &e).is_err());
        // stale extra output slot
        let e = espec(
            "fo_step",
            &["theta_l", "x", "y", "lr"],
            &["theta_l", "loss", "grad_norm"],
        );
        assert!(check_entry_spec("v", &e).is_err());
        // renamed output
        let e = espec(
            "client_fwd",
            &["theta_c", "x"],
            &["activations"],
        );
        assert!(check_entry_spec("v", &e).is_err());
        // reordered inputs
        let e = espec(
            "client_fwd",
            &["x", "theta_c"],
            &["smashed"],
        );
        assert!(check_entry_spec("v", &e).is_err());
        // missing input
        let e = espec("client_fwd", &["theta_c"], &["smashed"]);
        assert!(check_entry_spec("v", &e).is_err());
    }
}
