//! L3 runtime: load artifact manifests and execute their entry points.
//!
//! One `Session` owns the artifact manifest and an execution engine.
//! Invocation validates inputs against the manifest's `TensorSpec`s,
//! executes the entry, and returns the outputs.
//!
//! The default engine is the pure-Rust [`native`] reference backend
//! (substrate S20): deterministic f32 math with counter-based random
//! streams, bit-identical across runs and thread counts. The PJRT/XLA
//! path this API was originally written for (HloModuleProto -> compile ->
//! execute) needs the XLA toolchain, which is not in the offline vendor
//! set; the `Session` surface is backend-agnostic so it can return behind
//! a feature gate without touching callers.
//!
//! Three invocation paths exist, bit-identical by construction:
//!
//! * [`Session::client_runtime`] — the typed [`api::ClientRuntime`]
//!   trait surface: one method per protocol step, concrete argument and
//!   return types, per-probe ZO records. This is what the coordinator's
//!   hot path drives; the manifest is validated against the trait's
//!   canonical signatures at [`Session::new`].
//! * [`Session::invoke`] — name-based entries, owned `TensorValue` in,
//!   fresh `Vec` out. The artifact/golden validation path.
//! * [`Session::invoke_into`] — borrowed [`TensorRef`] views in, outputs
//!   written into a caller-owned slot vector whose buffers are reused
//!   across calls.
//!
//! `Session` is `Sync`: the manifest and engine are immutable after
//! construction and the runtime statistics sit behind a mutex, so the
//! parallel round driver can invoke entries from worker threads
//! concurrently.

pub mod api;
pub mod artifacts;
pub mod manifest;
pub mod native;
pub mod tensor;

use anyhow::{bail, Context, Result};
use api::ClientRuntime;
use manifest::{Manifest, VariantSpec};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;
use tensor::{TensorRef, TensorValue};

/// Cumulative execution statistics (the coordinator reads these for
/// §Perf and the event simulator's compute-time calibration). The
/// feature-plan cache counters come from the engine's per-model caches:
/// `feature_cache_hits`/`misses` count θ-independent projection lookups,
/// and `alloc_avoided_bytes` totals the bytes served from cache instead of
/// recomputed into fresh allocations.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub invocations: u64,
    pub exec_seconds: f64,
    pub marshal_seconds: f64,
    pub compile_seconds: f64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub feature_cache_hits: u64,
    pub feature_cache_misses: u64,
    pub alloc_avoided_bytes: u64,
}

impl RuntimeStats {
    /// Hit rate of the feature-plan cache in [0, 1] (0 when unused).
    pub fn feature_cache_hit_rate(&self) -> f64 {
        let total = self.feature_cache_hits + self.feature_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.feature_cache_hits as f64 / total as f64
        }
    }

    /// Mirror the counters into the telemetry registry (`runtime.*`
    /// gauges). Absolute sets, so re-publishing is idempotent.
    pub fn publish_registry(&self) {
        use crate::telemetry::registry::gauge;
        gauge("runtime.invocations").set(self.invocations as f64);
        gauge("runtime.exec_seconds").set(self.exec_seconds);
        gauge("runtime.marshal_seconds").set(self.marshal_seconds);
        gauge("runtime.compile_seconds").set(self.compile_seconds);
        gauge("runtime.bytes_in").set(self.bytes_in as f64);
        gauge("runtime.bytes_out").set(self.bytes_out as f64);
        gauge("runtime.feature_cache_hits")
            .set(self.feature_cache_hits as f64);
        gauge("runtime.feature_cache_misses")
            .set(self.feature_cache_misses as f64);
        gauge("runtime.alloc_avoided_bytes")
            .set(self.alloc_avoided_bytes as f64);
    }
}

pub struct Session {
    pub manifest: Manifest,
    engine: native::Engine,
    stats: Mutex<RuntimeStats>,
}

impl Session {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let t0 = Instant::now();
        let engine =
            native::Engine::new(&manifest).context("building native engine")?;
        let build = t0.elapsed().as_secs_f64();
        log::debug!(
            "native engine ready: {} variants in {build:.3}s",
            manifest.variants.len()
        );
        Ok(Session {
            manifest,
            engine,
            stats: Mutex::new(RuntimeStats {
                compile_seconds: build,
                ..RuntimeStats::default()
            }),
        })
    }

    pub fn open_default() -> Result<Self> {
        Self::new(Manifest::load_default()?)
    }

    pub fn stats(&self) -> RuntimeStats {
        let mut st =
            self.stats.lock().unwrap_or_else(|p| p.into_inner()).clone();
        let cs = self.engine.cache_stats();
        st.feature_cache_hits = cs.hits;
        st.feature_cache_misses = cs.misses;
        st.alloc_avoided_bytes = cs.bytes_avoided;
        st
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.manifest.variant(name)
    }

    /// The typed runtime surface for one variant — what the coordinator's
    /// hot path drives instead of name-based entry invocation (see
    /// [`api::ClientRuntime`]). Dispatches straight to the native model;
    /// the manifest was validated against the trait's signatures at
    /// construction, so no per-call name/shape marshalling remains.
    /// (Typed calls bypass the `RuntimeStats` invocation counters; the
    /// feature-plan cache counters live in the models and keep counting.)
    pub fn client_runtime(&self, variant: &str) -> Result<&dyn ClientRuntime> {
        Ok(match self.engine.model(variant)? {
            native::Model::Vision(m) => m,
            native::Model::Lm(m) => m,
        })
    }

    /// Validate that the given entries exist for the variant (the AOT
    /// backend eagerly compiled them here; the native engine is ready as
    /// soon as the session is). A request for an entry the variant does
    /// not provide is an error — a typo'd entry name must not "warm up"
    /// successfully and then fail at first invoke.
    pub fn warmup(&self, variant: &str, entries: &[&str]) -> Result<()> {
        let v = self.manifest.variant(variant)?;
        for e in entries {
            if !v.entries.contains_key(*e) {
                bail!("variant {variant} has no entry {e} to warm up");
            }
        }
        if !entries.is_empty() {
            self.engine.model(variant)?;
        }
        Ok(())
    }

    /// Invoke an entry with positional inputs; returns positional outputs.
    pub fn invoke(
        &self,
        variant: &str,
        entry: &str,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let refs: Vec<TensorRef> =
            inputs.iter().map(|v| v.view()).collect();
        let mut outs = Vec::new();
        self.invoke_into(variant, entry, &refs, &mut outs)?;
        Ok(outs)
    }

    /// Invoke an entry with borrowed positional inputs, writing outputs
    /// into `outs` (buffers reused across calls). Bit-identical to
    /// [`Self::invoke`]; this is the zero-allocation hot path.
    pub fn invoke_into(
        &self,
        variant: &str,
        entry: &str,
        inputs: &[TensorRef<'_>],
        outs: &mut Vec<TensorValue>,
    ) -> Result<()> {
        let vspec = self.manifest.variant(variant)?;
        let espec = vspec.entry(entry)?;
        if inputs.len() != espec.inputs.len() {
            bail!(
                "{variant}/{entry}: expected {} inputs, got {}",
                espec.inputs.len(),
                inputs.len()
            );
        }

        let tm = Instant::now();
        let mut bytes_in = 0u64;
        for (val, spec) in inputs.iter().zip(&espec.inputs) {
            val.check(spec)
                .with_context(|| format!("{variant}/{entry}"))?;
            bytes_in += (val.len() * 4) as u64;
        }
        let marshal = tm.elapsed().as_secs_f64();

        let te = Instant::now();
        self.engine
            .execute_into(vspec, espec, inputs, outs)
            .with_context(|| format!("executing {variant}/{entry}"))?;
        let exec_dt = te.elapsed().as_secs_f64();

        if outs.len() != espec.outputs.len() {
            bail!(
                "{variant}/{entry}: expected {} outputs, got {}",
                espec.outputs.len(),
                outs.len()
            );
        }
        let bytes_out: u64 =
            outs.iter().map(|v| (v.len() * 4) as u64).sum();

        let mut st = self.stats.lock().unwrap_or_else(|p| p.into_inner());
        st.invocations += 1;
        st.exec_seconds += exec_dt.max(1e-9);
        st.marshal_seconds += marshal;
        st.bytes_in += bytes_in;
        st.bytes_out += bytes_out;
        Ok(())
    }
}

/// Convenience: named-argument invocation builder.
pub struct Call<'a> {
    session: &'a Session,
    variant: &'a str,
    entry: &'a str,
    args: HashMap<String, TensorValue>,
}

impl<'a> Call<'a> {
    pub fn new(session: &'a Session, variant: &'a str, entry: &'a str) -> Self {
        Call {
            session,
            variant,
            entry,
            args: HashMap::new(),
        }
    }

    pub fn arg<V: Into<TensorValue>>(mut self, name: &str, v: V) -> Self {
        self.args.insert(name.to_string(), v.into());
        self
    }

    pub fn run(mut self) -> Result<HashMap<String, TensorValue>> {
        let vspec = self.session.manifest.variant(self.variant)?;
        let espec = vspec.entry(self.entry)?;
        let mut inputs = Vec::with_capacity(espec.inputs.len());
        for spec in &espec.inputs {
            let v = self.args.remove(&spec.name).ok_or_else(|| {
                anyhow::anyhow!(
                    "{}/{}: missing argument {}",
                    self.variant,
                    self.entry,
                    spec.name
                )
            })?;
            inputs.push(v);
        }
        if let Some(extra) = self.args.keys().next() {
            bail!(
                "{}/{}: unknown argument {extra}",
                self.variant,
                self.entry
            );
        }
        let outs = self.session.invoke(self.variant, self.entry, &inputs)?;
        Ok(espec
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }
}
