//! L3 runtime: load AOT HLO artifacts and execute them on PJRT CPU.
//!
//! One `Session` owns the PJRT client and a lazily-populated cache of
//! compiled executables keyed by (variant, entry). Invocation marshals
//! `TensorValue`s to `xla::Literal`s per the manifest's `TensorSpec`s,
//! executes, and unpacks the returned tuple.
//!
//! The flow (see /opt/xla-example reference):
//!   HloModuleProto::from_text_file -> XlaComputation::from_proto
//!   -> client.compile -> exe.execute -> Literal tuple.

pub mod manifest;
pub mod tensor;

use anyhow::{bail, Context, Result};
use manifest::{DType, Manifest, VariantSpec};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;
use tensor::TensorValue;

/// Cumulative execution statistics (the coordinator reads these for
/// §Perf and the event simulator's compute-time calibration).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub invocations: u64,
    pub exec_seconds: f64,
    pub marshal_seconds: f64,
    pub compile_seconds: f64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

pub struct Session {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables:
        RefCell<HashMap<(String, String), Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Session {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Session {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn open_default() -> Result<Self> {
        Self::new(Manifest::load_default()?)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.manifest.variant(name)
    }

    /// Compile (or fetch cached) the executable for (variant, entry).
    pub fn executable(
        &self,
        variant: &str,
        entry: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (variant.to_string(), entry.to_string());
        if let Some(e) = self.executables.borrow().get(&key) {
            return Ok(e.clone());
        }
        let vspec = self.manifest.variant(variant)?;
        let espec = vspec.entry(entry)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&espec.file)
            .with_context(|| format!("parsing {}", espec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {variant}/{entry}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.borrow_mut().compile_seconds += dt;
        log::debug!("compiled {variant}/{entry} in {dt:.2}s");
        let rc = Rc::new(exe);
        self.executables.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Pre-compile a set of entries (examples call this up-front so the
    /// first training round isn't skewed by compile time).
    pub fn warmup(&self, variant: &str, entries: &[&str]) -> Result<()> {
        for e in entries {
            if self.manifest.variant(variant)?.entries.contains_key(*e) {
                self.executable(variant, e)?;
            }
        }
        Ok(())
    }

    /// Invoke an entry with positional inputs; returns positional outputs.
    pub fn invoke(
        &self,
        variant: &str,
        entry: &str,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let vspec = self.manifest.variant(variant)?;
        let espec = vspec.entry(entry)?;
        if inputs.len() != espec.inputs.len() {
            bail!(
                "{variant}/{entry}: expected {} inputs, got {}",
                espec.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(variant, entry)?;

        let tm = Instant::now();
        let mut literals = Vec::with_capacity(inputs.len());
        let mut bytes_in = 0u64;
        for (val, spec) in inputs.iter().zip(&espec.inputs) {
            val.check(spec)
                .with_context(|| format!("{variant}/{entry}"))?;
            literals.push(to_literal(val, spec)?);
            bytes_in += (val.len() * 4) as u64;
        }
        let marshal1 = tm.elapsed().as_secs_f64();

        let te = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {variant}/{entry}"))?;
        let exec_dt = te.elapsed().as_secs_f64();

        let tm2 = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.to_tuple().context("untupling result")?;
        if parts.len() != espec.outputs.len() {
            bail!(
                "{variant}/{entry}: expected {} outputs, got {}",
                espec.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        let mut bytes_out = 0u64;
        for (lit, spec) in parts.into_iter().zip(&espec.outputs) {
            let v = from_literal(&lit, spec)?;
            bytes_out += (v.len() * 4) as u64;
            outs.push(v);
        }
        let marshal2 = tm2.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        st.invocations += 1;
        st.exec_seconds += exec_dt;
        st.marshal_seconds += marshal1 + marshal2;
        st.bytes_in += bytes_in;
        st.bytes_out += bytes_out;
        Ok(outs)
    }
}

fn to_literal(
    val: &TensorValue,
    spec: &manifest::TensorSpec,
) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match val {
        TensorValue::ScalarF32(s) => xla::Literal::scalar(*s),
        TensorValue::ScalarI32(s) => xla::Literal::scalar(*s),
        TensorValue::F32(v) => {
            let l = xla::Literal::vec1(v.as_slice());
            if spec.shape.len() == 1 {
                l
            } else {
                l.reshape(&dims).context("reshape f32 input")?
            }
        }
        TensorValue::I32(v) => {
            let l = xla::Literal::vec1(v.as_slice());
            if spec.shape.len() == 1 {
                l
            } else {
                l.reshape(&dims).context("reshape i32 input")?
            }
        }
    };
    Ok(lit)
}

fn from_literal(
    lit: &xla::Literal,
    spec: &manifest::TensorSpec,
) -> Result<TensorValue> {
    match spec.dtype {
        DType::F32 => {
            if spec.shape.is_empty() {
                Ok(TensorValue::ScalarF32(
                    lit.get_first_element::<f32>()
                        .context("scalar f32 output")?,
                ))
            } else {
                Ok(TensorValue::F32(
                    lit.to_vec::<f32>().context("f32 output")?,
                ))
            }
        }
        DType::I32 => {
            if spec.shape.is_empty() {
                Ok(TensorValue::ScalarI32(
                    lit.get_first_element::<i32>()
                        .context("scalar i32 output")?,
                ))
            } else {
                Ok(TensorValue::I32(
                    lit.to_vec::<i32>().context("i32 output")?,
                ))
            }
        }
    }
}

/// Convenience: named-argument invocation builder.
pub struct Call<'a> {
    session: &'a Session,
    variant: &'a str,
    entry: &'a str,
    args: HashMap<String, TensorValue>,
}

impl<'a> Call<'a> {
    pub fn new(session: &'a Session, variant: &'a str, entry: &'a str) -> Self {
        Call {
            session,
            variant,
            entry,
            args: HashMap::new(),
        }
    }

    pub fn arg<V: Into<TensorValue>>(mut self, name: &str, v: V) -> Self {
        self.args.insert(name.to_string(), v.into());
        self
    }

    pub fn run(mut self) -> Result<HashMap<String, TensorValue>> {
        let vspec = self.session.manifest.variant(self.variant)?;
        let espec = vspec.entry(self.entry)?;
        let mut inputs = Vec::with_capacity(espec.inputs.len());
        for spec in &espec.inputs {
            let v = self.args.remove(&spec.name).ok_or_else(|| {
                anyhow::anyhow!(
                    "{}/{}: missing argument {}",
                    self.variant,
                    self.entry,
                    spec.name
                )
            })?;
            inputs.push(v);
        }
        if let Some(extra) = self.args.keys().next() {
            bail!(
                "{}/{}: unknown argument {extra}",
                self.variant,
                self.entry
            );
        }
        let outs = self.session.invoke(self.variant, self.entry, &inputs)?;
        Ok(espec
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }
}
